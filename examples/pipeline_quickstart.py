"""Declarative plan-API quickstart: chained enrichment, filter, projection,
multi-sink fan-out, per-stage elasticity, progressive re-enrichment
(ref updates repairing stored rows in place), and analytical queries over
the enriched store — ingest, repair, and query in one pass.

The SQL++ this models (paper Figures 8/12, extended):

    CREATE FEED TweetFeed;
    CONNECT FEED TweetFeed TO DATASET EnrichedTweets
        APPLY FUNCTION safetyLevel, religiousPopulation   -- chained UDFs
        WHERE safety_level >= 3                           -- filter
        SELECT safety_level, religious_population;        -- project
    -- plus a second consumer of the same enriched stream (tee)

Elasticity (core/elasticity.py): ``.enrich(udf, partitions=..., elastic=
ElasticSpec(...))`` makes that stage its own **stage group** — its own
holder + worker pool, scaled between min/max partitions by a backlog-
sampling controller, independently of the rest of the chain.  A feed-wide
default goes on ``options(elastic=...)``.

Run:  PYTHONPATH=src python examples/pipeline_quickstart.py

(examples/quickstart.py shows the pre-plan FeedConfig shim.)
"""

import shutil
import tempfile
import threading
import time

import numpy as np

from repro.core import (CompactionSpec, ElasticSpec, FeedManager, RefStore,
                        RepairSpec, SyntheticAdapter, agg, col, pipeline)
from repro.core.enrich import queries as Q

# 1. reference data at (scaled-down) paper cardinalities
store = RefStore()
Q.make_reference_tables(store, scale=0.01, seed=7)
mgr = FeedManager(store)

# 2. a tee sink: a live consumer of the enriched stream (the LM data plane
#    in train/data_feed.py is exactly this, feeding a trainer)
lock = threading.Lock()
tee_rows = [0]


def monitor(batch):
    with lock:
        tee_rows[0] += int(batch["valid"].sum())


# 3. the declarative plan: parse -> Q1 (cheap probe, static) -> Q2 + filter
#    (its own stage group: declared partitions + elastic bounds, so the
#    controller scales THIS stage's workers with its backlog while Q1's
#    pool stays put) -> project -> fan out to the monitor AND the column
#    store, exactly once each.  Stages without their own declaration fuse
#    into the preceding group (the filter rides with Q2's workers).
plan = (pipeline(SyntheticAdapter(total=20_000, frame_size=420, seed=1),
                 "TweetPipeline")
        .parse(batch_size=420)
        .options(num_partitions=1)
        .enrich(Q.Q1)
        .enrich(Q.Q2, partitions=1,
                elastic=ElasticSpec(min_partitions=1, max_partitions=2,
                                    interval_s=0.02, up_after=1,
                                    cooldown_s=0.1))
        .filter(lambda b: b["safety_level"] >= 3, name="safe_enough")
        .project("safety_level", "religious_population")
        .tee(monitor, name="monitor")
        .store())

# compile-time validation: missing ref tables, dtype mismatches, stages
# after sinks, unknown projected columns, partitions outside elastic
# bounds -> PlanError HERE, not mid-feed
feed = mgr.submit(plan)
stats = feed.join()

stored_cols = sorted(next(iter(feed.storage.scan())))
builds = {name: s.state_builds
          for name, s in stats.computing.per_stage.items()}
print(f"ingested={stats.records_in} stored={stats.stored} "
      f"(filter dropped {stats.records_in - stats.stored})")
print(f"sink deliveries={stats.sink_batches} tee_rows={tee_rows[0]}")
print(f"stored columns={stored_cols}")
print(f"stage groups={[g.name for g in feed.plan.stage_groups]} "
      f"(per-stage state_builds={builds})")
print(f"elasticity: peak_partitions={stats.peak_partitions} "
      f"scale_ups={stats.scale_ups} scale_downs={stats.scale_downs} "
      f"worker_seconds={stats.worker_seconds:.2f} "
      f"p95_backlog={stats.backlog_p95_rows:.0f} rows")
print(f"throughput={stats.records_per_s:,.0f} records/s "
      f"compiles={stats.predeploy['compiles']}")
assert stats.stored == tee_rows[0]          # both sinks saw the same rows
assert stored_cols == ["id", "religious_population", "safety_level",
                       "valid"]

# 4. progressive re-enrichment: `.store(refresh=RepairSpec(...))` attaches
#    a background repair job.  Rows already in the column store record the
#    reference versions they were enriched under; upserting a RefTable
#    mid-feed makes those rows stale, and the repair scheduler re-runs the
#    plan's enrich stages over exactly the affected rows (dirty-key probe)
#    in ingestion's idle gaps — join() drains it to convergence, so the
#    store below is guaranteed current against the FINAL table state.
#    `compact=CompactionSpec(...)` additionally attaches a budgeted
#    background compactor that reclaims the superseded row versions
#    upserts and repair leave behind (zone maps — per-segment min/max for
#    the query pruning below — are on by default at flush).
repair_plan = (pipeline(SyntheticAdapter(total=10_000, frame_size=420,
                                         seed=2, rate=40_000.0),
                        "RepairDemo")
               .parse(batch_size=420)
               .options(num_partitions=1)
               .enrich(Q.Q1)
               .store(refresh=RepairSpec(budget_rows_s=10_000),
                      compact=CompactionSpec(budget_rows_s=100_000)))
feed2 = mgr.submit(repair_plan)
time.sleep(0.1)                             # some rows land, then go stale
table = store["safety_levels"]
hot_keys = np.arange(50, dtype=np.int64)    # re-rate 50 existing countries
table.upsert(hot_keys, safety_level=np.full(50, 4, np.int32))
stats2 = feed2.join()
r = stats2.repair
print(f"\nrepair: stored={stats2.stored} stale={stats2.stale_rows} "
      f"repaired={stats2.repaired_rows} refined={r.refined_rows} "
      f"lag p50/p95={stats2.repair_lag_p50_s:.3f}/"
      f"{stats2.repair_lag_p95_s:.3f}s invocations={r.repair_invocations}")
snap = table.snapshot()
levels = {int(k): int(v) for k, v in
          zip(snap.arrays["key"][:snap.size],
              snap.arrays["safety_level"][:snap.size])}
rows = {}                                   # latest row version wins (the
for chunk in feed2.storage.scan():          # pk index resolves the same)
    for i in range(chunk["id"].shape[0]):
        rows[int(chunk["id"][i])] = (int(chunk["country"][i]),
                                     int(chunk["safety_level"][i]))
assert len(rows) == 10_000
for country, lvl in rows.values():          # every live row is current
    assert lvl == levels.get(country, -1)
print("repair: store converged to the post-upsert reference snapshot")

# 5. analytical queries over the enriched store (core/query.py) — the
#    paper's point: enrichments are computed AT ingestion so they can be
#    queried WITH the data.  The query runs on a pinned snapshot
#    (consistent even mid-ingestion), prunes segments whose zone maps
#    prove the predicate can't match, and routes the group-by through the
#    same kernel-dispatch layer the enrichment UDFs use.
res = (feed2.query()
       .where(col("safety_level") >= 3)     # only well-rated countries
       .group_by("safety_level")
       .agg(n=agg.count(),
            top=agg.topk("created_at", k=2, payload="id"))
       .execute())
naive = {}
for country, lvl in rows.values():
    if lvl >= 3:
        naive[lvl] = naive.get(lvl, 0) + 1
assert res["safety_level"].tolist() == sorted(naive)
assert res["n"].tolist() == [naive[k] for k in sorted(naive)]
print(f"query: groups={res['safety_level'].tolist()} "
      f"counts={res['n'].tolist()} "
      f"(newest-2 tweet ids per level: {res['top'].tolist()}) "
      f"rows_scanned={res.stats.rows_scanned} in "
      f"{1e3 * res.stats.wall_s:.1f}ms")

# reclaim the superseded versions repair left behind, then re-query:
# identical answer over fewer row versions
dropped = feed2.storage.compact()
res2 = (feed2.query().where(col("safety_level") >= 3)
        .group_by("safety_level").agg(n=agg.count()).execute())
assert res2["n"].tolist() == res["n"].tolist()
assert feed2.storage.dead_rows == 0
print(f"compaction: reclaimed {dropped} superseded row versions "
      f"(scan now touches {res2.stats.rows_scanned} rows)")

# 6. leveled segment merging: a spilled store flushes at ingestion
#    granularity (many small segments), and `compact=CompactionSpec(
#    level_target_rows=...)` makes the background compactor fold
#    contiguous runs of small segments into one next-level segment —
#    re-sorted on sort_key, zone maps rebuilt — so per-unit scan overhead
#    shrinks as data ages.  `merge_now()` runs the same policy
#    synchronously.  Queries are answered identically before and after
#    (asserted below); they also default to BATCHED aggregation: all
#    surviving units concatenate into one dispatch per aggregate.
work = tempfile.mkdtemp(prefix="quickstart_store_")
try:
    merge_plan = (pipeline(SyntheticAdapter(total=10_000, frame_size=420,
                                            seed=3), "MergeDemo")
                  .parse(batch_size=420)
                  .options(num_partitions=1)
                  .enrich(Q.Q1)
                  .store(spill_dir=work, segment_rows=500,
                         sort_key="country",
                         compact=CompactionSpec(budget_rows_s=100_000,
                                                merge_fanin=8,
                                                level_target_rows=8_000)))
    feed3 = mgr.submit(merge_plan)
    feed3.join()
    feed3.storage.flush()
    q3 = (feed3.query().where(col("safety_level") >= 3)
          .group_by("safety_level")
          .agg(n=agg.count(), s=agg.sum("created_at")))
    pre = q3.execute()
    segs_before = feed3.storage.segment_count
    hist_before = feed3.storage.level_histogram()
    feed3.compaction.merge_now(min_run=2)
    segs_after = feed3.storage.segment_count
    hist_after = feed3.storage.level_histogram()
    post = q3.execute()
    for k in pre:                   # merging never changes an answer
        np.testing.assert_array_equal(pre[k], post[k])
    assert segs_after < segs_before
    print(f"merge: {segs_before} segments {dict(sorted(hist_before.items()))}"
          f" -> {segs_after} {dict(sorted(hist_after.items()))}; "
          f"query units {pre.stats.units} -> {post.stats.units}, "
          f"answers identical")
    print(f"batched agg: {post.stats.agg_batched_units} units in "
          f"{post.stats.agg_invocations} dispatches "
          f"(kernel={post.stats.agg_kernel_dispatches} "
          f"fallback={post.stats.agg_fallback_dispatches} "
          f"64bit={post.stats.agg_64bit_fallbacks})")
finally:
    shutil.rmtree(work, ignore_errors=True)

# 7. observability (core/obs, docs/OBSERVABILITY.md): metrics are always
#    on — `metrics()` is a live snapshot of every feed number, uniformly
#    named, and `metrics_text()` the Prometheus exposition.  Tracing is
#    opt-in per plan: `.options(trace=...)` stamps every batch with span
#    ids that ride intake -> worker -> store like WAL seqs, so one
#    batch's journey reconstructs from `drain_trace()`.
obs_plan = (pipeline(SyntheticAdapter(total=5_000, frame_size=420, seed=4),
                     "ObsDemo")
            .parse(batch_size=420)
            .options(num_partitions=1, trace=True)
            .enrich(Q.Q1)
            .store())
feed4 = mgr.submit(obs_plan)
feed4.join()
m = feed4.metrics()
lat = m["ingest_visible_latency_s"]
print(f"\nobs: stored={m['feed_stored']} "
      f"visible-latency p50/p95="
      f"{lat.percentile(0.5) * 1e3:.1f}/{lat.percentile(0.95) * 1e3:.1f}ms "
      f"({lat.count} batches) backlog_p95={m['feed_backlog_p95_rows']:.0f}")
excerpt = [ln for ln in feed4.metrics_text().splitlines()
           if ln.startswith(("feed_stored ", "ingest_visible_latency_s_c",
                             "store_rows "))]
print("obs: exposition excerpt:", "; ".join(excerpt))
spans = feed4.drain_trace()
names = sorted({s["name"] for s in spans})
sid = next(i for s in spans if s["name"] == "intake.draw"
           for i in s["spans"])
journey = [s["name"] for s in spans if sid in s["spans"]]
print(f"obs: {len(spans)} spans, taxonomy={names}")
print(f"obs: span {sid} journey: {' -> '.join(journey)}")
assert {"intake.draw", "store.append"} <= set(journey)
