"""Declarative plan-API quickstart: chained enrichment, filter, projection,
multi-sink fan-out, and per-stage elasticity in one ingestion pass.

The SQL++ this models (paper Figures 8/12, extended):

    CREATE FEED TweetFeed;
    CONNECT FEED TweetFeed TO DATASET EnrichedTweets
        APPLY FUNCTION safetyLevel, religiousPopulation   -- chained UDFs
        WHERE safety_level >= 3                           -- filter
        SELECT safety_level, religious_population;        -- project
    -- plus a second consumer of the same enriched stream (tee)

Elasticity (core/elasticity.py): ``.enrich(udf, partitions=..., elastic=
ElasticSpec(...))`` makes that stage its own **stage group** — its own
holder + worker pool, scaled between min/max partitions by a backlog-
sampling controller, independently of the rest of the chain.  A feed-wide
default goes on ``options(elastic=...)``.

Run:  PYTHONPATH=src python examples/pipeline_quickstart.py

(examples/quickstart.py shows the pre-plan FeedConfig shim.)
"""

import threading

import numpy as np

from repro.core import (ElasticSpec, FeedManager, RefStore,
                        SyntheticAdapter, pipeline)
from repro.core.enrich import queries as Q

# 1. reference data at (scaled-down) paper cardinalities
store = RefStore()
Q.make_reference_tables(store, scale=0.01, seed=7)
mgr = FeedManager(store)

# 2. a tee sink: a live consumer of the enriched stream (the LM data plane
#    in train/data_feed.py is exactly this, feeding a trainer)
lock = threading.Lock()
tee_rows = [0]


def monitor(batch):
    with lock:
        tee_rows[0] += int(batch["valid"].sum())


# 3. the declarative plan: parse -> Q1 (cheap probe, static) -> Q2 + filter
#    (its own stage group: declared partitions + elastic bounds, so the
#    controller scales THIS stage's workers with its backlog while Q1's
#    pool stays put) -> project -> fan out to the monitor AND the column
#    store, exactly once each.  Stages without their own declaration fuse
#    into the preceding group (the filter rides with Q2's workers).
plan = (pipeline(SyntheticAdapter(total=20_000, frame_size=420, seed=1),
                 "TweetPipeline")
        .parse(batch_size=420)
        .options(num_partitions=1)
        .enrich(Q.Q1)
        .enrich(Q.Q2, partitions=1,
                elastic=ElasticSpec(min_partitions=1, max_partitions=2,
                                    interval_s=0.02, up_after=1,
                                    cooldown_s=0.1))
        .filter(lambda b: b["safety_level"] >= 3, name="safe_enough")
        .project("safety_level", "religious_population")
        .tee(monitor, name="monitor")
        .store())

# compile-time validation: missing ref tables, dtype mismatches, stages
# after sinks, unknown projected columns, partitions outside elastic
# bounds -> PlanError HERE, not mid-feed
feed = mgr.submit(plan)
stats = feed.join()

stored_cols = sorted(next(iter(feed.storage.scan())))
builds = {name: s.state_builds
          for name, s in stats.computing.per_stage.items()}
print(f"ingested={stats.records_in} stored={stats.stored} "
      f"(filter dropped {stats.records_in - stats.stored})")
print(f"sink deliveries={stats.sink_batches} tee_rows={tee_rows[0]}")
print(f"stored columns={stored_cols}")
print(f"stage groups={[g.name for g in feed.plan.stage_groups]} "
      f"(per-stage state_builds={builds})")
print(f"elasticity: peak_partitions={stats.peak_partitions} "
      f"scale_ups={stats.scale_ups} scale_downs={stats.scale_downs} "
      f"worker_seconds={stats.worker_seconds:.2f} "
      f"p95_backlog={stats.backlog_p95_rows:.0f} rows")
print(f"throughput={stats.records_per_s:,.0f} records/s "
      f"compiles={stats.predeploy['compiles']}")
assert stats.stored == tee_rows[0]          # both sinks saw the same rows
assert stored_cols == ["id", "religious_population", "safety_level",
                       "valid"]
