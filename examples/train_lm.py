"""End-to-end training driver: the IDEA ingestion pipeline feeding an LM.

The feed's computing jobs run a chained UDF (safety filter + tokenize) over
the incoming stream; a packer assembles dense batches; the Trainer runs
AdamW with async checkpointing and fault-tolerant resume.  Mid-run, the
SensitiveWords lexicon is UPSERTed — from that batch on, newly-flagged
records stop entering the training stream, with zero recompilation: the
paper's Model-2 freshness, doing adaptive data curation for training.

Default is a CPU-sized config; ``--arch mamba2-130m --steps 300`` is the
real ~130M-parameter run (use a TPU host or be patient).

Run:  PYTHONPATH=src python examples/train_lm.py [--arch ID] [--steps N]
"""

import argparse

import numpy as np

from repro.configs import get_config, smoke_config
from repro.core import FeedManager, RefStore
from repro.core.enrich import queries as Q
from repro.core.records import hash64
from repro.train import OptConfig
from repro.train.data_feed import FeedDataSource
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-coder-33b")
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--full-size", action="store_true",
                    help="use the real config (default: reduced smoke)")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = (get_config(args.arch) if args.full_size
           else smoke_config(args.arch))
    print(f"arch={cfg.name} family={cfg.family} "
          f"params~{cfg.param_count() / 1e6:.1f}M")

    store = RefStore()
    Q.make_reference_tables(store, scale=0.002, seed=7)
    mgr = FeedManager(store)
    source = FeedDataSource(mgr, vocab_size=cfg.vocab_size,
                            seq_len=args.seq_len, batch_size=args.batch,
                            total_records=500_000, frame_size=256,
                            safety_filter=True, num_partitions=2)

    trainer = Trainer(
        cfg,
        OptConfig(lr=3e-4, warmup_steps=10, total_steps=args.steps),
        TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=max(args.steps // 2, 1), log_every=1))

    # mid-run lexicon update: adaptive curation through reference data
    store["sensitive_words"].upsert(
        np.array([hash64("curation-demo")], np.int64),
        country=np.array([3], np.int32),
        word=np.array([hash64("w42")], np.int64))

    history = trainer.run(iter(source))
    source.stop()
    for h in history:
        print(f"step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.3f}  lr {h['lr']:.2e}")
    print(f"filtered-by-safety-UDF records: {source.filtered}")
    assert history and np.isfinite(history[-1]["loss"])


if __name__ == "__main__":
    main()
