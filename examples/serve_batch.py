"""Batched serving example: continuous-batching engine over any assigned
arch (reduced config on CPU).  Requests arrive in waves; finished slots
refill between decode steps, so decode utilization never drains.

Run:  PYTHONPATH=src python examples/serve_batch.py [--arch ID]
"""

import argparse

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models import api
from repro.serve import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-1b-7b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=10)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    params = api.init_params(cfg, jax.random.key(0))
    engine = ServingEngine(cfg, params, slots=args.slots, max_len=128)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(16, cfg.vocab_size, 8).tolist()
        engine.submit(Request(prompt, max_new_tokens=12,
                              stop_at_eos=False))

    done = engine.run()
    assert len(done) == args.requests
    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid:2d}: +{len(r.tokens)} tokens "
              f"{r.tokens[:6]}...")
    print(f"\n{args.requests} requests on {args.slots} slots: "
          f"{engine.decode_steps} decode steps, {engine.prefills} "
          f"prefills (continuous batching: "
          f"{args.requests * 12 / max(engine.decode_steps, 1):.1f} "
          f"tokens/step vs {args.slots} ideal)")


if __name__ == "__main__":
    main()
