"""Model 1 vs Model 2 vs Model 3 (§5.3): why the paper's batched computing
model exists.

Scenario: tweets stream in while an analyst UPSERTs new rows into the
ReligiousPopulations reference dataset.  We enrich the same stream under
each computing model and show what each one sees — Model 3 (today's
AsterixDB streaming evaluation) serves stale enrichments forever; Model 2
(this paper) picks the update up at the next batch; the version-gated
variant does the same with far fewer state rebuilds.

Run:  PYTHONPATH=src python examples/enrichment_freshness.py
"""

import numpy as np

from repro.core import ComputingRunner, ComputingSpec, RefStore
from repro.core.enrich import queries as Q
from repro.core.records import empty_batch


def tweet_batch(country: int, n: int = 8):
    b = empty_batch(n)
    b["id"][:] = np.arange(n)
    b["country"][:] = country
    b["valid"][:] = True
    return b


store = RefStore()
t = store.create("religious_populations", 64,
                 {"country": np.int32, "religion": np.int32,
                  "population": np.int32})
t.upsert(np.array([0], np.int64), country=np.array([7], np.int32),
         religion=np.array([1], np.int32),
         population=np.array([1000], np.int32))

runners = {
    "model1_per_record": ComputingRunner(
        ComputingSpec(Q.Q2, 8, "per_record"), store),
    "model2_per_batch": ComputingRunner(
        ComputingSpec(Q.Q2, 8, "per_batch", "always"), store),
    "model2_version_gated": ComputingRunner(
        ComputingSpec(Q.Q2, 8, "per_batch", "version"), store),
    "model3_stream": ComputingRunner(
        ComputingSpec(Q.Q2, 8, "stream"), store),
}

print("batch 1 (population of country 7 = 1000):")
for name, r in runners.items():
    out = r.run(tweet_batch(7))
    print(f"  {name:22s} -> {int(out['religious_population'][0])}")

print("\n>> UPSERT: +5000 believers in country 7 (mid-ingestion)\n")
t.upsert(np.array([1], np.int64), country=np.array([7], np.int32),
         religion=np.array([2], np.int32),
         population=np.array([5000], np.int32))

print("batch 2 (true value now 6000):")
for name, r in runners.items():
    out = r.run(tweet_batch(7))
    seen = int(out["religious_population"][0])
    verdict = "FRESH" if seen == 6000 else "STALE"
    print(f"  {name:22s} -> {seen}  [{verdict}]  "
          f"state_builds={r.stats.state_builds}")

m2 = runners["model2_per_batch"]
gated = runners["model2_version_gated"]
assert int(m2.stats.state_builds) == 2           # rebuilt every batch
assert int(gated.stats.state_builds) == 2        # rebuilt only on change
for _ in range(3):                               # quiet batches
    m2.run(tweet_batch(7))
    gated.run(tweet_batch(7))
print(f"\nafter 3 quiet batches: paper-faithful Model 2 built state "
      f"{m2.stats.state_builds}x, version-gated {gated.stats.state_builds}x "
      f"(beyond-paper optimization)")
