"""Quickstart: the paper's running example, end to end.

Equivalent of the paper's DDL (Figures 1, 4, 8, 12):

    CREATE DATASET Tweets(TweetType);
    CREATE FUNCTION tweetSafetyCheck(t) { ... SensitiveWords join ... };
    CREATE FEED TweetFeed; CONNECT FEED TweetFeed TO DATASET EnrichedTweets
        APPLY FUNCTION tweetSafetyCheck;
    START FEED TweetFeed;

Run:  PYTHONPATH=src python examples/quickstart.py

(One UDF, one sink — the smallest plan.  Chained UDFs, filters,
projection, multi-sink fan-out, repair, and the analytical query API are
examples/pipeline_quickstart.py.)
"""

import numpy as np

from repro.core import FeedManager, RefStore, SyntheticAdapter, \
    col, pipeline
from repro.core.enrich import queries as Q
from repro.core.records import hash64

# 1. reference data: the SensitiveWords dataset (UPSERT-able during
#    ingestion — that's the point of the paper)
store = RefStore()
sw = store.create("sensitive_words", capacity=1024,
                  schema={"country": np.int32, "word": np.int64})
sw.upsert(np.array([0], np.int64),
          country=np.array([Q.US_CODE], np.int32),
          word=np.array([hash64("bomb")], np.int64))

# 2. create + start the feed with the enrichment UDF attached
mgr = FeedManager(store)
feed = mgr.submit(
    pipeline(SyntheticAdapter(total=10_000, frame_size=420), "TweetFeed")
    .parse(batch_size=420)
    .options(num_partitions=2)
    .enrich(Q.UDF2)
    .store())

# 3. mid-ingestion UPSERT: add a new sensitive keyword for country 3.
#    Batches picked up after this point see it immediately (Model 2);
#    no recompilation happens (parameterized predeployed job).
sw.upsert(np.array([1], np.int64),
          country=np.array([3], np.int32),
          word=np.array([hash64("storm")], np.int64))

stats = feed.join()

# 4. analytical query over the enriched dataset (core/query.py):
#    SELECT count(*) FROM EnrichedTweets WHERE safety_check_flag = "Red"
red = feed.query().where(col("safety_check_flag") != 0) \
    .select("id").execute().rows

print(f"ingested={stats.records_in} stored={stats.stored} "
      f"red_flagged={red}")
print(f"throughput={stats.records_per_s:,.0f} records/s  "
      f"computing jobs={stats.computing.invocations}  "
      f"compiles={stats.predeploy['compiles']} (predeployed: compiled "
      f"once, invoked per batch)")
assert stats.stored == 10_000
