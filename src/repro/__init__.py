"""repro — a production-grade JAX reproduction of "An IDEA: An Ingestion
Framework for Data Enrichment in AsterixDB" (Wang & Carey, PVLDB 2019).

64-bit mode is enabled package-wide: the enrichment data plane joins on
int64 primary keys / hashes (records.hash64, refdata.KEY_SENTINEL).  All
model code is dtype-explicit (bf16/f32/int32), so enabling x64 does not
change model numerics; the dry-run additionally asserts no f64 appears in
lowered HLO.
"""

import jax

jax.config.update("jax_enable_x64", True)
