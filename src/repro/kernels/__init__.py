"""Custom-kernel registry + the global kernel-dispatch policy.

Kernels live in subpackages (<name>/kernel.py + ops.py + ref.py); add one
ONLY for compute hot-spots the paper itself optimizes.  This module owns the
*policy* every ops.py wrapper consults when its ``use_pallas`` argument is
left as None:

  mode "auto"       Pallas on TPU, reference elsewhere (the default: CPU
                    interpret-mode Pallas is an emulator, orders of
                    magnitude slower than the jnp reference paths)
  mode "pallas"     always the Pallas kernel (interpret mode off-TPU) —
                    what the equivalence tests and --dispatch pallas
                    benchmarks force
  mode "reference"  always the pure-jnp oracle

The initial mode comes from ``REPRO_KERNEL_DISPATCH`` so subprocess runs
(benchmarks, dry-runs) inherit the choice without plumbing.  The
higher-level enrichment router (core/enrich/dispatch.py) layers batch-size
thresholds and shape bucketing on top of this backend policy.
"""

from __future__ import annotations

import contextlib
import os
import threading

import jax

DISPATCH_MODES = ("auto", "pallas", "reference")

# process-global (NOT thread-local): feed computing workers are threads and
# must see the mode the driver set
_policy_lock = threading.Lock()
_policy_mode: str | None = None


def _default_mode() -> str:
    mode = os.environ.get("REPRO_KERNEL_DISPATCH", "auto")
    return mode if mode in DISPATCH_MODES else "auto"


def get_dispatch_mode() -> str:
    with _policy_lock:
        return _policy_mode or _default_mode()


def set_dispatch_mode(mode: str) -> None:
    global _policy_mode
    if mode not in DISPATCH_MODES:
        raise ValueError(f"dispatch mode {mode!r} not in {DISPATCH_MODES}")
    with _policy_lock:
        _policy_mode = mode


@contextlib.contextmanager
def dispatch_mode(mode: str):
    """Scoped override, e.g. ``with dispatch_mode("pallas"): ...``.
    Process-wide, like set_dispatch_mode."""
    global _policy_mode
    with _policy_lock:
        prev = _policy_mode
    set_dispatch_mode(mode)
    try:
        yield
    finally:
        with _policy_lock:
            _policy_mode = prev


def resolve_use_pallas(use_pallas: bool | None) -> bool:
    """Resolve an ops.py wrapper's ``use_pallas=None`` against the policy."""
    if use_pallas is not None:
        return use_pallas
    mode = get_dispatch_mode()
    if mode == "pallas":
        return True
    if mode == "reference":
        return False
    return jax.default_backend() == "tpu"


def auto_interpret(interpret: bool | None) -> bool:
    """Off-TPU there is no Mosaic backend: run kernels interpreted."""
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"
