"""Jit'd public wrapper for flash attention."""

from __future__ import annotations

import jax

from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, use_pallas: bool = True,
                    interpret: bool | None = None) -> jax.Array:
    if not use_pallas:
        return ref.flash_attention(q, k, v, causal)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return flash_attention_pallas(q, k, v, causal, interpret=interpret)
