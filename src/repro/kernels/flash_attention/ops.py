"""Jit'd public wrapper for flash attention."""

from __future__ import annotations

import jax

from repro.kernels import auto_interpret, resolve_use_pallas
from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, use_pallas: bool | None = None,
                    interpret: bool | None = None) -> jax.Array:
    """``use_pallas=None`` defers to the global dispatch policy
    (repro.kernels.get_dispatch_mode)."""
    if not resolve_use_pallas(use_pallas):
        return ref.flash_attention(q, k, v, causal)
    return flash_attention_pallas(q, k, v, causal,
                                  interpret=auto_interpret(interpret))
