"""Pure-jnp oracle: exact softmax GQA attention (fp32 accumulation)."""

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True) -> jax.Array:
    """q: (B, S, H, D); k/v: (B, T, Kv, D); H = Kv * G.  Returns (B,S,H,D)."""
    b, s, h, d = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * (d ** -0.5)
    if causal:
        mask = jnp.arange(s)[:, None] >= jnp.arange(t)[None, :]
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(b, s, h, d)
