"""Causal GQA flash attention for TPU (the train/prefill compute hot spot of
every assigned LM architecture).

Standard online-softmax blocking, adapted to the TPU grid model: the grid is
(batch, q-heads, q-blocks, kv-blocks) with the kv dimension innermost and
'arbitrary' (sequential), so the running (m, l, acc) statistics live in VMEM
scratch and survive across kv steps; the output block is written once, on
the final kv step.  GQA is expressed entirely through the k/v BlockSpec
index maps (query head h reads kv head h // G) — no head-replicated copies
of K/V ever materialize, which is the main memory win over the XLA path at
long context.

Causality is exploited at block granularity: fully-masked kv blocks are
skipped via pl.when (a real TPU win — upper-triangle blocks cost zero), and
the diagonal blocks apply the element mask.

Block shapes default to (128 q x 512 kv) x head_dim, sized so q/k/v tiles +
scratch stay well under VMEM (~2 MB at D=128) and every matmul dim is a
multiple of the 128-lane MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed pltpu.TPUCompilerParams -> pltpu.CompilerParams after 0.4.x
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams",
                           getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_q: int, block_k: int, causal: bool):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = (not causal) or (ik * block_k <= iq * block_q + block_q - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0]                                   # (bq, d)
        k = k_ref[0, 0]                                   # (bk, d)
        v = v_ref[0, 0]
        s = jnp.dot(q, k.T,
                    preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 0)
            kpos = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jnp.dot(p.astype(v.dtype), v,
                                  preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True,
                           block_q: int = 128, block_k: int = 512,
                           interpret: bool = False) -> jax.Array:
    """q: (B, S, H, D); k/v: (B, T, Kv, D) with H = Kv * G.
    Returns (B, S, H, D) in q.dtype."""
    b, s, h, d = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    assert h == kv * g, (h, kv)
    scale = d ** -0.5

    block_q = min(block_q, _round_up(s, 128))
    block_k = min(block_k, _round_up(t, 128))
    s_pad = _round_up(s, block_q)
    t_pad = _round_up(t, block_k)
    d_pad = _round_up(d, 128)

    # (B, H, S, D) layout; zero-pad S/T/D (padded kv columns are masked by
    # causality for the padded q rows only — guard with an explicit big-neg
    # score via position masks when padding T)
    qx = jnp.moveaxis(q, 2, 1)
    kx = jnp.moveaxis(k, 2, 1)
    vx = jnp.moveaxis(v, 2, 1)
    qx = jnp.pad(qx, ((0, 0), (0, 0), (0, s_pad - s), (0, d_pad - d)))
    kx = jnp.pad(kx, ((0, 0), (0, 0), (0, t_pad - t), (0, d_pad - d)))
    vx = jnp.pad(vx, ((0, 0), (0, 0), (0, t_pad - t), (0, d_pad - d)))
    if t_pad != t:
        # padded keys sit at positions >= t; with causality and s <= t every
        # real query (qpos < s <= t <= kpos) masks them out.  Non-causal
        # callers must pre-align T to the kv block.
        assert causal and s <= t, "T padding requires causal and s <= t"

    grid = (b, h, s_pad // block_q, t_pad // block_k)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d_pad),
                         lambda bb, hh, iq, ik: (bb, hh, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d_pad),
                         lambda bb, hh, iq, ik, g=g: (bb, hh // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d_pad),
                         lambda bb, hh, iq, ik, g=g: (bb, hh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d_pad),
                               lambda bb, hh, iq, ik: (bb, hh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s_pad, d_pad), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d_pad), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qx, kx, vx)

    out = out[:, :, :s, :d]
    return jnp.moveaxis(out, 1, 2)
