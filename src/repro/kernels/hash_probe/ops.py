"""Jit'd public wrapper for the equi-join probe."""

from __future__ import annotations

import jax

from repro.kernels.hash_probe import ref
from repro.kernels.hash_probe.kernel import sorted_probe_pallas


def sorted_probe(probe: jax.Array, ref_keys: jax.Array,
                 use_pallas: bool = True, interpret: bool | None = None):
    if not use_pallas:
        return ref.sorted_probe(probe, ref_keys)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return sorted_probe_pallas(probe, ref_keys, interpret=interpret)
