"""Jit'd public wrapper for the equi-join probe."""

from __future__ import annotations

import jax

from repro.kernels import auto_interpret, resolve_use_pallas
from repro.kernels.hash_probe import ref
from repro.kernels.hash_probe.kernel import sorted_probe_pallas


def sorted_probe(probe: jax.Array, ref_keys: jax.Array,
                 use_pallas: bool | None = None,
                 interpret: bool | None = None):
    """``use_pallas=None`` defers to the global dispatch policy
    (repro.kernels.get_dispatch_mode)."""
    if not resolve_use_pallas(use_pallas):
        return ref.sorted_probe(probe, ref_keys)
    return sorted_probe_pallas(probe, ref_keys,
                               interpret=auto_interpret(interpret))
