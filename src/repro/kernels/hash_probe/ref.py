"""Pure-jnp oracle for the equi-join probe kernel (searchsorted form —
identical semantics to core/enrich/ops.sorted_join)."""

import jax
import jax.numpy as jnp

from repro.core.refdata import KEY_SENTINEL


def sorted_probe(probe: jax.Array, ref_keys: jax.Array):
    """probe: (B,) int64; ref_keys: (R,) int64 ascending, sentinel-padded.
    Returns (idx (B,) int32 [-1 when absent], found (B,) bool)."""
    idx = jnp.searchsorted(ref_keys, probe)
    idx = jnp.minimum(idx, ref_keys.shape[0] - 1)
    found = (ref_keys[idx] == probe) & (probe != KEY_SENTINEL)
    return jnp.where(found, idx, -1).astype(jnp.int32), found
