"""Equi-join probe ("hash join" probe phase) as a streaming compare kernel.

The paper's hash join builds an in-memory hash table and probes it per
record (§5.3.4).  TPUs have no efficient pointer-chase, so the adaptation
(DESIGN.md §2) probes the *sorted* key column instead.  A GPU port would
binary-search; on TPU even binary search is awkward (vector gather across a
large VMEM array).  This kernel instead streams reference-key blocks
through VMEM and does a dense (bk x rk) equality compare per tile — O(B·R)
compares instead of O(B log R), but every op is a full-width VPU op with
zero irregular memory traffic, and R-blocks are shared across all probes in
the block.  For reference tables that fit VMEM (all of the paper's), one
pass suffices; the match index is recovered from an iota-min reduction.

Keys are int64 (primary keys / 63-bit hashes); the compare is done on the
(hi, lo) int32 halves since the TPU VPU has no native 64-bit lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BIG = 2**31 - 1  # python int: pallas kernels cannot capture array constants


def _split64(x: jax.Array):
    """int64 -> (hi, lo) int32 pair (TPU vectors are 32-bit)."""
    lo = (x & jnp.int64(0xFFFFFFFF)).astype(jnp.uint32).astype(jnp.int32)
    hi = (x >> jnp.int64(32)).astype(jnp.int32)
    return hi, lo


def _kernel(phi_ref, plo_ref, rhi_ref, rlo_ref, idx_ref, *, block_r: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        idx_ref[...] = jnp.full_like(idx_ref, _BIG)

    eq = ((phi_ref[...][:, None] == rhi_ref[...][None, :])
          & (plo_ref[...][:, None] == rlo_ref[...][None, :]))   # (bk, rk)
    r_base = j * block_r
    local = jax.lax.broadcasted_iota(jnp.int32, eq.shape, 1) + r_base
    hit = jnp.min(jnp.where(eq, local, _BIG), axis=1)
    idx_ref[...] = jnp.minimum(idx_ref[...], hit)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit,
                   static_argnames=("block_b", "block_r", "interpret"))
def sorted_probe_pallas(probe: jax.Array, ref_keys: jax.Array,
                        block_b: int = 512, block_r: int = 2048,
                        interpret: bool = False):
    """probe: (B,) int64; ref_keys: (R,) int64 (sentinel-padded; uniqueness
    assumed, as produced by RefTable.snapshot).  Returns (idx, found)."""
    from repro.core.refdata import KEY_SENTINEL

    b, r = probe.shape[0], ref_keys.shape[0]
    b_pad = _round_up(max(b, block_b), block_b)
    r_pad = _round_up(max(r, block_r), block_r)
    probe_p = jnp.pad(probe, (0, b_pad - b), constant_values=KEY_SENTINEL)
    # pad ref with sentinel-1 values: never equal to any probe (sentinel
    # probes must also miss, handled below)
    ref_p = jnp.pad(ref_keys, (0, r_pad - r),
                    constant_values=KEY_SENTINEL - 1)
    phi, plo = _split64(probe_p)
    rhi, rlo = _split64(ref_p)

    idx = pl.pallas_call(
        functools.partial(_kernel, block_r=block_r),
        grid=(b_pad // block_b, r_pad // block_r),
        in_specs=[
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
            pl.BlockSpec((block_r,), lambda i, j: (j,)),
            pl.BlockSpec((block_r,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((b_pad,), jnp.int32),
        interpret=interpret,
    )(phi, plo, rhi, rlo)

    idx = idx[:b]
    found = (idx != _BIG) & (probe != KEY_SENTINEL) & (idx < r)
    return jnp.where(found, idx, -1), found
