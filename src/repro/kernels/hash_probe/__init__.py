from repro.kernels.hash_probe.ops import sorted_probe  # noqa: F401
