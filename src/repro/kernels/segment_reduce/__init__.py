from repro.kernels.segment_reduce.ops import segment_sum  # noqa: F401
