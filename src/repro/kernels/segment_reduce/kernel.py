"""Group-by aggregation as a one-hot x matmul on the MXU.

GPU group-by kernels scatter with atomics; TPU has neither atomics nor
efficient random scatter.  The TPU-native formulation turns the irregular
reduction into a dense GEMM (DESIGN.md §2):

    out[s] = sum_r 1[seg_r == s] * v_r    =    onehot(seg)^T @ v

The kernel streams value/segment blocks through VMEM (grid over R); the
(S_pad,) accumulator lives in the output block, revisited every grid step
(dimension 0 is 'arbitrary', so the revisits are ordered).  The one-hot tile
is built in-register from a broadcasted iota compare — it never exists in
HBM, which is what makes this beat the XLA scatter lowering.

float32 values accumulate via the MXU matmul; int32 sums above 2^24 would
lose bits in f32, so the int path multiplies+reduces on the VPU in int32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(values_ref, seg_ref, out_ref, *, num_segments: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    vals = values_ref[...]                       # (rk,)
    seg = seg_ref[...]                           # (rk,) int32
    s_pad = out_ref.shape[0]
    onehot = (seg[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (vals.shape[0], s_pad), 1))
    if jnp.issubdtype(vals.dtype, jnp.floating):
        # (1, rk) @ (rk, S) vector-matrix product on the MXU
        acc = jnp.dot(vals[None, :], onehot.astype(vals.dtype),
                      preferred_element_type=jnp.float32)[0]
    else:
        # exact integer accumulation on the VPU
        acc = jnp.sum(jnp.where(onehot, vals[:, None], 0), axis=0)
    out_ref[...] += acc.astype(out_ref.dtype)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit,
                   static_argnames=("num_segments", "block_r", "interpret"))
def segment_sum_pallas(values: jax.Array, seg: jax.Array, num_segments: int,
                       block_r: int = 2048, interpret: bool = False
                       ) -> jax.Array:
    """values: (R,); seg: (R,) int32.  Rows with seg >= num_segments are
    dropped (padding convention shared with the oracle)."""
    r = values.shape[0]
    r_pad = _round_up(max(r, block_r), block_r)
    s_pad = _round_up(num_segments, 128)
    acc_dtype = (jnp.float32 if jnp.issubdtype(values.dtype, jnp.floating)
                 else jnp.int32)
    values = jnp.pad(values, (0, r_pad - r))
    # out-of-range segments (incl. padding) match no one-hot column
    seg = jnp.pad(seg.astype(jnp.int32), (0, r_pad - r),
                  constant_values=s_pad)

    out = pl.pallas_call(
        functools.partial(_kernel, num_segments=num_segments),
        grid=(r_pad // block_r,),
        in_specs=[
            pl.BlockSpec((block_r,), lambda i: (i,)),
            pl.BlockSpec((block_r,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((s_pad,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((s_pad,), acc_dtype),
        interpret=interpret,
    )(values, seg)
    return out[:num_segments].astype(values.dtype)
