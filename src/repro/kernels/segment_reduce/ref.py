"""Pure-jnp oracle for the segment-reduce kernel."""

import jax
import jax.numpy as jnp


def segment_sum(values: jax.Array, seg: jax.Array,
                num_segments: int) -> jax.Array:
    """values: (R,) float32/int32; seg: (R,) int32 in [0, num_segments)
    (rows with seg >= num_segments are dropped).  Returns (num_segments,)."""
    mask = seg < num_segments
    vals = jnp.where(mask, values, 0)
    return jax.ops.segment_sum(vals, jnp.where(mask, seg, 0),
                               num_segments=num_segments)
