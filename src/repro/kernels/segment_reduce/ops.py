"""Jit'd public wrapper: Pallas on TPU, interpret-mode Pallas (or the jnp
oracle) on CPU."""

from __future__ import annotations

import jax

from repro.kernels import auto_interpret, resolve_use_pallas
from repro.kernels.segment_reduce import ref
from repro.kernels.segment_reduce.kernel import segment_sum_pallas


def segment_sum(values: jax.Array, seg: jax.Array, num_segments: int,
                use_pallas: bool | None = None,
                interpret: bool | None = None) -> jax.Array:
    """Drop-in ``segment_sum``; ``use_pallas=None`` defers to the global
    dispatch policy, ``interpret=None`` auto-selects interpret mode off-TPU
    so the same call sites run everywhere."""
    if not resolve_use_pallas(use_pallas):
        return ref.segment_sum(values, seg, num_segments)
    return segment_sum_pallas(values, seg, num_segments,
                              interpret=auto_interpret(interpret))
