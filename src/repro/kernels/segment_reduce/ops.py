"""Jit'd public wrapper: Pallas on TPU, interpret-mode Pallas (or the jnp
oracle) on CPU."""

from __future__ import annotations

import jax

from repro.kernels.segment_reduce import ref
from repro.kernels.segment_reduce.kernel import segment_sum_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def segment_sum(values: jax.Array, seg: jax.Array, num_segments: int,
                use_pallas: bool = True, interpret: bool | None = None
                ) -> jax.Array:
    """Drop-in ``segment_sum``; ``interpret=None`` auto-selects interpret
    mode off-TPU so the same call sites run everywhere."""
    if not use_pallas:
        return ref.segment_sum(values, seg, num_segments)
    if interpret is None:
        interpret = not _on_tpu()
    return segment_sum_pallas(values, seg, num_segments,
                              interpret=interpret)
