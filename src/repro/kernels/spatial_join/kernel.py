"""Spatial radius join (the paper's dominant enrichment cost, Fig 25/26) as
a tiled distance kernel with an in-register streaming top-k.

Paper workload: "monuments within 1.5 degrees of the tweet" (Q4), "3 closest
religious buildings within 3 degrees" (Q5/Q7).  A CUDA version would bucket
by spatial grid and chase neighbor lists; the TPU adaptation (DESIGN.md §2)
computes dense (bk x rk) distance tiles — perfectly regular VPU work — and
maintains, per probe row, a running ascending top-k of (distance, index)
entirely in registers/VMEM across reference blocks:

  extract the tile's k minima one at a time (min + iota-argmin + mask),
  insert each into the sorted running list with a compare-shift — no sort
  primitive needed, so everything lowers to Mosaic-supported elementwise
  ops and reductions.

Outputs are revisited across the reference grid dimension (innermost,
'arbitrary' semantics), so the counts and top-k accumulate in place.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_IBIG = 2**31 - 1  # python int: pallas kernels cannot capture array constants


def _kernel(px_ref, py_ref, rx_ref, ry_ref, valid_ref,
            bestd_ref, besti_ref, count_ref, *,
            k: int, radius2: float, block_r: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        bestd_ref[...] = jnp.full_like(bestd_ref, jnp.inf)
        besti_ref[...] = jnp.full_like(besti_ref, -1)
        count_ref[...] = jnp.zeros_like(count_ref)

    px, py = px_ref[...], py_ref[...]                     # (bk,)
    rx, ry = rx_ref[...], ry_ref[...]                     # (rk,)
    ok = valid_ref[...] != 0                              # (rk,)

    dx = px[:, None] - rx[None, :]
    dy = py[:, None] - ry[None, :]
    d2 = jnp.where(ok[None, :], dx * dx + dy * dy, jnp.inf)   # (bk, rk)

    count_ref[...] += jnp.sum(d2 <= radius2, axis=1).astype(jnp.int32)

    bd, bi = bestd_ref[...], besti_ref[...]               # (bk, k) ascending
    bk_ = d2.shape[0]
    local = jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1) + j * block_r
    slot = jax.lax.broadcasted_iota(jnp.int32, (bk_, k), 1)
    work = d2
    for _ in range(k):
        m = jnp.min(work, axis=1)                         # (bk,)
        sel_i = jnp.min(jnp.where(work == m[:, None], local, _IBIG), axis=1)
        # remove exactly the selected entry from the tile
        work = jnp.where(local == sel_i[:, None], jnp.inf, work)
        # sorted insert: after any equal values (keeps lower-index-first)
        pos = jnp.sum((bd <= m[:, None]).astype(jnp.int32), axis=1)
        shift_d = jnp.concatenate([bd[:, :1], bd[:, :-1]], axis=1)
        shift_i = jnp.concatenate([bi[:, :1], bi[:, :-1]], axis=1)
        at = slot == pos[:, None]
        before = slot < pos[:, None]
        bd = jnp.where(before, bd, jnp.where(at, m[:, None], shift_d))
        bi = jnp.where(before, bi, jnp.where(at, sel_i[:, None], shift_i))
    bestd_ref[...] = bd
    besti_ref[...] = bi


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("radius", "k", "block_b",
                                             "block_r", "interpret"))
def radius_join_pallas(px: jax.Array, py: jax.Array,
                       rx: jax.Array, ry: jax.Array,
                       radius: float, k: int,
                       ref_valid: jax.Array | None = None,
                       block_b: int = 256, block_r: int = 1024,
                       interpret: bool = False):
    """Returns (idx (B,k) int32 [-1], dist2 (B,k) [inf], count (B,) int32)
    for reference points within ``radius``, nearest first."""
    b, r = px.shape[0], rx.shape[0]
    b_pad = _round_up(max(b, block_b), block_b)
    r_pad = _round_up(max(r, block_r), block_r)
    f32 = jnp.float32
    pxp = jnp.pad(px.astype(f32), (0, b_pad - b))
    pyp = jnp.pad(py.astype(f32), (0, b_pad - b))
    rxp = jnp.pad(rx.astype(f32), (0, r_pad - r))
    ryp = jnp.pad(ry.astype(f32), (0, r_pad - r))
    if ref_valid is None:
        valid = jnp.ones((r,), jnp.int32)
    else:
        valid = ref_valid.astype(jnp.int32)
    validp = jnp.pad(valid, (0, r_pad - r))               # padding invalid

    grid = (b_pad // block_b, r_pad // block_r)
    bestd, besti, count = pl.pallas_call(
        functools.partial(_kernel, k=k, radius2=float(radius) ** 2,
                          block_r=block_r),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
            pl.BlockSpec((block_r,), lambda i, j: (j,)),
            pl.BlockSpec((block_r,), lambda i, j: (j,)),
            pl.BlockSpec((block_r,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((b_pad, k), jnp.int32),
            jax.ShapeDtypeStruct((b_pad,), jnp.int32),
        ],
        interpret=interpret,
    )(pxp, pyp, rxp, ryp, validp)

    bestd, besti, count = bestd[:b], besti[:b], count[:b]
    inside = bestd <= float(radius) ** 2
    return (jnp.where(inside, besti, -1),
            jnp.where(inside, bestd, jnp.inf), count)
