from repro.kernels.spatial_join.ops import radius_join  # noqa: F401
