"""Jit'd public wrapper for the spatial radius join."""

from __future__ import annotations

import jax

from repro.kernels import auto_interpret, resolve_use_pallas
from repro.kernels.spatial_join import ref
from repro.kernels.spatial_join.kernel import radius_join_pallas


def radius_join(px: jax.Array, py: jax.Array, rx: jax.Array, ry: jax.Array,
                radius: float, k: int, ref_valid: jax.Array | None = None,
                use_pallas: bool | None = None,
                interpret: bool | None = None):
    """``use_pallas=None`` defers to the global dispatch policy
    (repro.kernels.get_dispatch_mode)."""
    if not resolve_use_pallas(use_pallas):
        return ref.radius_join(px, py, rx, ry, radius, k, ref_valid)
    return radius_join_pallas(px, py, rx, ry, radius, k, ref_valid,
                              interpret=auto_interpret(interpret))
