"""Jit'd public wrapper for the spatial radius join."""

from __future__ import annotations

import jax

from repro.kernels.spatial_join import ref
from repro.kernels.spatial_join.kernel import radius_join_pallas


def radius_join(px: jax.Array, py: jax.Array, rx: jax.Array, ry: jax.Array,
                radius: float, k: int, ref_valid: jax.Array | None = None,
                use_pallas: bool = True, interpret: bool | None = None):
    if not use_pallas:
        return ref.radius_join(px, py, rx, ry, radius, k, ref_valid)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return radius_join_pallas(px, py, rx, ry, radius, k, ref_valid,
                              interpret=interpret)
