"""Pure-jnp oracle for the spatial radius join kernel."""

import jax
import jax.numpy as jnp


def radius_join(px: jax.Array, py: jax.Array, rx: jax.Array, ry: jax.Array,
                radius: float, k: int, ref_valid: jax.Array | None = None):
    """All-pairs reference implementation.
    Returns (idx (B,k) int32 [-1 fill], dist2 (B,k) [inf fill], count (B,)).
    Results ordered by ascending distance; ties broken by lower index."""
    d2 = ((px[:, None] - rx[None, :]) ** 2
          + (py[:, None] - ry[None, :]) ** 2)
    if ref_valid is not None:
        d2 = jnp.where(ref_valid[None, :], d2, jnp.inf)
    r2 = jnp.float32(radius) ** 2
    count = jnp.sum(d2 <= r2, axis=1).astype(jnp.int32)
    kk = min(k, rx.shape[0])
    neg, idx = jax.lax.top_k(-d2, kk)
    dd = -neg
    if kk < k:
        idx = jnp.pad(idx, ((0, 0), (0, k - kk)), constant_values=-1)
        dd = jnp.pad(dd, ((0, 0), (0, k - kk)), constant_values=jnp.inf)
    ok = dd <= r2
    return (jnp.where(ok, idx, -1).astype(jnp.int32),
            jnp.where(ok, dd, jnp.inf), count)
