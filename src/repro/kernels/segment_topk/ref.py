"""Pure-jnp oracle for the per-segment top-k kernel: one composite-key
stable argsort (ascending segment, descending clipped value, ties by row)
— the same formulation as core/enrich/ops.py's ``_segment_topk_ref``,
kept standalone here so the kernel package stays self-contained."""

import jax
import jax.numpy as jnp


def segment_topk_idx(values: jax.Array, seg: jax.Array,
                     num_segments: int, k: int) -> jax.Array:
    """values: (R,) int (negatives rank as 0, like the kernel's clip);
    seg: (R,) int32, rows outside [0, num_segments) dropped.
    Returns (num_segments, k) int32 row indices, -1-filled."""
    r = values.shape[0]
    vmax = jnp.int64(1) << 31
    v = jnp.clip(values.astype(jnp.int64), 0, vmax - 1)
    segi = jnp.where((seg >= 0) & (seg < num_segments),
                     seg.astype(jnp.int64), num_segments)
    composite = segi * vmax + (vmax - 1 - v)   # asc seg, desc value
    order = jnp.argsort(composite)             # stable: ties by row asc
    sseg = segi[order]
    starts = jnp.searchsorted(sseg, jnp.arange(num_segments + 1,
                                               dtype=jnp.int64))
    pos = jnp.arange(r) - starts[jnp.clip(sseg, 0, num_segments)]
    keep = (pos < k) & (sseg < num_segments)
    slot = jnp.where(keep, sseg * k + pos, num_segments * k)
    out = jnp.full((num_segments * k + 1,), -1, jnp.int32)
    out = out.at[slot].set(
        jnp.where(keep, order, -1).astype(jnp.int32), mode="drop")
    return out[:-1].reshape(num_segments, k)
