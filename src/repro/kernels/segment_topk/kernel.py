"""Per-segment top-k selection as k tournament rounds on the VPU.

GPU top-k kernels sort per segment with shared-memory bitonic networks;
the TPU has neither scatter nor per-segment shared memory, but it eats
dense compare/reduce tiles — so the selection network runs over the SAME
in-register one-hot tile the segment-sum kernel uses (DESIGN.md §2):

    round j:  for every segment s, pick argmax_r {value_r : seg_r == s,
              r not selected in rounds < j}   (ties -> lowest row)

The grid is (k, R/block): the slow dimension is the round, the fast one
streams value/segment blocks through VMEM.  The (k_pad, S_pad) winner
tables (value + row index) live in the revisited output block; a round
reads the previous rounds' winner rows to mask them out — the per-row
"am I already taken" test reuses the one-hot tile as a gather
(``where(onehot, taken_row, -1)`` + a lane max), so nothing irregular
ever touches memory.  k rounds re-stream R rows: O(kR) work against the
reference's O(R log R) composite sort, but each pass is pure VPU
compare/max on data already in VMEM, and the k the workload cares about
(paper Q3: top-3; query topk: single digits) is tiny.

Selection order is deterministic and bit-identical to the reference
oracle's composite-key sort: values descend, ties break toward the lower
row (blocks revisit in ascending row order and merges are strictly
``>``), which is exactly stable-argsort order.

The kernel returns ROW INDICES (``(S, k)`` int32, -1-filled); the ops.py
wrapper gathers payload/value columns outside the kernel, so any payload
dtype works without touching kernel memory.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(values_ref, seg_ref, idx_ref, val_ref, *, k: int,
            block_r: int):
    j = pl.program_id(0)          # selection round (slow)
    # x64 mode: every dynamic-slice start must share one index dtype
    jd = j.astype(jnp.int64)
    i = pl.program_id(1)          # row block (fast)
    s_pad = idx_ref.shape[1]

    @pl.when(i == 0)
    def _init():                  # open round j with an empty winner row
        neg = jnp.full((1, s_pad), -1, jnp.int32)
        pl.store(idx_ref, (pl.ds(jd, 1), slice(None)), neg)
        pl.store(val_ref, (pl.ds(jd, 1), slice(None)), neg)

    vals = values_ref[...]                       # (block_r,) int32, >= 0
    seg = seg_ref[...]                           # (block_r,) int32
    onehot = (seg[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (block_r, s_pad), 1))
    row_mat = (jax.lax.broadcasted_iota(jnp.int32, (block_r, s_pad), 0)
               + i * block_r)
    rows = row_mat[:, 0]
    # mask rows already selected by earlier rounds: the winner row of
    # round jj for THIS row's segment, fetched through the one-hot tile
    taken = jnp.zeros((block_r,), jnp.bool_)
    for jj in range(k):
        prev = pl.load(idx_ref, (pl.ds(jj, 1), slice(None)))   # (1, s_pad)
        mine = jnp.max(jnp.where(onehot, prev, -1), axis=1)    # (block_r,)
        taken |= (mine == rows) & (jj < j)
    cand = jnp.where(taken, -1, vals)
    # per-segment argmax within the block (first max -> lowest row)
    tile = jnp.where(onehot, cand[:, None], -1)  # (block_r, s_pad)
    bmax = jnp.max(tile, axis=0)
    brow = jnp.argmax(tile, axis=0).astype(jnp.int32) + i * block_r
    accv = pl.load(val_ref, (pl.ds(jd, 1), slice(None)))[0]
    acci = pl.load(idx_ref, (pl.ds(jd, 1), slice(None)))[0]
    # blocks revisit in ascending row order, so strict > keeps the
    # earliest row on value ties — stable-sort order, like the oracle
    better = bmax > accv
    pl.store(val_ref, (pl.ds(jd, 1), slice(None)),
             jnp.where(better, bmax, accv)[None, :])
    pl.store(idx_ref, (pl.ds(jd, 1), slice(None)),
             jnp.where(better, brow, acci)[None, :])


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("num_segments", "k",
                                             "block_r", "interpret"))
def segment_topk_pallas(values: jax.Array, seg: jax.Array,
                        num_segments: int, k: int, block_r: int = 512,
                        interpret: bool = False) -> jax.Array:
    """values: (R,) int32 (negatives rank as 0, clipped here — the empty-
    winner sentinel is -1); seg: (R,) int32, rows with seg outside
    [0, num_segments) are dropped (the shared padding convention).
    Returns (num_segments, k) int32 row indices, -1 where the segment has
    fewer than k rows."""
    r = values.shape[0]
    r_pad = _round_up(max(r, block_r), block_r)
    s_pad = _round_up(max(num_segments, 1), 128)
    k_pad = _round_up(k, 8)
    values = jnp.pad(jnp.maximum(values.astype(jnp.int32), 0),
                     (0, r_pad - r))
    seg = jnp.pad(seg.astype(jnp.int32), (0, r_pad - r),
                  constant_values=s_pad)

    idx, _ = pl.pallas_call(
        functools.partial(_kernel, k=k, block_r=block_r),
        grid=(k, r_pad // block_r),
        in_specs=[
            pl.BlockSpec((block_r,), lambda j, i: (i,)),
            pl.BlockSpec((block_r,), lambda j, i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((k_pad, s_pad), lambda j, i: (0, 0)),
            pl.BlockSpec((k_pad, s_pad), lambda j, i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k_pad, s_pad), jnp.int32),   # winner rows
            jax.ShapeDtypeStruct((k_pad, s_pad), jnp.int32),   # winner vals
        ],
        interpret=interpret,
    )(values, seg)
    return idx[:k, :num_segments].T
