"""Jit'd public wrapper: Pallas on TPU, interpret-mode Pallas (or the jnp
oracle) on CPU."""

from __future__ import annotations

import jax

from repro.kernels import auto_interpret, resolve_use_pallas
from repro.kernels.segment_topk import ref
from repro.kernels.segment_topk.kernel import segment_topk_pallas


def segment_topk_idx(values: jax.Array, seg: jax.Array, num_segments: int,
                     k: int, use_pallas: bool | None = None,
                     interpret: bool | None = None) -> jax.Array:
    """Per-segment top-k selection INDICES ((S, k) int32 rows, -1-filled;
    value desc, ties by row asc — stable-sort order on both paths).
    ``use_pallas=None`` defers to the global dispatch policy."""
    if not resolve_use_pallas(use_pallas):
        return ref.segment_topk_idx(values, seg, num_segments, k)
    return segment_topk_pallas(values, seg, num_segments, k,
                               interpret=auto_interpret(interpret))
