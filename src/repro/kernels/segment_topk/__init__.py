from repro.kernels.segment_topk.ops import segment_topk_idx  # noqa: F401
