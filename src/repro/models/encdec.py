"""Whisper-style encoder/decoder LM (family "encdec").

The modality frontend (conv-over-mel stack) is a STUB per the assignment:
``input_specs()`` supplies precomputed frame embeddings (B, F, D) and the
encoder consumes them directly.  The decoder is a standard causal LM with a
cross-attention sub-layer per block; serving caches both the decoder
self-attention KV *and* the (fixed) encoder cross KV, so decode steps never
re-run the encoder.

Backbone substrate (RMSNorm, RoPE self-attention) is shared with the rest of
the model zoo — the assignment specifies the transformer backbone only; see
DESIGN.md §2 for the norm/positional-embedding adaptation notes.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T

Array = jax.Array


def enc_layer_specs(cfg: ModelConfig) -> Dict:
    return {
        "ln1": L.rmsnorm_spec(cfg.d_model),
        "attn": L.attention_specs(cfg),
        "ln2": L.rmsnorm_spec(cfg.d_model),
        "mlp": L.mlp_specs(cfg),
    }


def dec_layer_specs(cfg: ModelConfig) -> Dict:
    return {
        "ln1": L.rmsnorm_spec(cfg.d_model),
        "self_attn": L.attention_specs(cfg),
        "lnx": L.rmsnorm_spec(cfg.d_model),
        "cross_attn": L.cross_attention_specs(cfg),
        "ln2": L.rmsnorm_spec(cfg.d_model),
        "mlp": L.mlp_specs(cfg),
    }


def specs(cfg: ModelConfig) -> Dict:
    return {
        "embed": L.embedding_specs(cfg),
        "enc_norm": L.rmsnorm_spec(cfg.d_model),
        "enc_layers": T.stack_specs(enc_layer_specs(cfg), cfg.encoder_layers),
        "layers": T.stack_specs(dec_layer_specs(cfg), cfg.num_layers),
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def encode(cfg: ModelConfig, params: Dict, frames: Array) -> Array:
    """frames: (B, F, D) precomputed frame embeddings (frontend stub)."""
    b, f, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32), (b, f))
    x = frames.astype(jnp.dtype(cfg.dtype))

    def block(p, x):
        h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        x = x + L.attention(cfg, p["attn"], h, positions, causal=False)
        h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        return x + L.mlp(cfg, p["mlp"], h)

    body = T.remat_wrap(cfg, block)
    x, _ = jax.lax.scan(lambda c, lp: (body(lp, c), None),
                        x, params["enc_layers"])
    return L.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# decoder training
# ---------------------------------------------------------------------------

def _dec_block(cfg: ModelConfig, p: Dict, x: Array, enc: Array,
               positions: Array, segment_ids: Optional[Array]) -> Array:
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    x = x + L.attention(cfg, p["self_attn"], h, positions, segment_ids)
    h = L.rmsnorm(x, p["lnx"], cfg.norm_eps)
    xattn, _ = L.cross_attention(cfg, p["cross_attn"], h, enc)
    x = x + xattn
    h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    return x + L.mlp(cfg, p["mlp"], h)


def hidden_states(cfg: ModelConfig, params: Dict, batch: Dict
                  ) -> Tuple[Array, Array]:
    tokens = batch["tokens"]
    frames = batch.get("frontend")
    if frames is None:
        frames = jnp.zeros(
            (tokens.shape[0], cfg.num_frontend_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype))
    enc = encode(cfg, params, frames)

    b, s = tokens.shape
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    segment_ids = batch.get("segment_ids")

    body = T.remat_wrap(cfg, functools.partial(
        _dec_block, cfg, enc=enc, positions=positions,
        segment_ids=segment_ids))
    x, _ = jax.lax.scan(lambda c, lp: (body(lp, c), None),
                        x, params["layers"])
    x = L.rmsnorm(x, params["embed"]["norm_f"], cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


def apply(cfg: ModelConfig, params: Dict, batch: Dict) -> Tuple[Array, Array]:
    x, aux = hidden_states(cfg, params, batch)
    return L.unembed(cfg, params["embed"], x), aux


def loss(cfg: ModelConfig, params: Dict, batch: Dict,
         aux_weight: float = 0.0) -> Tuple[Array, Dict]:
    x, aux = hidden_states(cfg, params, batch)
    ce, denom = T.chunked_xent(cfg, params["embed"], x,
                               batch["targets"], batch.get("loss_mask"))
    return ce, {"loss": ce, "aux": aux, "tokens": denom}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params: Dict, tokens: Array,
            frontend: Optional[Array] = None) -> Tuple[Dict, Array]:
    """Encode frames, prefill the decoder, return (cache, last-token logits).
    Cache: self k/v (L,B,S,Kv,hd), cross k/v (L,B,F,Kv,hd), len (B,)."""
    b, s = tokens.shape
    if frontend is None:
        frontend = jnp.zeros((b, cfg.num_frontend_tokens, cfg.d_model),
                             jnp.dtype(cfg.dtype))
    enc = encode(cfg, params, frontend)
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(carry, lp):
        x = carry
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        a, kv = L.attention_prefill(cfg, lp["self_attn"], h, positions)
        x = x + a
        h = L.rmsnorm(x, lp["lnx"], cfg.norm_eps)
        xa, xkv = L.cross_attention(cfg, lp["cross_attn"], h, enc)
        x = x + xa
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.mlp(cfg, lp["mlp"], h)
        return x, (kv, xkv)

    x, ((k, v), (xk, xv)) = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(x, params["embed"]["norm_f"], cfg.norm_eps)
    logits = L.unembed(cfg, params["embed"], x[:, -1:])[:, 0]
    cache = {"k": k, "v": v, "xk": xk, "xv": xv,
             "len": jnp.full((b,), s, jnp.int32)}
    return cache, logits


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict,
                tokens: Array) -> Tuple[Array, Dict]:
    pos = cache["len"]
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.dtype))

    def body(carry, xs):
        lp, kc, vc, xk, xv = xs
        x = carry
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        a, kc, vc = L.attention_decode(cfg, lp["self_attn"], h, pos, kc, vc)
        x = x + a
        h = L.rmsnorm(x, lp["lnx"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h,
                       lp["cross_attn"]["wq"].astype(h.dtype))
        if cfg.qkv_bias:
            q = q + lp["cross_attn"]["bq"].astype(h.dtype)
        x = x + L.cross_attention_apply(cfg, lp["cross_attn"], q, xk, xv)
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.mlp(cfg, lp["mlp"], h)
        return x, (kc, vc)

    x, (k, v) = jax.lax.scan(
        body, x,
        (params["layers"], cache["k"], cache["v"], cache["xk"], cache["xv"]))
    x = L.rmsnorm(x, params["embed"]["norm_f"], cfg.norm_eps)
    logits = L.unembed(cfg, params["embed"], x)[:, 0]
    return logits, {"k": k, "v": v, "xk": cache["xk"], "xv": cache["xv"],
                    "len": pos + 1}


def cache_specs(cfg: ModelConfig, batch: int, max_len: int
                ) -> Tuple[Dict, Dict]:
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    f = cfg.num_frontend_tokens
    lyr = cfg.num_layers
    shapes = {
        "k": jax.ShapeDtypeStruct((lyr, batch, max_len, kv, hd), dt),
        "v": jax.ShapeDtypeStruct((lyr, batch, max_len, kv, hd), dt),
        "xk": jax.ShapeDtypeStruct((lyr, batch, f, kv, hd), dt),
        "xv": jax.ShapeDtypeStruct((lyr, batch, f, kv, hd), dt),
        "len": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }
    axes = {
        "k": ("layers", "batch", "kv_seq", "kv_heads", None),
        "v": ("layers", "batch", "kv_seq", "kv_heads", None),
        "xk": ("layers", "batch", "frames", "kv_heads", None),
        "xv": ("layers", "batch", "frames", "kv_heads", None),
        "len": ("batch",),
    }
    return shapes, axes
