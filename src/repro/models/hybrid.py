"""Jamba-style hybrid LM (family "hybrid"): periods of ``attn_period`` layers
with one attention layer per period (index ``attn_offset``) and Mamba2 mixers
elsewhere; FFN alternates dense MLP / MoE by ``moe_period``.

The layer stack is scanned over *periods* (the repeating unit), with the 8
sub-layers unrolled inside the period body — HLO stays O(period), not
O(num_layers).  The decode cache holds a KV cache only for the attention
layers (1/8 of depth) plus O(1) SSD states: this is what makes ``long_500k``
feasible, with the attention KV sharded over the "data" axis (sequence
parallelism) under the long-context rule overrides.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import moe_ep as MEP
from repro.models import ssm as S
from repro.models import transformer as T
from repro.models.sharding import shard

Array = jax.Array


def _is_attn(cfg: ModelConfig, i: int) -> bool:
    return (i % cfg.attn_period) == cfg.attn_offset


def _is_moe(cfg: ModelConfig, i: int) -> bool:
    return bool(cfg.num_experts) and (i % cfg.moe_period) == cfg.moe_offset


def num_periods(cfg: ModelConfig) -> int:
    assert cfg.num_layers % cfg.attn_period == 0, (
        "hybrid num_layers must be a multiple of attn_period")
    return cfg.num_layers // cfg.attn_period


def period_specs(cfg: ModelConfig) -> Dict:
    subs = {}
    for i in range(cfg.attn_period):
        sub = {
            "ln1": L.rmsnorm_spec(cfg.d_model),
            "ln2": L.rmsnorm_spec(cfg.d_model),
            "mixer": (L.attention_specs(cfg) if _is_attn(cfg, i)
                      else S.ssm_specs(cfg)),
            "ffn": (M.moe_specs(cfg) if _is_moe(cfg, i)
                    else L.mlp_specs(cfg)),
        }
        subs[f"sub{i}"] = sub
    return subs


def specs(cfg: ModelConfig) -> Dict:
    return {
        "embed": L.embedding_specs(cfg),
        "periods": T.stack_specs(period_specs(cfg), num_periods(cfg),
                                 axis="periods"),
    }


# ---------------------------------------------------------------------------
# training
# ---------------------------------------------------------------------------

def _period_fwd(cfg: ModelConfig, pp: Dict, x: Array, positions: Array,
                segment_ids: Optional[Array]) -> Tuple[Array, Array]:
    aux_total = jnp.zeros((), jnp.float32)
    for i in range(cfg.attn_period):
        p = pp[f"sub{i}"]
        h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        if _is_attn(cfg, i):
            mix = L.attention(cfg, p["mixer"], h, positions, segment_ids)
        else:
            mix = S.ssm_block(cfg, p["mixer"], h)
        x = x + mix
        h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        if _is_moe(cfg, i):
            ffn = MEP.moe_ffn_ep if cfg.moe_ep else M.moe_ffn
            f, aux = ffn(cfg, p["ffn"], h)
            aux_total = aux_total + aux
        else:
            f = L.mlp(cfg, p["ffn"], h)
        x = x + f
        x = shard(x, "batch", "seq", None)
    return x, aux_total


def hidden_states(cfg: ModelConfig, params: Dict, batch: Dict
                  ) -> Tuple[Array, Array]:
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    segment_ids = batch.get("segment_ids")

    body = T.remat_wrap(cfg, functools.partial(
        _period_fwd, cfg, positions=positions, segment_ids=segment_ids))
    x, auxs = jax.lax.scan(lambda c, pp: body(pp, c), x, params["periods"])
    x = L.rmsnorm(x, params["embed"]["norm_f"], cfg.norm_eps)
    return x, jnp.mean(auxs)


def apply(cfg: ModelConfig, params: Dict, batch: Dict) -> Tuple[Array, Array]:
    x, aux = hidden_states(cfg, params, batch)
    return L.unembed(cfg, params["embed"], x), aux


def loss(cfg: ModelConfig, params: Dict, batch: Dict,
         aux_weight: float = 0.01) -> Tuple[Array, Dict]:
    x, aux = hidden_states(cfg, params, batch)
    ce, denom = T.chunked_xent(cfg, params["embed"], x,
                               batch["targets"], batch.get("loss_mask"))
    total = ce + aux_weight * aux
    return total, {"loss": ce, "aux": aux, "tokens": denom}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def _mamba_subs(cfg: ModelConfig):
    return [i for i in range(cfg.attn_period) if not _is_attn(cfg, i)]


def prefill(cfg: ModelConfig, params: Dict, tokens: Array,
            frontend=None) -> Tuple[Dict, Array]:
    del frontend
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(carry, pp):
        x = carry
        kv = None
        ssm_caches = {}
        for i in range(cfg.attn_period):
            p = pp[f"sub{i}"]
            h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
            if _is_attn(cfg, i):
                mix, kv = L.attention_prefill(cfg, p["mixer"], h, positions)
            else:
                mix, c = S.ssm_block(cfg, p["mixer"], h, return_cache=True)
                ssm_caches[f"sub{i}"] = c
            x = x + mix
            h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
            if _is_moe(cfg, i):
                ffn = MEP.moe_ffn_ep if cfg.moe_ep else M.moe_ffn
                f, _ = ffn(cfg, p["ffn"], h)
            else:
                f = L.mlp(cfg, p["ffn"], h)
            x = x + f
        return x, (kv, ssm_caches)

    x, (kv, ssm_caches) = jax.lax.scan(body, x, params["periods"])
    x = L.rmsnorm(x, params["embed"]["norm_f"], cfg.norm_eps)
    logits = L.unembed(cfg, params["embed"], x[:, -1:])[:, 0]
    cache = {"k": kv[0], "v": kv[1], "ssm": ssm_caches,
             "len": jnp.full((b,), s, jnp.int32)}
    return cache, logits


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict,
                tokens: Array) -> Tuple[Array, Dict]:
    pos = cache["len"]
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.dtype))

    def body(carry, xs):
        pp, kc, vc, ssm_c = xs
        x = carry
        new_ssm = {}
        for i in range(cfg.attn_period):
            p = pp[f"sub{i}"]
            h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
            if _is_attn(cfg, i):
                mix, kc, vc = L.attention_decode(
                    cfg, p["mixer"], h, pos, kc, vc)
            else:
                mix, new_ssm[f"sub{i}"] = S.ssm_decode_step(
                    cfg, p["mixer"], h, ssm_c[f"sub{i}"])
            x = x + mix
            h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
            if _is_moe(cfg, i):
                ffn = MEP.moe_ffn_ep if cfg.moe_ep else M.moe_ffn
                f, _ = ffn(cfg, p["ffn"], h)
            else:
                f = L.mlp(cfg, p["ffn"], h)
            x = x + f
        return x, (kc, vc, new_ssm)

    x, (k, v, ssm_caches) = jax.lax.scan(
        body, x, (params["periods"], cache["k"], cache["v"], cache["ssm"]))
    x = L.rmsnorm(x, params["embed"]["norm_f"], cfg.norm_eps)
    logits = L.unembed(cfg, params["embed"], x)[:, 0]
    return logits, {"k": k, "v": v, "ssm": ssm_caches, "len": pos + 1}


def cache_specs(cfg: ModelConfig, batch: int, max_len: int
                ) -> Tuple[Dict, Dict]:
    np_ = num_periods(cfg)
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    sshapes, saxes = S.ssm_cache_specs(cfg, batch, dt)
    shapes = {
        "k": jax.ShapeDtypeStruct((np_, batch, max_len, kv, hd), dt),
        "v": jax.ShapeDtypeStruct((np_, batch, max_len, kv, hd), dt),
        "ssm": {f"sub{i}": {
            k_: jax.ShapeDtypeStruct((np_,) + v_.shape, v_.dtype)
            for k_, v_ in sshapes.items()} for i in _mamba_subs(cfg)},
        "len": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }
    axes = {
        "k": ("periods", "batch", "kv_seq", "kv_heads", None),
        "v": ("periods", "batch", "kv_seq", "kv_heads", None),
        "ssm": {f"sub{i}": {k_: ("periods",) + v_ for k_, v_ in saxes.items()}
                for i in _mamba_subs(cfg)},
        "len": ("batch",),
    }
    return shapes, axes
