"""Parameter-spec trees.

A model is described by a pytree of ``PSpec`` (shape + logical axes + init).
From one spec tree we derive: real initialized arrays (smoke tests, examples),
``ShapeDtypeStruct`` stand-ins (dry-run lowering — no allocation), and the
logical-axes tree consumed by ``models.sharding.tree_shardings``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"     # normal | embed | zeros | ones | small
    scale: float = 1.0
    dtype: Optional[str] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, PSpec)


def _std(spec: PSpec) -> float:
    if spec.init == "embed":
        return 0.02 * spec.scale
    if spec.init == "small":
        return 1e-3 * spec.scale
    # lecun-style: fan-in is the second-to-last dim for rank>=2 (layer-stacked
    # params share the same per-layer fan-in, so the leading dims are ignored)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    return spec.scale / np.sqrt(max(fan_in, 1))


def init_tree(specs: Any, rng: jax.Array, default_dtype: str) -> Any:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    rngs = jax.random.split(rng, len(leaves))

    def one(spec: PSpec, key):
        dt = jnp.dtype(spec.dtype or default_dtype)
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dt)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dt)
        return (jax.random.normal(key, spec.shape, jnp.float32)
                * _std(spec)).astype(dt)

    return jax.tree.unflatten(treedef, [one(s, k) for s, k in zip(leaves, rngs)])


def shape_tree(specs: Any, default_dtype: str) -> Any:
    def one(spec: PSpec):
        return jax.ShapeDtypeStruct(
            spec.shape, jnp.dtype(spec.dtype or default_dtype))
    return jax.tree.map(one, specs, is_leaf=_is_spec)


def axes_tree(specs: Any) -> Any:
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)


def param_bytes(specs: Any, default_dtype: str) -> int:
    total = 0
    for s in jax.tree.leaves(specs, is_leaf=_is_spec):
        n = int(np.prod(s.shape)) if s.shape else 1
        total += n * jnp.dtype(s.dtype or default_dtype).itemsize
    return total


def param_count(specs: Any) -> int:
    return sum(int(np.prod(s.shape)) if s.shape else 1
               for s in jax.tree.leaves(specs, is_leaf=_is_spec))
