"""Mamba-2 (SSD — state-space duality) mixer.

Training/prefill uses the chunked SSD algorithm: within a chunk the
computation is a masked (decay-weighted) attention-like GEMM — MXU friendly —
and across chunks a tiny state recurrence (B,H,P,N) runs in a lax.scan.
Decode is the O(1) recurrent step on the same state, which is why the
``long_500k`` shape is only runnable for the SSM/hybrid families: the decode
"cache" does not grow with context length.

Numerics: all decay exponents are cumulative sums of negative increments, so
every exp() argument is <= 0 — no overflow anywhere in the chunked path.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rmsnorm
from repro.models.params import PSpec
from repro.models.sharding import shard

Array = jax.Array


def ssm_specs(cfg: ModelConfig) -> Dict:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    w = cfg.ssm_conv
    return {
        "w_z": PSpec((d, di), ("embed", "inner")),
        "w_x": PSpec((d, di), ("embed", "inner")),
        "w_B": PSpec((d, n), ("embed", "state")),
        "w_C": PSpec((d, n), ("embed", "state")),
        "w_dt": PSpec((d, h), ("embed", "ssm_heads")),
        "conv_x": PSpec((w, di), ("conv", "inner"), init="normal"),
        "conv_B": PSpec((w, n), ("conv", "state"), init="normal"),
        "conv_C": PSpec((w, n), ("conv", "state"), init="normal"),
        "dt_bias": PSpec((h,), ("ssm_heads",), init="zeros", dtype="float32"),
        "A_log": PSpec((h,), ("ssm_heads",), init="zeros", dtype="float32"),
        "D": PSpec((h,), ("ssm_heads",), init="ones", dtype="float32"),
        "norm": PSpec((di,), ("inner",), init="ones", dtype="float32"),
        "w_out": PSpec((di, d), ("inner", "embed")),
    }


def _causal_conv(x: Array, kernel: Array) -> Array:
    """Depthwise causal conv. x: (B,S,C); kernel: (W,C)."""
    w = kernel.shape[0]
    pad = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    acc = jnp.zeros_like(x)
    for i in range(w):
        acc = acc + pad[:, i:i + x.shape[1]] * kernel[i].astype(x.dtype)
    return acc


def _proj_in(cfg: ModelConfig, p: Dict, x: Array):
    dt_f = x.dtype
    z = jnp.einsum("bsd,di->bsi", x, p["w_z"].astype(dt_f))
    xin = jnp.einsum("bsd,di->bsi", x, p["w_x"].astype(dt_f))
    b = jnp.einsum("bsd,dn->bsn", x, p["w_B"].astype(dt_f))
    c = jnp.einsum("bsd,dn->bsn", x, p["w_C"].astype(dt_f))
    dt = jnp.einsum("bsd,dh->bsh", x, p["w_dt"].astype(dt_f))
    return z, xin, b, c, dt


def ssd_chunked(cfg: ModelConfig, xh: Array, dt: Array, b: Array, c: Array,
                a_log: Array, init_state: Array = None
                ) -> Tuple[Array, Array]:
    """Chunked SSD scan.
    xh: (B,S,H,P); dt: (B,S,H) fp32; b,c: (B,S,N); a_log: (H,) fp32 (=A<0).
    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, pdim = xh.shape
    n = b.shape[-1]
    q = min(cfg.ssm_chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    xdt = (xh.astype(jnp.float32) * dt[..., None])       # (B,S,H,P)
    a = dt * a_log                                       # (B,S,H)  <= 0

    def chunk(v, last):
        return v.reshape(bsz, nc, q, *v.shape[2:]) if not last else v

    a_c = a.reshape(bsz, nc, q, h)
    cum = jnp.cumsum(a_c, axis=2)                        # (B,NC,Q,H)
    xdt_c = xdt.reshape(bsz, nc, q, h, pdim)
    b_c = b.reshape(bsz, nc, q, n).astype(jnp.float32)
    c_c = c.reshape(bsz, nc, q, n).astype(jnp.float32)

    # ---- intra-chunk (attention-like dual form) ----
    # L[i,j] = exp(cum_i - cum_j) for i >= j else 0
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,NC,Q,Q,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    l_mat = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    g_mat = jnp.einsum("bcqn,bckn->bcqk", c_c, b_c)       # (B,NC,Q,Q)
    m_mat = g_mat[..., None] * l_mat                      # (B,NC,Q,Q,H)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", m_mat, xdt_c)

    # ---- chunk states ----
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)       # (B,NC,Q,H) <= 1
    s_chunk = jnp.einsum("bckn,bckh,bckhp->bchpn",
                         b_c, decay_to_end, xdt_c)        # (B,NC,H,P,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])               # (B,NC,H)

    # ---- inter-chunk recurrence ----
    if init_state is None:
        init_state = jnp.zeros((bsz, h, pdim, n), jnp.float32)

    def step(state, inp):
        dec, s_c = inp                                    # (B,H), (B,H,P,N)
        entering = state
        state = dec[:, :, None, None] * state + s_c
        return state, entering

    final_state, states_in = jax.lax.scan(
        step, init_state,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(s_chunk, 1, 0)))
    states_in = jnp.moveaxis(states_in, 0, 1)             # (B,NC,H,P,N)

    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                         c_c, jnp.exp(cum), states_in)
    y = (y_intra + y_inter).reshape(bsz, s, h, pdim)
    return y.astype(xh.dtype), final_state


def ssm_block(cfg: ModelConfig, p: Dict, x: Array,
              return_cache: bool = False):
    """Full Mamba2 mixer over a sequence. x: (B,S,D).
    With ``return_cache`` also returns the O(1) decode cache (conv tails +
    final SSD state) so prefill can hand off to the recurrent decode step."""
    bsz, s, _ = x.shape
    h, pdim = cfg.ssm_heads, cfg.ssm_headdim
    w = cfg.ssm_conv
    z, xin_r, b_r, c_r, dt = _proj_in(cfg, p, x)
    xin = jax.nn.silu(_causal_conv(xin_r, p["conv_x"]))
    b = jax.nn.silu(_causal_conv(b_r, p["conv_B"]))
    c = jax.nn.silu(_causal_conv(c_r, p["conv_C"]))
    xin = shard(xin, "batch", "seq", "inner")
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a_log = -jnp.exp(p["A_log"])
    xh = xin.reshape(bsz, s, h, pdim)
    y, final_state = ssd_chunked(cfg, xh, dt, b, c, a_log)
    y = y + xh.astype(jnp.float32).astype(y.dtype) * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, cfg.d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"].astype(x.dtype))
    if not return_cache:
        return out
    cache = {"conv_x": xin_r[:, s - (w - 1):],
             "conv_B": b_r[:, s - (w - 1):],
             "conv_C": c_r[:, s - (w - 1):],
             "state": final_state}
    return out, cache


# ---------------------------------------------------------------------------
# decode (O(1) state)
# ---------------------------------------------------------------------------

def ssm_cache_init(cfg: ModelConfig, batch: int, dtype) -> Dict:
    di, n = cfg.d_inner, cfg.ssm_state
    w = cfg.ssm_conv
    return {
        "conv_x": jnp.zeros((batch, w - 1, di), dtype),
        "conv_B": jnp.zeros((batch, w - 1, n), dtype),
        "conv_C": jnp.zeros((batch, w - 1, n), dtype),
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_headdim, n),
                           jnp.float32),
    }


def ssm_cache_specs(cfg: ModelConfig, batch: int, dtype) -> Dict:
    """(shape-struct, logical-axes) for dry-run lowering."""
    di, n = cfg.d_inner, cfg.ssm_state
    w = cfg.ssm_conv
    shapes = {
        "conv_x": jax.ShapeDtypeStruct((batch, w - 1, di), dtype),
        "conv_B": jax.ShapeDtypeStruct((batch, w - 1, n), dtype),
        "conv_C": jax.ShapeDtypeStruct((batch, w - 1, n), dtype),
        "state": jax.ShapeDtypeStruct(
            (batch, cfg.ssm_heads, cfg.ssm_headdim, n), jnp.float32),
    }
    axes = {
        "conv_x": ("batch", None, "inner"),
        "conv_B": ("batch", None, "state"),
        "conv_C": ("batch", None, "state"),
        "state": ("batch", "ssm_heads", None, None),
    }
    return shapes, axes


def _conv_step(buf: Array, new: Array, kernel: Array) -> Tuple[Array, Array]:
    """buf: (B,W-1,C) previous raw inputs; new: (B,C). Returns (y, buf')."""
    win = jnp.concatenate([buf, new[:, None]], axis=1)     # (B,W,C)
    y = jnp.einsum("bwc,wc->bc", win, kernel.astype(win.dtype))
    return y, win[:, 1:]


def ssm_decode_step(cfg: ModelConfig, p: Dict, x: Array, cache: Dict
                    ) -> Tuple[Array, Dict]:
    """One-token recurrent step. x: (B,1,D). Returns (out (B,1,D), cache')."""
    bsz = x.shape[0]
    h, pdim, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    z, xin_r, b_r, c_r, dt = _proj_in(cfg, p, x)
    z, xin_r, b_r, c_r, dt = (v[:, 0] for v in (z, xin_r, b_r, c_r, dt))

    xin, conv_x = _conv_step(cache["conv_x"], xin_r, p["conv_x"])
    b, conv_b = _conv_step(cache["conv_B"], b_r, p["conv_B"])
    c, conv_c = _conv_step(cache["conv_C"], c_r, p["conv_C"])
    xin, b, c = jax.nn.silu(xin), jax.nn.silu(b), jax.nn.silu(c)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,H)
    decay = jnp.exp(dt * -jnp.exp(p["A_log"]))                    # (B,H)
    xh = xin.reshape(bsz, h, pdim).astype(jnp.float32)
    xdt = xh * dt[..., None]
    state = cache["state"] * decay[:, :, None, None] + \
        jnp.einsum("bhp,bn->bhpn", xdt, b.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", state, c.astype(jnp.float32))
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(bsz, cfg.d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bi,id->bd", y, p["w_out"].astype(x.dtype))
    cache = {"conv_x": conv_x, "conv_B": conv_b, "conv_C": conv_c,
             "state": state}
    return out[:, None], cache
