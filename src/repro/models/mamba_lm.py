"""Mamba-2 language model (family "ssm"): embedding -> N x (norm + SSD mixer)
-> final norm -> tied unembedding.  Attention-free; the decode cache is O(1)
in context length, which is why this family serves the ``long_500k`` shape.
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T
from repro.models.params import PSpec

Array = jax.Array


def layer_specs(cfg: ModelConfig) -> Dict:
    return {"ln": L.rmsnorm_spec(cfg.d_model), "mixer": S.ssm_specs(cfg)}


def specs(cfg: ModelConfig) -> Dict:
    return {
        "embed": L.embedding_specs(cfg),
        "layers": T.stack_specs(layer_specs(cfg), cfg.num_layers),
    }


def _block(cfg: ModelConfig, p: Dict, x: Array) -> Array:
    return x + S.ssm_block(cfg, p["mixer"],
                           L.rmsnorm(x, p["ln"], cfg.norm_eps))


def hidden_states(cfg: ModelConfig, params: Dict, batch: Dict
                  ) -> Tuple[Array, Array]:
    x = L.embed(params["embed"], batch["tokens"], jnp.dtype(cfg.dtype))
    block = T.remat_wrap(cfg, functools.partial(_block, cfg))
    x, _ = jax.lax.scan(lambda c, lp: (block(lp, c), None),
                        x, params["layers"])
    x = L.rmsnorm(x, params["embed"]["norm_f"], cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


def apply(cfg: ModelConfig, params: Dict, batch: Dict) -> Tuple[Array, Array]:
    x, aux = hidden_states(cfg, params, batch)
    return L.unembed(cfg, params["embed"], x), aux


def loss(cfg: ModelConfig, params: Dict, batch: Dict,
         aux_weight: float = 0.0) -> Tuple[Array, Dict]:
    x, aux = hidden_states(cfg, params, batch)
    ce, denom = T.chunked_xent(cfg, params["embed"], x,
                               batch["targets"], batch.get("loss_mask"))
    return ce, {"loss": ce, "aux": aux, "tokens": denom}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params: Dict, tokens: Array,
            frontend=None) -> Tuple[Dict, Array]:
    del frontend
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.dtype))

    def body(carry, lp):
        h = L.rmsnorm(carry, lp["ln"], cfg.norm_eps)
        out, cache = S.ssm_block(cfg, lp["mixer"], h, return_cache=True)
        return carry + out, cache

    x, caches = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(x, params["embed"]["norm_f"], cfg.norm_eps)
    logits = L.unembed(cfg, params["embed"], x[:, -1:])[:, 0]
    caches["len"] = jnp.full((tokens.shape[0],), tokens.shape[1], jnp.int32)
    return caches, logits


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict,
                tokens: Array) -> Tuple[Array, Dict]:
    """tokens: (B,1). cache leaves carry a leading layer axis."""
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.dtype))
    layer_cache = {k: v for k, v in cache.items() if k != "len"}

    def body(carry, xs):
        lp, lc = xs
        h = L.rmsnorm(carry, lp["ln"], cfg.norm_eps)
        out, lc = S.ssm_decode_step(cfg, lp["mixer"], h, lc)
        return carry + out, lc

    x, new_cache = jax.lax.scan(body, x, (params["layers"], layer_cache))
    x = L.rmsnorm(x, params["embed"]["norm_f"], cfg.norm_eps)
    logits = L.unembed(cfg, params["embed"], x)[:, 0]
    new_cache["len"] = cache["len"] + 1
    return logits, new_cache


def cache_specs(cfg: ModelConfig, batch: int, max_len: int
                ) -> Tuple[Dict, Dict]:
    """ShapeDtypeStructs + logical axes for the decode cache (leading layer
    axis).  Constant-size in ``max_len`` — that's the SSD selling point."""
    del max_len
    shapes, axes = S.ssm_cache_specs(cfg, batch, jnp.dtype(cfg.dtype))
    lshapes = {k: jax.ShapeDtypeStruct((cfg.num_layers,) + v.shape, v.dtype)
               for k, v in shapes.items()}
    laxes = {k: ("layers",) + v for k, v in axes.items()}
    lshapes["len"] = jax.ShapeDtypeStruct((batch,), jnp.int32)
    laxes["len"] = ("batch",)
    return lshapes, laxes


def init_cache(cfg: ModelConfig, batch: int) -> Dict:
    one = S.ssm_cache_init(cfg, batch, jnp.dtype(cfg.dtype))
    cache = {k: jnp.broadcast_to(v[None], (cfg.num_layers,) + v.shape)
             for k, v in one.items()}
    cache["len"] = jnp.zeros((batch,), jnp.int32)
    return cache
