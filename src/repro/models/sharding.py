"""Logical-axis sharding rules.

Model code annotates parameters and activations with *logical* axis names
("embed", "heads", "ffn", "experts", "batch", "kv_seq", ...).  A rules table
maps logical names to physical mesh axes.  This indirection is the main
hillclimbing lever in EXPERIMENTS.md §Perf: changing a rule re-lowers the
whole program with a different partitioning, no model edits.

Divisibility fallback: if a tensor dim is not divisible by the mapped mesh
axis size (e.g. qwen1.5's 40 heads on a 16-way model axis) the rule silently
degrades to replication for that dim, so every (arch x shape x mesh) cell in
the dry-run sweep lowers.  Fallbacks are recorded and surfaced by dryrun.py.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]
Rules = Dict[str, Axis]

# Default production rules: DP over pod+data, FSDP(param) over data,
# TP/EP over model.  See DESIGN.md §4.
DEFAULT_RULES: Rules = {
    "batch": ("pod", "data"),
    "seq": None,             # residual-stream sequence dim (SP shards this)
    "act_seq": None,         # sequence dim INSIDE attention/MLP (stays
                             # unsharded under SP so TP axes win the specs)
    "logits_seq": None,      # sequence dim of logits (vocab TP has priority)
    "kv_seq": None,          # long-context decode overrides this to "data"
    "embed": "data",         # FSDP axis for parameters
    "vocab": "model",
    "heads": "model",
    "kv_heads": None,
    "head_dim": None,
    "ffn": "model",
    "experts": "model",
    "expert_cap": None,
    "state": None,           # SSM state dim
    "ssm_heads": "model",
    "inner": "model",        # mamba d_inner
    "conv": None,
    "layers": None,
    "periods": None,
    "frames": None,
    "stack": None,
}

LONG_CONTEXT_OVERRIDES: Rules = {
    "kv_seq": "data",        # sequence-parallel KV cache / scan chunks
    "batch": "pod",
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Rules = dict(DEFAULT_RULES)
        self.fallbacks: list = []


_ctx = _Ctx()


@contextlib.contextmanager
def sharding_ctx(mesh: Optional[Mesh], rules: Optional[Rules] = None):
    """Activate a mesh + logical rules for model tracing."""
    prev = (_ctx.mesh, _ctx.rules, _ctx.fallbacks)
    _ctx.mesh = mesh
    _ctx.rules = dict(DEFAULT_RULES)
    if rules:
        _ctx.rules.update(rules)
    _ctx.fallbacks = []
    try:
        yield _ctx
    finally:
        _ctx.mesh, _ctx.rules, _ctx.fallbacks = prev


def current_mesh() -> Optional[Mesh]:
    return _ctx.mesh


def recorded_fallbacks() -> list:
    return list(_ctx.fallbacks)


def _axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape.get(axis, 1)
    n = 1
    for a in axis:
        n *= mesh.shape.get(a, 1)
    return n


def _present(mesh: Mesh, axis: Axis) -> Axis:
    """Drop mesh axes that do not exist on this mesh (e.g. 'pod' single-pod)."""
    if axis is None:
        return None
    if isinstance(axis, str):
        return axis if axis in mesh.shape else None
    kept = tuple(a for a in axis if a in mesh.shape)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def spec_for(shape: Sequence[int], logical: Sequence[Optional[str]],
             mesh: Optional[Mesh] = None, rules: Optional[Rules] = None) -> P:
    """Build a PartitionSpec for ``shape`` from logical axis names, applying
    the divisibility fallback. ``logical`` may be shorter than rank (trailing
    dims replicate)."""
    mesh = mesh or _ctx.mesh
    rules = rules or _ctx.rules
    if mesh is None:
        return P()
    parts = []
    used: set = set()
    for i, dim in enumerate(shape):
        name = logical[i] if i < len(logical) else None
        axis = _present(mesh, rules.get(name)) if name else None
        # a mesh axis may appear at most once in a PartitionSpec
        if axis is not None:
            flat = (axis,) if isinstance(axis, str) else tuple(axis)
            if any(a in used for a in flat):
                axis = None
        if axis is not None and dim % _axis_size(mesh, axis) != 0:
            _ctx.fallbacks.append((tuple(shape), tuple(logical), name, axis))
            axis = None
        if axis is not None:
            flat = (axis,) if isinstance(axis, str) else tuple(axis)
            used.update(flat)
        parts.append(axis)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply a logical sharding constraint to an activation (no-op when no
    mesh is active, so unit tests and the single-device path are untouched)."""
    if _ctx.mesh is None:
        return x
    spec = spec_for(x.shape, logical)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_ctx.mesh, spec))


def tree_shardings(tree_shapes: Any, tree_logical: Any,
                   mesh: Optional[Mesh] = None,
                   rules: Optional[Rules] = None) -> Any:
    """NamedShardings for a pytree of ShapeDtypeStructs given a matching
    pytree of logical-axis tuples (used for in_shardings at lower time)."""
    mesh = mesh or _ctx.mesh
    rules = rules or _ctx.rules

    def one(shape_struct, logical):
        return NamedSharding(
            mesh, spec_for(shape_struct.shape, logical, mesh, rules))

    return jax.tree.map(one, tree_shapes, tree_logical,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))
