"""Unified model API.

One dispatch surface over the five family implementations so that the
trainer, the serving engine, the dry-run, and the tests never branch on
architecture:

    specs / init_params / param_shapes / param_axes
    loss(cfg)(params, batch)              -- training
    prefill(cfg) / decode(cfg)            -- serving
    cache_specs(cfg, batch, max_len)      -- decode-cache ShapeDtypeStructs
    input_specs(cfg, shape)               -- per-(arch x shape) batch stand-ins

``input_specs`` returns ShapeDtypeStruct stand-ins + logical-axes trees; the
dry-run lowers against them with no allocation (same pattern for every cell
of the 40-cell sweep).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import encdec as E
from repro.models import hybrid as H
from repro.models import mamba_lm as ML
from repro.models import transformer as T
from repro.models import params as P

_FAMILY_MODULE = {
    "dense": T, "moe": T, "vlm": T,
    "ssm": ML, "hybrid": H, "encdec": E,
}


def module(cfg: ModelConfig):
    return _FAMILY_MODULE[cfg.family]


def param_specs(cfg: ModelConfig) -> Any:
    return module(cfg).specs(cfg)


def init_params(cfg: ModelConfig, rng: jax.Array) -> Any:
    return P.init_tree(param_specs(cfg), rng, cfg.param_dtype)


def param_shapes(cfg: ModelConfig) -> Any:
    return P.shape_tree(param_specs(cfg), cfg.param_dtype)


def param_axes(cfg: ModelConfig) -> Any:
    return P.axes_tree(param_specs(cfg))


def param_count(cfg: ModelConfig) -> int:
    return P.param_count(param_specs(cfg))


def loss(cfg: ModelConfig, params: Any, batch: Dict) -> Tuple[jax.Array, Dict]:
    return module(cfg).loss(cfg, params, batch)


def apply(cfg: ModelConfig, params: Any, batch: Dict):
    return module(cfg).apply(cfg, params, batch)


def prefill(cfg: ModelConfig, params: Any, tokens: jax.Array,
            frontend=None):
    return module(cfg).prefill(cfg, params, tokens, frontend)


def decode_step(cfg: ModelConfig, params: Any, cache: Dict,
                tokens: jax.Array):
    return module(cfg).decode_step(cfg, params, cache, tokens)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int
                ) -> Tuple[Dict, Dict]:
    if cfg.family in ("dense", "moe", "vlm"):
        return T.kv_cache_specs(cfg, batch, max_len)
    if cfg.family == "ssm":
        return ML.cache_specs(cfg, batch, max_len)
    if cfg.family == "hybrid":
        return H.cache_specs(cfg, batch, max_len)
    if cfg.family == "encdec":
        return E.cache_specs(cfg, batch, max_len)
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# per-(arch x shape) input stand-ins
# ---------------------------------------------------------------------------

def _frontend_spec(cfg: ModelConfig, batch: int):
    shape = (batch, cfg.num_frontend_tokens, cfg.d_model)
    return (jax.ShapeDtypeStruct(shape, jnp.dtype(cfg.dtype)),
            ("batch", "frames", None))


def token_len(cfg: ModelConfig, seq_len: int) -> int:
    """vlm prepends patch embeddings inside the context budget, so its token
    run is shorter; encdec frames live in a separate encoder sequence."""
    if cfg.family == "vlm":
        return seq_len - cfg.num_frontend_tokens
    return seq_len


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[Dict, Dict]:
    """(ShapeDtypeStruct tree, logical-axes tree) for one sweep cell.

    train   -> {tokens, targets[, frontend]}
    prefill -> {tokens[, frontend]}
    decode  -> {tokens (B,1), cache}  (serve_step: one new token against a
               KV/SSD cache of ``seq_len``)
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.dtype(jnp.int32)

    if shape.kind == "train":
        t = token_len(cfg, s)
        specs = {"tokens": jax.ShapeDtypeStruct((b, t), i32),
                 "targets": jax.ShapeDtypeStruct((b, t), i32)}
        axes = {"tokens": ("batch", "seq"), "targets": ("batch", "seq")}
        if cfg.family in ("vlm", "encdec"):
            specs["frontend"], axes["frontend"] = _frontend_spec(cfg, b)
        return specs, axes

    if shape.kind == "prefill":
        t = token_len(cfg, s)
        specs = {"tokens": jax.ShapeDtypeStruct((b, t), i32)}
        axes = {"tokens": ("batch", "seq")}
        if cfg.family in ("vlm", "encdec"):
            specs["frontend"], axes["frontend"] = _frontend_spec(cfg, b)
        return specs, axes

    if shape.kind == "decode":
        cshapes, caxes = cache_specs(cfg, b, s)
        specs = {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
                 "cache": cshapes}
        axes = {"tokens": ("batch", None), "cache": caxes}
        return specs, axes

    raise ValueError(shape.kind)


def pad_cache(cfg: ModelConfig, cache: Dict, max_len: int) -> Dict:
    """Pad a fresh-from-prefill cache out to ``max_len`` KV slots so decode
    steps can write past the prefill length (SSM caches are O(1) — no-op)."""
    if cfg.family == "ssm":
        return cache
    out = dict(cache)
    for key in ("k", "v"):
        arr = cache[key]
        pad = max_len - arr.shape[2]
        if pad > 0:
            out[key] = jnp.pad(
                arr, [(0, 0), (0, 0), (0, pad)] +
                [(0, 0)] * (arr.ndim - 3))
    return out


def make_zero_inputs(cfg: ModelConfig, shape: ShapeSpec) -> Dict:
    """Materialized (tiny-config) inputs matching ``input_specs`` — used by
    the smoke tests; never called on full-size configs."""
    specs, _ = input_specs(cfg, shape)

    def one(sds: jax.ShapeDtypeStruct):
        return jnp.zeros(sds.shape, sds.dtype)

    return jax.tree.map(one, specs)
