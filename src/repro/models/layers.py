"""Common transformer layers: RMSNorm, RoPE, GQA attention (train / prefill /
decode with per-example cache positions, packing-aware masks), MLP, embedding.

All functions are pure; parameters are nested dicts produced from the PSpec
trees in each family module.  Activation sharding is expressed through
``sharding.shard`` logical constraints so the same code lowers on any mesh.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import PSpec
from repro.models.sharding import shard

Array = jax.Array

NEG_INF = -1e30  # large-negative instead of -inf: keeps softmax NaN-free
                 # for fully-masked rows (padding slots in packed batches)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: Array, w: Array, eps: float) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * w.astype(dt)


def rmsnorm_spec(d: int) -> PSpec:
    return PSpec((d,), ("embed",), init="ones", dtype="float32")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotate-half RoPE.  x: (..., S, H, D), positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = jnp.exp(-jnp.log(theta) *
                   jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq       # (..., S, half)
    sin = jnp.sin(ang)[..., None, :]                            # (..., S, 1, half)
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:2 * half]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    rest = x[..., 2 * half:]
    return jnp.concatenate(
        [out1.astype(x.dtype), out2.astype(x.dtype), rest], axis=-1)


# ---------------------------------------------------------------------------
# attention parameter specs
# ---------------------------------------------------------------------------

def attention_specs(cfg: ModelConfig, d_in: Optional[int] = None) -> Dict:
    d = d_in or cfg.d_model
    hd, h, kv = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
    specs = {
        "wq": PSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": PSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": PSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": PSpec((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        specs["bq"] = PSpec((h, hd), ("heads", "head_dim"), init="zeros")
        specs["bk"] = PSpec((kv, hd), ("kv_heads", "head_dim"), init="zeros")
        specs["bv"] = PSpec((kv, hd), ("kv_heads", "head_dim"), init="zeros")
    return specs


def _qkv(cfg: ModelConfig, p: Dict, x: Array) -> Tuple[Array, Array, Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return q, k, v


def _gqa_scores(q: Array, k: Array, q_per_kv: int) -> Array:
    """q: (B,S,H,D) -> grouped (B,Kv,G,S,D); k: (B,T,Kv,D).
    Returns fp32 scores (B,Kv,G,S,T)."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    q = q.reshape(b, s, kvh, q_per_kv, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k,
                        preferred_element_type=jnp.float32)
    return scores * (d ** -0.5)


def _gqa_out(probs: Array, v: Array) -> Array:
    """probs: (B,Kv,G,S,T); v: (B,T,Kv,D) -> (B,S,H,D)."""
    b, kvh, g, s, t = probs.shape
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(b, s, kvh * g, v.shape[-1])


def causal_mask(positions_q: Array, positions_k: Array,
                seg_q: Optional[Array], seg_k: Optional[Array]) -> Array:
    """(B,S,T) boolean mask: causal in *positions* and packing-aware."""
    m = positions_q[:, :, None] >= positions_k[:, None, :]
    if seg_q is not None:
        m &= seg_q[:, :, None] == seg_k[:, None, :]
    return m


def _pick_block(s: int, cap: int = 1024) -> Optional[int]:
    for b in (1024, 512, 256, 128):
        if b <= cap and s % b == 0 and s > b:
            return b
    return None


def _sdpa(cfg: ModelConfig, q: Array, k: Array, v: Array,
          pos_q: Array, pos_k: Array,
          seg_q: Optional[Array], seg_k: Optional[Array],
          causal: bool) -> Array:
    """Scaled-dot-product GQA attention with automatic online-softmax
    chunking.  Never materializes (B,H,S,T) when S/T are large — the exact
    property the Pallas flash kernel provides on TPU; this is the XLA path
    used for lowering/dry-run and on CPU (see kernels/flash_attention.py for
    the TPU kernel).  Returns (B,S,H,D)."""
    s, t = q.shape[1], k.shape[1]
    qb = _pick_block(s)
    kb = _pick_block(t)
    if qb is None or kb is None:
        scores = _gqa_scores(q, k, cfg.q_per_kv)      # (B,Kv,G,S,T) fp32
        if causal or seg_q is not None:
            m = causal_mask(pos_q, pos_k, seg_q, seg_k) if causal else (
                seg_q[:, :, None] == seg_k[:, None, :])
            scores = jnp.where(m[:, None, None], scores, NEG_INF)
        return _gqa_out(jax.nn.softmax(scores, axis=-1), v)
    return _chunked_gqa(cfg, q, k, v, pos_q, pos_k, seg_q, seg_k,
                        qb, kb, causal)


def _chunked_gqa(cfg: ModelConfig, q: Array, k: Array, v: Array,
                 pos_q: Array, pos_k: Array,
                 seg_q: Optional[Array], seg_k: Optional[Array],
                 q_block: int, kv_block: int, causal: bool) -> Array:
    """Online-softmax (flash-style) attention in pure XLA: double lax.scan
    over query and key/value blocks with running (m, l, o) statistics."""
    b, s, h, d = q.shape
    t = k.shape[1]
    kvh, g = cfg.num_kv_heads, cfg.q_per_kv
    nq, nk = s // q_block, t // kv_block
    scale = d ** -0.5

    qx = jnp.moveaxis(q.reshape(b, nq, q_block, kvh, g, d), 1, 0)
    kx = jnp.moveaxis(k.reshape(b, nk, kv_block, kvh, d), 1, 0)
    vx = jnp.moveaxis(v.reshape(b, nk, kv_block, kvh, d), 1, 0)
    pqx = jnp.moveaxis(pos_q.reshape(b, nq, q_block), 1, 0)
    pkx = jnp.moveaxis(pos_k.reshape(b, nk, kv_block), 1, 0)
    has_seg = seg_q is not None
    sqx = jnp.moveaxis(seg_q.reshape(b, nq, q_block), 1, 0) if has_seg else pqx
    skx = jnp.moveaxis(seg_k.reshape(b, nk, kv_block), 1, 0) if has_seg else pkx

    def q_step(_, qin):
        qb_, pq_, sq_ = qin

        def kv_step(carry, kin):
            o, m, l = carry
            kb_, vb_, pk_, sk_ = kin
            sblk = jnp.einsum("bqkgd,btkd->bkgqt", qb_, kb_,
                              preferred_element_type=jnp.float32) * scale
            mask = None
            if causal:
                mask = pq_[:, :, None] >= pk_[:, None, :]
            if has_seg:
                segm = sq_[:, :, None] == sk_[:, None, :]
                mask = segm if mask is None else (mask & segm)
            if mask is not None:
                sblk = jnp.where(mask[:, None, None], sblk, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(sblk, axis=-1))
            p = jnp.exp(sblk - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqt,btkd->bkgqd", p, vb_.astype(jnp.float32))
            o = o * alpha[..., None] + pv
            return (o, m_new, l), None

        init = (jnp.zeros((b, kvh, g, q_block, d), jnp.float32),
                jnp.full((b, kvh, g, q_block), NEG_INF, jnp.float32),
                jnp.zeros((b, kvh, g, q_block), jnp.float32))
        (o, _, l), _ = jax.lax.scan(kv_step, init, (kx, vx, pkx, skx))
        o = o / jnp.maximum(l, 1e-30)[..., None]
        return None, o

    _, oblk = jax.lax.scan(q_step, None, (qx, pqx, sqx))
    # (nq, B, Kv, G, Qb, D) -> (B, S, H, D)
    out = jnp.transpose(oblk, (1, 0, 4, 2, 3, 5)).reshape(b, s, h, d)
    return out.astype(v.dtype)


def attention(cfg: ModelConfig, p: Dict, x: Array, positions: Array,
              segment_ids: Optional[Array] = None,
              causal: bool = True) -> Array:
    """Full-sequence attention (train / encoder). x: (B,S,D)."""
    q, k, v = _qkv(cfg, p, x)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "act_seq", "heads", None)
    k = shard(k, "batch", "act_seq", "kv_heads", None)
    v = shard(v, "batch", "act_seq", "kv_heads", None)
    out = _sdpa(cfg, q, k, v, positions, positions,
                segment_ids, segment_ids, causal)
    out = shard(out, "batch", "act_seq", "heads", None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def attention_prefill(cfg: ModelConfig, p: Dict, x: Array,
                      positions: Array) -> Tuple[Array, Tuple[Array, Array]]:
    """Like ``attention`` but also returns (k, v) for cache construction."""
    q, k, v = _qkv(cfg, p, x)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    k = shard(k, "batch", "kv_seq", "kv_heads", None)
    v = shard(v, "batch", "kv_seq", "kv_heads", None)
    out = _sdpa(cfg, q, k, v, positions, positions, None, None, True)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, (k, v)


def cross_attention_specs(cfg: ModelConfig) -> Dict:
    return attention_specs(cfg)


def cross_attention(cfg: ModelConfig, p: Dict, x: Array, enc: Array
                    ) -> Tuple[Array, Tuple[Array, Array]]:
    """Encoder-decoder cross attention (no RoPE, no mask). x: (B,S,D),
    enc: (B,F,D). Returns (out, (k,v)) so serving can cache encoder KV."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bfd,dhk->bfhk", enc, p["wk"].astype(enc.dtype))
    v = jnp.einsum("bfd,dhk->bfhk", enc, p["wv"].astype(enc.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    y = cross_attention_apply(cfg, p, q, k, v)
    return y, (k, v)


def cross_attention_apply(cfg: ModelConfig, p: Dict, q: Array,
                          k: Array, v: Array) -> Array:
    b, s = q.shape[:2]
    pos_q = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    pos_k = jnp.broadcast_to(
        jnp.arange(k.shape[1], dtype=jnp.int32), (b, k.shape[1]))
    out = _sdpa(cfg, q, k, v, pos_q, pos_k, None, None, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(q.dtype))


def cache_update(k_cache: Array, v_cache: Array, k_new: Array, v_new: Array,
                 pos: Array) -> Tuple[Array, Array]:
    """Write one new token per example at per-example positions.
    caches: (B, Smax, Kv, D); new: (B, 1, Kv, D); pos: (B,) int32."""
    def upd(c, n, pi):
        return jax.lax.dynamic_update_slice_in_dim(c, n, pi, axis=0)
    k_cache = jax.vmap(upd)(k_cache, k_new, pos)
    v_cache = jax.vmap(upd)(v_cache, v_new, pos)
    return k_cache, v_cache


def attention_decode(cfg: ModelConfig, p: Dict, x: Array, pos: Array,
                     k_cache: Array, v_cache: Array,
                     ) -> Tuple[Array, Array, Array]:
    """Single-token decode. x: (B,1,D); pos: (B,) current position;
    caches: (B,Smax,Kv,D). Returns (out, k_cache, v_cache)."""
    b, _, _ = x.shape
    smax = k_cache.shape[1]
    q, k_new, v_new = _qkv(cfg, p, x)
    q = rope(q, pos[:, None], cfg.rope_theta)
    k_new = rope(k_new, pos[:, None], cfg.rope_theta)
    k_cache, v_cache = cache_update(k_cache, v_cache, k_new, v_new, pos)
    k_cache = shard(k_cache, "batch", "kv_seq", "kv_heads", None)
    v_cache = shard(v_cache, "batch", "kv_seq", "kv_heads", None)
    scores = _gqa_scores(q, k_cache, cfg.q_per_kv)    # (B,Kv,G,1,Smax)
    valid = jnp.arange(smax)[None] <= pos[:, None]    # (B,Smax)
    scores = jnp.where(valid[:, None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, v_cache)                    # (B,1,H,D)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, k_cache, v_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp_variant == "swiglu":
        return {
            "w_gate": PSpec((d, f), ("embed", "ffn")),
            "w_up": PSpec((d, f), ("embed", "ffn")),
            "w_down": PSpec((f, d), ("ffn", "embed")),
        }
    return {
        "w_in": PSpec((d, f), ("embed", "ffn")),
        "b_in": PSpec((f,), ("ffn",), init="zeros"),
        "w_out": PSpec((f, d), ("ffn", "embed")),
        "b_out": PSpec((d,), ("embed",), init="zeros"),
    }


def mlp(cfg: ModelConfig, p: Dict, x: Array) -> Array:
    if cfg.mlp_variant == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
        h = jax.nn.silu(g) * u
        h = shard(h, "batch", "act_seq", "ffn")
        return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"].astype(x.dtype))
    h = jax.nn.gelu(h + p["b_in"].astype(x.dtype))
    h = shard(h, "batch", "act_seq", "ffn")
    return jnp.einsum("bsf,fd->bsd", h,
                      p["w_out"].astype(x.dtype)) + p["b_out"].astype(x.dtype)


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

def embedding_specs(cfg: ModelConfig) -> Dict:
    specs = {
        "tok": PSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                     init="embed"),
        "norm_f": rmsnorm_spec(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        specs["head"] = PSpec((cfg.vocab_size, cfg.d_model),
                              ("vocab", "embed"), init="embed")
    return specs


def embed(p: Dict, tokens: Array, dtype) -> Array:
    x = jnp.take(p["tok"], tokens, axis=0).astype(dtype)
    return shard(x, "batch", "seq", None)


def unembed(cfg: ModelConfig, p: Dict, x: Array) -> Array:
    w = p.get("head", p["tok"])
    logits = jnp.einsum("bsd,vd->bsv", x, w.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    if cfg.logits_softcap > 0:
        logits = cfg.logits_softcap * jnp.tanh(logits / cfg.logits_softcap)
    return shard(logits, "batch", "logits_seq", "vocab")
