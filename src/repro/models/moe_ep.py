"""Explicit expert-parallel MoE FFN (shard_map + all_to_all).

The GSPMD-compiled sort/scatter MoE (moe.py) is correct but the SPMD
partitioner cannot see that dispatch is a permutation: it materializes and
**all-gathers** the (E, C, D) expert buffers across the model axis — the
dry-run measured 65 TB/device/step of all-gather wire on
kimi-k2 train_4k (EXPERIMENTS.md §Perf).  This module routes tokens with
two explicit ``all_to_all``s instead, which is what the physics requires:

  per device:  t local tokens, k experts each
    1. route + sort by destination expert shard (E/M experts per shard)
    2. all_to_all  (M, cap, D) token payload        -> owning shards
    3. local sort by expert, capacity-bucket, batched expert GEMMs
    4. all_to_all the processed tokens back, combine with router weights

Wire bytes/device/layer = 2 x t*k*cf*D (payload there and back) — for
kimi-k2 train_4k that is ~4.7 GB vs the ~1 TB GSPMD path, a ~200x
reduction at the collective-roofline level.

Drop semantics match moe.py (capacity factor bounds both hops).  The
routing math (top-k, normalized weights, load-balance aux) is shared.
"""

from __future__ import annotations

import functools
import inspect
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import moe as M
from repro.models.sharding import current_mesh

Array = jax.Array

# jax renamed shard_map's replication-check kwarg check_rep -> check_vma
_CHECK_KWARG = (
    {"check_vma": False}
    if "check_vma" in inspect.signature(shard_map).parameters
    else {"check_rep": False})


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _sort_bucket(values: Array, keys: Array, num_buckets: int,
                 capacity: int, fill_value=0.0):
    """Stable-sort rows of ``values`` by ``keys`` and place them in a dense
    (num_buckets, capacity) layout.  Returns (bucketed values, the slot each
    input row landed in [-1 = dropped])."""
    n = values.shape[0]
    order = jnp.argsort(keys)
    skey = keys[order]
    start = jnp.searchsorted(skey, jnp.arange(num_buckets))
    pos = jnp.arange(n, dtype=jnp.int32) - start[skey].astype(jnp.int32)
    keep = (pos < capacity) & (skey < num_buckets)
    slot = jnp.where(keep, skey * capacity + pos, num_buckets * capacity)
    buf = jnp.full((num_buckets * capacity + 1,) + values.shape[1:],
                   fill_value, values.dtype)
    buf = buf.at[slot].set(values[order], mode="drop")   # sorted order!
    # slot of each ORIGINAL row (invert the sort)
    inv_slot = jnp.full((n,), -1, jnp.int32)
    inv_slot = inv_slot.at[order].set(
        jnp.where(keep, slot, -1).astype(jnp.int32))
    return buf[:-1].reshape((num_buckets, capacity) + values.shape[1:]), \
        inv_slot


def moe_ffn_ep(cfg: ModelConfig, p: Dict, x: Array,
               axis: str = "model") -> Tuple[Array, Array]:
    """Drop-in for moe.moe_ffn when a mesh with ``axis`` is active."""
    mesh = current_mesh()
    if mesh is None or axis not in mesh.shape or \
            cfg.num_experts % mesh.shape[axis] != 0:
        return M.moe_ffn(cfg, p, x)

    m_sz = mesh.shape[axis]
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)

    body = functools.partial(_ep_body, cfg, axis, m_sz)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(batch_axes if batch_axes else None, None, None),
                  P(None, None),                    # router replicated
                  P(axis), P(axis), P(axis)),       # experts sharded on E
        out_specs=(P(batch_axes if batch_axes else None, None, None),
                   P()),
        **_CHECK_KWARG)
    y, aux = fn(x, p["router"], p["w_gate"].astype(x.dtype),
                p["w_up"].astype(x.dtype), p["w_down"].astype(x.dtype))
    return y, aux


def _ep_body(cfg: ModelConfig, axis: str, m_sz: int,
             x: Array, router: Array, wg: Array, wu: Array, wd: Array
             ) -> Tuple[Array, Array]:
    """Per-device body.  x: (B_l, S, D) local tokens; wg/wu/wd:
    (E_l, D, F) local experts."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    e_l = e // m_sz
    t = b * s
    xf = x.reshape(t, d)

    # router product in activation dtype with f32 accumulation — casting
    # xf up materialized a (t, d) f32 copy per layer (§Perf: 4.5 TB/step
    # of convert traffic on kimi-k2 before this)
    logits = jnp.einsum("td,de->te", xf, router.astype(xf.dtype),
                        preferred_element_type=jnp.float32)
    weights, idx = M._route(logits, k)                 # (t, k)

    # load-balance aux (local estimate; mean across devices via psum)
    probs = jax.nn.softmax(logits, axis=-1)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(frac_tokens * jnp.mean(probs, axis=0))
    aux = jax.lax.pmean(aux, axis)

    # ---- hop 1: tokens -> owning expert shard (bf16 features + int meta,
    # identical bucketing order so the slots line up) ----
    tk = t * k
    flat_e = idx.reshape(tk)                           # global expert id
    dst = (flat_e // e_l).astype(jnp.int32)            # owning shard
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_w = weights.reshape(tk)

    cap_send = _round_up(
        max(int(cfg.capacity_factor * tk / m_sz), 8), 8)
    send_x, sent_slot = _sort_bucket(xf[flat_t], dst, m_sz, cap_send,
                                     fill_value=0)
    send_e, _ = _sort_bucket((flat_e % e_l).astype(jnp.int32), dst,
                             m_sz, cap_send, fill_value=-1)
    recv_x = jax.lax.all_to_all(send_x, axis, split_axis=0,
                                concat_axis=0, tiled=False)
    recv_e = jax.lax.all_to_all(send_e, axis, split_axis=0,
                                concat_axis=0, tiled=False)

    rx = recv_x.reshape(m_sz * cap_send, d)
    rexp = recv_e.reshape(m_sz * cap_send)             # -1 = padding

    # ---- local expert GEMMs (same bucketing, per local expert) ----
    # single-expert shards (e.g. jamba: 16e over 16-way) need no second
    # over-provision: every received row fits by construction (§Perf —
    # the 1.25^2 double-padding showed up as +25% expert-GEMM flops)
    over = 1.25 if e_l > 1 else 1.0
    cap_e = _round_up(max(int(m_sz * cap_send / e_l * over), 8), 8)
    buf, rslot = _sort_bucket(rx, jnp.where(rexp >= 0, rexp, e_l),
                              e_l, cap_e)
    cdt = wg.dtype
    g = jnp.einsum("ecd,edf->ecf", buf.astype(cdt), wg)
    u = jnp.einsum("ecd,edf->ecf", buf.astype(cdt), wu)
    h = jax.nn.silu(g) * u
    out = jnp.einsum("ecf,efd->ecd", h, wd)            # (E_l, cap_e, D)

    # un-bucket back to recv order, send back in the SAME slots
    out_flat = out.reshape(e_l * cap_e, d)
    out_flat = jnp.concatenate(
        [out_flat, jnp.zeros((1, d), out_flat.dtype)], axis=0)
    back = out_flat[jnp.where(rslot >= 0, rslot, e_l * cap_e)]
    back = back.reshape(m_sz, cap_send, d)
    ret = jax.lax.all_to_all(back, axis, split_axis=0, concat_axis=0,
                             tiled=False)
    ret_flat = ret.reshape(m_sz * cap_send, d)

    # ---- combine: gather each (token, k) contribution by its sent slot
    ret_flat = jnp.concatenate(
        [ret_flat, jnp.zeros((1, d), ret_flat.dtype)], axis=0)
    contrib = ret_flat[jnp.where(sent_slot >= 0, sent_slot,
                                 m_sz * cap_send)]
    contrib = contrib * jnp.where(sent_slot >= 0, flat_w,
                                  0.0).astype(contrib.dtype)[:, None]
    y = jnp.zeros((t, d), contrib.dtype).at[flat_t].add(contrib)
    return y.reshape(b, s, d).astype(x.dtype), aux
