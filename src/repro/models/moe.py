"""Mixture-of-Experts FFN with TPU-friendly sort-based routing.

TPU adaptation notes (DESIGN.md §2): GPU MoE kernels use atomics/scatter
into per-expert buffers.  Here routing is a *sort*: token->expert assignments
are argsorted so each expert's tokens are contiguous, bucketed into a dense
(E, C, D) capacity buffer (static shapes — XLA/SPMD friendly), processed with
batched einsums on the MXU, and combined back with a scatter-add.

Two static layouts, chosen by token count at trace time:
  * per-row routing (train/prefill): capacity is per (sequence-row, expert),
    so routing is local to the "batch" sharding axis — no global sort across
    data-parallel shards.  Expert dims shard over "model" (EP); SPMD inserts
    the dispatch/combine all-to-alls.
  * global routing (decode): few tokens, one global sort.

Overflow tokens beyond capacity are dropped (standard Switch/GShard
semantics); capacity_factor controls the drop rate.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import PSpec
from repro.models.sharding import shard

Array = jax.Array

_GLOBAL_ROUTE_MAX_TOKENS = 4096  # decode-sized workloads use the global sort


def moe_specs(cfg: ModelConfig) -> Dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": PSpec((d, e), ("embed", "experts"), dtype="float32"),
        "w_gate": PSpec((e, d, f), ("experts", "embed", "ffn")),
        "w_up": PSpec((e, d, f), ("experts", "embed", "ffn")),
        "w_down": PSpec((e, f, d), ("experts", "ffn", "embed")),
    }


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = int(cfg.capacity_factor * tokens_per_group *
            cfg.experts_per_token / cfg.num_experts)
    return max(8, _round_up(c, 8))


def _route(logits: Array, k: int) -> Tuple[Array, Array]:
    """Top-k routing probabilities. logits: (..., E) fp32.
    Returns (weights (...,k), indices (...,k))."""
    gate, idx = jax.lax.top_k(logits, k)
    return jax.nn.softmax(gate, axis=-1), idx


def _dispatch_combine(cfg: ModelConfig, p: Dict, x2d: Array,
                      weights: Array, idx: Array, capacity: int) -> Array:
    """Sort-based dispatch for a flat token group.
    x2d: (T, D); weights/idx: (T, K).  Returns (T, D)."""
    t, d = x2d.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    tk = t * k

    flat_e = idx.reshape(tk)
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_w = weights.reshape(tk)

    order = jnp.argsort(flat_e)                  # stable -> token order kept
    se = flat_e[order]
    st = flat_t[order]
    sw = flat_w[order]

    group_start = jnp.searchsorted(se, jnp.arange(e), side="left")
    pos = jnp.arange(tk, dtype=jnp.int32) - group_start[se].astype(jnp.int32)
    keep = pos < capacity
    slot = jnp.where(keep, se * capacity + pos, e * capacity)  # drop row

    buf = jnp.zeros((e * capacity + 1, d), x2d.dtype)
    buf = buf.at[slot].set(x2d[st], mode="drop")
    buf = buf[:-1].reshape(e, capacity, d)

    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x2d.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x2d.dtype))
    h = jax.nn.silu(g) * u
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x2d.dtype))

    out = out.reshape(e * capacity, d)
    out = jnp.concatenate([out, jnp.zeros((1, d), out.dtype)], axis=0)
    gathered = out[slot] * (sw * keep).astype(out.dtype)[:, None]
    y = jnp.zeros((t, d), x2d.dtype).at[st].add(gathered)
    return y


def moe_ffn(cfg: ModelConfig, p: Dict, x: Array) -> Tuple[Array, Array]:
    """x: (B, S, D) -> (out (B,S,D), aux_loss scalar)."""
    b, s, d = x.shape
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    weights, idx = _route(logits, cfg.experts_per_token)

    # load-balancing auxiliary loss (Switch-style).  one_hot dtype pinned:
    # under x64 its default is f64, which would leak into the whole step
    probs = jax.nn.softmax(logits, axis=-1)                 # (B,S,E)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(idx[..., 0], cfg.num_experts, dtype=jnp.float32),
        axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = cfg.num_experts * jnp.sum(frac_tokens * frac_probs)

    if b * s <= _GLOBAL_ROUTE_MAX_TOKENS:
        cap = _capacity(cfg, b * s)
        y = _dispatch_combine(cfg, p, x.reshape(b * s, d),
                              weights.reshape(b * s, -1),
                              idx.reshape(b * s, -1), cap)
        return y.reshape(b, s, d), aux

    # per-row routing: every sequence row routes independently, so the sort
    # and capacity buffers are local to the batch sharding.
    cap = _capacity(cfg, s)
    y = jax.vmap(lambda xr, wr, ir:
                 _dispatch_combine(cfg, p, xr, wr, ir, cap))(x, weights, idx)
    y = shard(y, "batch", "seq", None)
    return y, aux
