"""Decoder-only transformer LM (families: dense, moe, vlm).

Layers are stacked along a leading "layers" axis and executed with
``jax.lax.scan`` so the lowered HLO is O(1) in depth — essential for
compiling 60+-layer trillion-parameter configs in the multi-pod dry-run.
Remat policy is configurable per arch (none / dots / full).

The vlm family prepends ``num_frontend_tokens`` precomputed patch embeddings
(the modality frontend is a stub per the assignment; ``input_specs`` provides
the embeddings).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import moe_ep as MEP
from repro.models.params import PSpec
from repro.models.sharding import shard

Array = jax.Array


def stack_specs(specs: Any, n: int, axis: str = "layers") -> Any:
    """Prepend a stacked-layer dim to every PSpec leaf."""
    def one(s: PSpec) -> PSpec:
        return PSpec((n,) + s.shape, (axis,) + s.axes, s.init, s.scale,
                     s.dtype)
    return jax.tree.map(one, specs, is_leaf=lambda x: isinstance(x, PSpec))


def remat_wrap(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.nothing_saveable)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def layer_specs(cfg: ModelConfig) -> Dict:
    specs = {
        "ln1": L.rmsnorm_spec(cfg.d_model),
        "attn": L.attention_specs(cfg),
        "ln2": L.rmsnorm_spec(cfg.d_model),
    }
    if cfg.num_experts and cfg.moe_period == 1:
        specs["moe"] = M.moe_specs(cfg)
    else:
        specs["mlp"] = L.mlp_specs(cfg)
    return specs


def specs(cfg: ModelConfig) -> Dict:
    return {
        "embed": L.embedding_specs(cfg),
        "layers": stack_specs(layer_specs(cfg), cfg.num_layers),
    }


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _block_train(cfg: ModelConfig, p: Dict, x: Array, positions: Array,
                 segment_ids: Optional[Array]) -> Tuple[Array, Array]:
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    x = x + L.attention(cfg, p["attn"], h, positions, segment_ids)
    x = shard(x, "batch", "seq", None)
    h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        ffn = MEP.moe_ffn_ep if cfg.moe_ep else M.moe_ffn
        f, aux = ffn(cfg, p["moe"], h)
    else:
        f, aux = L.mlp(cfg, p["mlp"], h), jnp.zeros((), jnp.float32)
    x = x + f
    return shard(x, "batch", "seq", None), aux


def _forward(cfg: ModelConfig, params: Dict, x: Array, positions: Array,
             segment_ids: Optional[Array]) -> Tuple[Array, Array]:
    """Run the layer stack. Returns (hidden, mean aux loss)."""
    block = remat_wrap(
        cfg, functools.partial(_block_train, cfg,
                               positions=positions, segment_ids=segment_ids))

    def body(carry, lp):
        y, aux = block(lp, carry)
        return y, aux

    x, auxs = jax.lax.scan(lambda c, lp: body(c, lp), x, params["layers"])
    return x, jnp.mean(auxs)


def _inputs_embed(cfg: ModelConfig, params: Dict, tokens: Array,
                  frontend: Optional[Array]) -> Tuple[Array, Array]:
    """Token (+ frontend stub) embedding. Returns (x, positions)."""
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed(params["embed"], tokens, dtype)
    b, s = tokens.shape
    if frontend is not None:
        x = jnp.concatenate([frontend.astype(dtype), x], axis=1)
        s = s + frontend.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return x, positions


# ---------------------------------------------------------------------------
# training forward / loss
# ---------------------------------------------------------------------------

def apply(cfg: ModelConfig, params: Dict, batch: Dict) -> Tuple[Array, Array]:
    """Training forward returning full logits (small/smoke workloads only —
    production training uses ``loss`` which never materializes them)."""
    x, aux = hidden_states(cfg, params, batch)
    return L.unembed(cfg, params["embed"], x), aux


def loss(cfg: ModelConfig, params: Dict, batch: Dict,
         aux_weight: float = 0.01) -> Tuple[Array, Dict]:
    """Training loss.  The hidden states are unembedded in sequence chunks
    (rematerialized in the backward pass) so the full (B,S,V) logits tensor
    — petabytes for the 256k-vocab archs at global_batch 256 x 4k — never
    exists."""
    hidden, aux = hidden_states(cfg, params, batch)
    ce, denom = chunked_xent(cfg, params["embed"], hidden,
                             batch["targets"], batch.get("loss_mask"))
    total = ce + aux_weight * aux
    return total, {"loss": ce, "aux": aux, "tokens": denom}


def hidden_states(cfg: ModelConfig, params: Dict, batch: Dict
                  ) -> Tuple[Array, Array]:
    """Final-norm hidden states over the *token* positions (frontend stub
    positions trimmed). Returns (x (B,S,D), aux)."""
    frontend = batch.get("frontend")
    if frontend is None and cfg.num_frontend_tokens and cfg.family == "vlm":
        frontend = jnp.zeros(
            (batch["tokens"].shape[0], cfg.num_frontend_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype))
    x, default_pos = _inputs_embed(cfg, params, batch["tokens"], frontend)
    nf = 0 if frontend is None else frontend.shape[1]
    positions = batch.get("positions")
    segment_ids = batch.get("segment_ids")
    if positions is not None and nf:
        fpos = jnp.broadcast_to(jnp.arange(nf, dtype=jnp.int32),
                                (x.shape[0], nf))
        positions = jnp.concatenate([fpos, positions + nf], axis=1)
        if segment_ids is not None:
            fseg = jnp.ones((x.shape[0], nf), segment_ids.dtype)
            segment_ids = jnp.concatenate([fseg, segment_ids], axis=1)
    if positions is None:
        positions = default_pos
    x, aux = _forward(cfg, params, x, positions, segment_ids)
    x = L.rmsnorm(x, params["embed"]["norm_f"], cfg.norm_eps)
    if nf:
        x = x[:, nf:]
    return x, aux


def chunked_xent(cfg: ModelConfig, embed_params: Dict, hidden: Array,
                 targets: Array, mask: Optional[Array],
                 chunk: int = 512) -> Tuple[Array, Array]:
    """Cross-entropy via a scan over sequence chunks; each chunk's logits are
    recomputed in the backward pass (jax.checkpoint)."""
    b, s, d = hidden.shape
    if mask is None:
        mask = jnp.ones_like(targets, jnp.float32)
    mask = mask.astype(jnp.float32)

    if s % chunk != 0 or s <= chunk:
        logits = L.unembed(cfg, embed_params, hidden)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        return jnp.sum(nll * mask) / denom, denom

    nc = s // chunk
    hx = jnp.moveaxis(hidden.reshape(b, nc, chunk, d), 1, 0)
    tx = jnp.moveaxis(targets.reshape(b, nc, chunk), 1, 0)
    mx = jnp.moveaxis(mask.reshape(b, nc, chunk), 1, 0)

    @jax.checkpoint
    def step(acc, xs):
        hb, tb, mb = xs
        logits = L.unembed(cfg, embed_params, hb)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, tb[..., None], axis=-1)[..., 0]
        return (acc[0] + jnp.sum(nll * mb), acc[1] + jnp.sum(mb)), None

    (tot, cnt), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hx, tx, mx))
    denom = jnp.maximum(cnt, 1.0)
    return tot / denom, denom


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def _block_prefill(cfg, p, x, positions):
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, kv = L.attention_prefill(cfg, p["attn"], h, positions)
    x = x + a
    h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        ffn = MEP.moe_ffn_ep if cfg.moe_ep else M.moe_ffn
        f, _ = ffn(cfg, p["moe"], h)
    else:
        f = L.mlp(cfg, p["mlp"], h)
    return x + f, kv


def prefill(cfg: ModelConfig, params: Dict, tokens: Array,
            frontend: Optional[Array] = None) -> Tuple[Dict, Array]:
    """Returns (cache {k,v:(L,B,S,Kv,hd), len:(B,)}, logits (B,V) at last)."""
    if frontend is None and cfg.num_frontend_tokens and cfg.family == "vlm":
        frontend = jnp.zeros(
            (tokens.shape[0], cfg.num_frontend_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype))
    x, positions = _inputs_embed(cfg, params, tokens, frontend)

    def body(carry, lp):
        y, kv = _block_prefill(cfg, lp, carry, positions)
        return y, kv

    x, (k, v) = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(x, params["embed"]["norm_f"], cfg.norm_eps)
    logits = L.unembed(cfg, params["embed"], x[:, -1:])[:, 0]
    cache = {"k": k, "v": v,
             "len": jnp.full((tokens.shape[0],), x.shape[1], jnp.int32)}
    return cache, logits


def _block_decode(cfg, p, x, pos, k_cache, v_cache):
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, k_cache, v_cache = L.attention_decode(
        cfg, p["attn"], h, pos, k_cache, v_cache)
    x = x + a
    h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        ffn = MEP.moe_ffn_ep if cfg.moe_ep else M.moe_ffn
        f, _ = ffn(cfg, p["moe"], h)
    else:
        f = L.mlp(cfg, p["mlp"], h)
    return x + f, k_cache, v_cache


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict,
                tokens: Array) -> Tuple[Array, Dict]:
    """One decode step. tokens: (B,1); cache k/v: (L,B,Smax,Kv,hd).
    Returns (logits (B,V), new cache)."""
    pos = cache["len"]                                    # (B,)
    x = L.embed(params["embed"], tokens, jnp.dtype(cfg.dtype))

    def body(carry, xs):
        lp, kc, vc = xs
        y, kc, vc = _block_decode(cfg, lp, carry, pos, kc, vc)
        return y, (kc, vc)

    x, (k, v) = jax.lax.scan(body, x, (params["layers"],
                                       cache["k"], cache["v"]))
    x = L.rmsnorm(x, params["embed"]["norm_f"], cfg.norm_eps)
    logits = L.unembed(cfg, params["embed"], x)[:, 0]
    return logits, {"k": k, "v": v, "len": pos + 1}


def kv_cache_specs(cfg: ModelConfig, batch: int, max_len: int
                   ) -> Tuple[Dict, Dict]:
    """ShapeDtypeStructs + logical axes for a decode cache."""
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    shapes = {
        "k": jax.ShapeDtypeStruct(
            (cfg.num_layers, batch, max_len, kv, hd), dt),
        "v": jax.ShapeDtypeStruct(
            (cfg.num_layers, batch, max_len, kv, hd), dt),
        "len": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }
    axes = {
        "k": ("layers", "batch", "kv_seq", "kv_heads", None),
        "v": ("layers", "batch", "kv_seq", "kv_heads", None),
        "len": ("batch",),
    }
    return shapes, axes
