"""Production serving launcher: continuous-batching engine over the mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-32b \
        --smoke --requests 12 [--slots 4]

On TPU hosts, drop ``--smoke`` to load the full config (params must come
from a checkpoint via --ckpt-dir; random-init otherwise, for pipeline
validation)."""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

import repro  # noqa: F401
from repro.ckpt import latest_step, restore
from repro.configs import get_config, smoke_config
from repro.models import api
from repro.serve import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = api.init_params(cfg, jax.random.key(0))
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        state = restore(args.ckpt_dir,
                        {"params": params})  # params-only restore
        params = state["params"]

    engine = ServingEngine(cfg, params, slots=args.slots,
                           max_len=args.max_len)
    rng = np.random.default_rng(1)
    t0 = time.perf_counter()
    for _ in range(args.requests):
        engine.submit(Request(
            rng.integers(16, cfg.vocab_size, 16).tolist(),
            max_new_tokens=args.max_new, stop_at_eos=False))
    done = engine.run()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.tokens) for r in done)
    print(f"{len(done)} requests, {tokens} tokens in {dt:.2f}s "
          f"({tokens/dt:.1f} tok/s) — {engine.decode_steps} decode steps "
          f"on {args.slots} slots")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
