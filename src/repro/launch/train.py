"""Production training launcher.

Builds the mesh from the live device set (elastic: whatever survived),
derives shardings from the logical-axis rules, initializes/restores the
train state sharded, and runs the fault-tolerant Trainer fed by the IDEA
pipeline.  On a TPU pod this is invoked under ``jax.distributed``; on this
CPU container use ``--smoke`` (reduced config, 1-device mesh) — the same
code path end to end.

    PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b \
        --smoke --steps 10 [--ckpt-dir /tmp/ckpt] [--model-parallel 1]
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

import repro  # noqa: F401  (x64)
from repro.configs import get_config, smoke_config
from repro.core import FeedManager, RefStore
from repro.core.enrich import queries as Q
from repro.models.sharding import sharding_ctx
from repro.runtime.elastic import build_mesh
from repro.train import OptConfig
from repro.train.data_feed import FeedDataSource
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = build_mesh(model_parallel=args.model_parallel)
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(mesh.shape)} devices={len(jax.devices())}")

    store = RefStore()
    Q.make_reference_tables(store, scale=0.002, seed=7)
    source = FeedDataSource(FeedManager(store), vocab_size=cfg.vocab_size,
                            seq_len=args.seq_len, batch_size=args.batch,
                            total_records=10_000_000, frame_size=512,
                            safety_filter=True, num_partitions=2)

    opt = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 2),
                    total_steps=args.steps)
    tcfg = TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every, log_every=5)
    with sharding_ctx(mesh if mesh.size > 1 else None):
        trainer = Trainer(cfg, opt, tcfg)
        history = trainer.run(iter(source))
    source.stop()
    for h in history[-5:]:
        print(f"step {h['step']:5d}  loss {h['loss']:.4f}  "
              f"lr {h['lr']:.2e}  {h['wall_s']:.1f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
