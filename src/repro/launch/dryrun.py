import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run (deliverable e): lower + compile every
# (architecture x input shape) cell against the production mesh — 16x16
# single-pod and 2x16x16 multi-pod — with ShapeDtypeStruct operands (no
# allocation), then extract memory_analysis / cost_analysis / collective
# schedule for EXPERIMENTS.md §Dry-run and §Roofline.
#
# The device-count override above MUST precede every other import (jax
# locks the device count on first init); it lives only in this entrypoint,
# so tests and benches keep seeing the single real CPU device.

import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

import repro         # noqa: F401,E402  (enables x64)
from repro.configs import (ALL_ARCHS, SHAPES, get_config,  # noqa: E402
                           shape_applicable)
from repro.launch import hlocost as HC                     # noqa: E402
from repro.launch import roofline as RL                    # noqa: E402
from repro.launch.mesh import V5E, make_production_mesh    # noqa: E402
from repro.models import api                               # noqa: E402
from repro.models.sharding import (recorded_fallbacks,     # noqa: E402
                                   sharding_ctx, tree_shardings)
from repro.train.optimizer import OptConfig                # noqa: E402
from repro.train.steps import (make_train_step,            # noqa: E402
                               train_state_axes, train_state_shapes)

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "launch_artifacts", "dryrun")


def opt_for(cfg) -> OptConfig:
    """Memory preset: the bf16 (100B+) archs get factored-v bf16 Adam."""
    huge = cfg.param_dtype == "bfloat16"
    return OptConfig(state_dtype="bfloat16" if huge else "float32",
                     factored_v=huge)


def rules_for(shape, arch: str):
    """Per-shape sharding-rule overrides (see DESIGN.md §4).

    decode_32k: the KV cache dominates — shard its sequence dim over
    'model' (flash-decoding style; softmax partials all-reduce).
    long_500k: batch=1, so both non-trivial axes go to the sequence
    (attention layers of hybrids) / heads stay on 'model' for SSM.
    """
    if shape.kind != "decode":
        return {}
    if shape.name == "long_500k":
        return {"kv_seq": ("data", "model"), "batch": None}
    return {"kv_seq": "model"}


def build_cell(cfg, shape, microbatches: int = 1):
    """Returns (fn, operand ShapeDtypeStructs, operand axes, donate)."""
    if shape.kind == "train":
        opt = opt_for(cfg)
        step = make_train_step(cfg, opt, microbatches=microbatches)
        st_shapes = train_state_shapes(cfg, opt)
        st_axes = train_state_axes(cfg, opt)
        b_shapes, b_axes = api.input_specs(cfg, shape)
        return step, (st_shapes, b_shapes), (st_axes, b_axes), (0,)

    p_shapes = api.param_shapes(cfg)
    p_axes = api.param_axes(cfg)
    if shape.kind == "prefill":
        b_shapes, b_axes = api.input_specs(cfg, shape)

        def prefill_fn(params, batch):
            return api.prefill(cfg, params, batch["tokens"],
                               batch.get("frontend"))

        return prefill_fn, (p_shapes, b_shapes), (p_axes, b_axes), ()

    b_shapes, b_axes = api.input_specs(cfg, shape)

    def decode_fn(params, cache, tokens):
        return api.decode_step(cfg, params, cache, tokens)

    return (decode_fn,
            (p_shapes, b_shapes["cache"], b_shapes["tokens"]),
            (p_axes, b_axes["cache"], b_axes["tokens"]), (1,))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, tag: str = "",
             rule_overrides: dict | None = None,
             cfg_overrides: dict | None = None) -> dict:
    """One dry-run cell.  ``tag`` + overrides support the §Perf hillclimb:
    variants re-lower the same cell with different sharding rules /
    config knobs and land in tagged artifacts for comparison."""
    cfg = get_config(arch)
    microbatches = 1
    if cfg_overrides:
        cfg_overrides = dict(cfg_overrides)
        microbatches = cfg_overrides.pop("_microbatches", 1)
        if cfg_overrides:
            cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    mesh_name = ("multi" if multi_pod else "single") + \
        (f"@{tag}" if tag else "")
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "status": "ok", "tag": tag,
              "overrides": {"rules": rule_overrides or {},
                            "cfg": cfg_overrides or {}}}
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        result.update(status="skip", reason=why)
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    rules = rules_for(shape, arch)
    if rule_overrides:
        rules.update({k: (tuple(v) if isinstance(v, list) else v)
                      for k, v in rule_overrides.items()})
    fn, op_shapes, op_axes, donate = build_cell(cfg, shape, microbatches)

    with sharding_ctx(mesh, rules) as ctx:
        in_shardings = tuple(tree_shardings(s, a)
                             for s, a in zip(op_shapes, op_axes))
        out_shardings = ((in_shardings[0], None)
                         if shape.kind == "train" else None)
        t0 = time.perf_counter()
        jitted = jax.jit(fn, in_shardings=in_shardings,
                         out_shardings=out_shardings,
                         donate_argnums=donate)
        with mesh:
            lowered = jitted.lower(*op_shapes)
            t_lower = time.perf_counter() - t0
            t0 = time.perf_counter()
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0
        fallbacks = [f"{s} {l} {n}->{a}" for s, l, n, a in
                     recorded_fallbacks()]

    mem = compiled.memory_analysis()
    cost = HC.xla_cost_analysis(compiled)
    hlo = compiled.as_text()
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(mem)
        print({k: v for k, v in cost.items()
               if k in ("flops", "bytes accessed")})

    # archive the HLO (zstd) so §Perf iterations re-analyze w/o recompiling
    try:
        import zstandard
        with open(art_path(arch, shape_name, mesh_name)
                  .replace(".json", ".hlo.zst"), "wb") as f:
            f.write(zstandard.ZstdCompressor(level=9).compress(
                hlo.encode()))
    except Exception:
        pass
    result["mesh"] = mesh_name  # tagged name (variant artifacts)

    # trip-count-aware costs (XLA cost_analysis counts while bodies once —
    # see launch/hlocost.py and tests/test_hlocost.py)
    mc = HC.analyze_text(hlo)
    roof = RL.analyze_module_cost(mc, V5E)
    f64 = RL.check_no_f64(hlo)
    mflops, formula = RL.model_flops(cfg, shape, chips)
    hlo_flops_global = roof.flops_per_dev * chips

    arg_b = mem.argument_size_in_bytes
    tmp_b = mem.temp_size_in_bytes
    out_b = mem.output_size_in_bytes
    result.update(
        chips=chips,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        params=api.param_count(cfg),
        params_active=cfg.param_count(active_only=True),
        arg_bytes_per_dev=arg_b, temp_bytes_per_dev=tmp_b,
        out_bytes_per_dev=out_b,
        hbm_fit=bool(arg_b + tmp_b + out_b <= V5E.hbm_bytes),
        roofline=roof.to_dict(),
        xla_cost_analysis={k: cost.get(k, 0.0)
                           for k in ("flops", "bytes accessed")},
        model_flops=mflops, model_flops_formula=formula,
        useful_ratio=(mflops / hlo_flops_global
                      if hlo_flops_global else 0.0),
        fallbacks=fallbacks,
        f64_leaks=f64[:5],
        hlo_ops=len(hlo.splitlines()),
    )
    if f64:
        result["status"] = "f64-leak"
    return result


def art_path(arch, shape, mesh_name):
    return os.path.join(ART_DIR, f"{arch}__{shape}__{mesh_name}.json")


# sweep order: cheapest-to-compile first, so the artifact dir fills with
# signal early and the trillion-parameter cells run last
SWEEP_ORDER = (
    "mamba2-130m", "whisper-medium", "internvl2-2b", "olmoe-1b-7b",
    "qwen1.5-32b", "deepseek-coder-33b", "command-r-35b",
    "command-r-plus-104b", "jamba-1.5-large-398b", "kimi-k2-1t-a32b",
)


def cells():
    for arch in SWEEP_ORDER:
        for shape in SHAPES:
            for mesh_name in ("single", "multi"):
                yield arch, shape, mesh_name


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="",
                    help="variant tag for §Perf artifacts")
    ap.add_argument("--rules", default=None,
                    help='JSON rule overrides, e.g. {"seq": "model"}')
    ap.add_argument("--cfg", default=None,
                    help='JSON ModelConfig overrides, e.g. '
                         '{"ssm_chunk": 128}')
    ap.add_argument("--report", action="store_true",
                    help="print a summary table from artifacts")
    args = ap.parse_args()
    os.makedirs(ART_DIR, exist_ok=True)

    if args.report:
        rows = []
        for arch, shape, mesh_name in cells():
            p = art_path(arch, shape, mesh_name)
            if os.path.exists(p):
                rows.append(json.load(open(p)))
        print(json.dumps(rows, indent=1))
        return 0

    if args.all:
        # each cell in a fresh interpreter: XLA state + memory isolation
        import subprocess
        failures = []
        for arch, shape, mesh_name in cells():
            p = art_path(arch, shape, mesh_name)
            if os.path.exists(p) and not args.force:
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh_name]
            print(">>", " ".join(cmd), flush=True)
            r = subprocess.run(cmd)
            if r.returncode != 0:
                failures.append((arch, shape, mesh_name))
        print("failures:", failures)
        return 1 if failures else 0

    mesh_name = args.mesh + (f"@{args.tag}" if args.tag else "")
    try:
        res = run_cell(args.arch, args.shape, args.mesh == "multi",
                       tag=args.tag,
                       rule_overrides=json.loads(args.rules)
                       if args.rules else None,
                       cfg_overrides=json.loads(args.cfg)
                       if args.cfg else None)
    except Exception:
        res = {"arch": args.arch, "shape": args.shape, "mesh": mesh_name,
               "status": "fail", "error": traceback.format_exc()[-4000:]}
        with open(art_path(args.arch, args.shape, mesh_name), "w") as f:
            json.dump(res, f, indent=1)
        print(res["error"])
        return 1
    with open(art_path(args.arch, args.shape, mesh_name), "w") as f:
        json.dump(res, f, indent=1)
    print(json.dumps({k: v for k, v in res.items()
                      if k not in ("roofline",)}, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
