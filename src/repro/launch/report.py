"""Render the dry-run artifact directory as the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.launch.report [--tags] > table.md
"""

from __future__ import annotations

import argparse
import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "launch_artifacts", "dryrun")


def load(tags: bool = False):
    rows = []
    for p in sorted(glob.glob(os.path.join(ART, "*.json"))):
        r = json.load(open(p))
        tagged = "@" in r.get("mesh", "")
        if tagged != tags:
            continue
        rows.append(r)
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9),
                             r["mesh"]))
    return rows


def fmt_row(r) -> str:
    cell = f"{r['arch']} | {r['shape']} | {r['mesh']}"
    if r["status"] == "skip":
        return f"| {cell} | skip | — | — | — | — | — | — | {r['reason']} |"
    if r["status"] != "ok":
        return (f"| {cell} | **{r['status']}** | — | — | — | — | — | — | "
                f"{r.get('error', '')[:60]} |")
    rf = r["roofline"]
    gb = (r["arg_bytes_per_dev"] + r["temp_bytes_per_dev"]
          + r["out_bytes_per_dev"]) / 1e9
    dom = rf["dominant"]
    bound = rf[f"{dom}_s"]
    frac = rf["compute_s"] / bound if bound else 0.0
    note = "" if r["hbm_fit"] else "**over HBM**"
    return (f"| {cell} | ok | {rf['compute_s']:.4f} | {rf['memory_s']:.4f} "
            f"| {rf['collective_s']:.4f} | {dom} | {frac:.3f} "
            f"| {gb:.1f} | {note} |")


HEADER = ("| arch \\| shape \\| mesh | status | compute s | memory s | "
          "collective s | dominant | roofline frac | GB/dev | notes |\n"
          "|---|---|---|---|---|---|---|---|---|")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tags", action="store_true",
                    help="show tagged (§Perf variant) artifacts instead")
    args = ap.parse_args()
    rows = load(tags=args.tags)
    print(HEADER)
    for r in rows:
        print(fmt_row(r))
    ok = [r for r in rows if r["status"] == "ok"]
    if ok:
        fits = sum(1 for r in ok if r["hbm_fit"])
        print(f"\n{len(ok)} compiled, {fits} fit in 16 GB HBM/chip; "
              f"{sum(1 for r in rows if r['status'] == 'skip')} skipped "
              f"(long_500k on full-attention archs).")


if __name__ == "__main__":
    main()
