"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), in seconds (EXPERIMENTS.md §Roofline):

    compute    = per-device HLO FLOPs / peak_FLOP/s
    memory     = per-device HLO bytes accessed / HBM bandwidth
    collective = per-device wire bytes / ICI link bandwidth

``compiled.cost_analysis()`` FLOPs/bytes are per-partition (verified
empirically for the SPMD CPU backend), so no chip division is needed.
Collective bytes are NOT in cost_analysis: we parse the post-partitioning
HLO text and sum the output-shape bytes of every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute, then convert to
bytes-on-wire with the standard ring-algorithm factors:

    all-reduce        2 (N-1)/N x bytes
    all-gather          (N-1)/N x bytes      (bytes = gathered output)
    reduce-scatter    (N-1)   x bytes        (bytes = scattered output)
    all-to-all          (N-1)/N x bytes
    collective-permute  1      x bytes

N = collective group size, parsed from replica_groups (iota or explicit).
Raw operand-byte sums are also reported (the assignment's literal metric).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

from repro.launch.mesh import Hardware, V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<out>.+?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_EXPL_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_WIRE_FACTOR = {
    "all-reduce": lambda n: 2.0 * (n - 1) / n,
    "all-gather": lambda n: (n - 1) / n,
    "reduce-scatter": lambda n: float(n - 1),
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _EXPL_GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 2   # conservative default when ungrouped


@dataclasses.dataclass
class CollectiveStats:
    count: int = 0
    out_bytes: int = 0
    wire_bytes: float = 0.0


def parse_collectives(hlo_text: str) -> Dict[str, CollectiveStats]:
    """Per-op totals from post-SPMD HLO text (per-device shapes)."""
    stats: Dict[str, CollectiveStats] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        op = m.group("op")
        out_bytes = _shape_bytes(m.group("out"))
        n = _group_size(line)
        s = stats.setdefault(op, CollectiveStats())
        s.count += 1
        s.out_bytes += out_bytes
        s.wire_bytes += _WIRE_FACTOR[op](max(n, 2)) * out_bytes
    return stats


def check_no_f64(hlo_text: str) -> List[str]:
    """x64 mode hygiene: the model path must not leak f64 compute."""
    bad = []
    for line in hlo_text.splitlines():
        if re.search(r"=\s*f64\[", line):
            bad.append(line.strip()[:120])
    return bad


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_dev: float
    bytes_per_dev: float
    wire_bytes_per_dev: float
    coll_out_bytes_per_dev: float
    collectives: Dict[str, Dict]
    dominant: str

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def analyze_module_cost(mc, hw: Hardware = V5E) -> Roofline:
    """Roofline terms from a trip-count-aware hlocost.ModuleCost
    (per-device, since post-SPMD HLO shapes are per-device)."""
    terms = {
        "compute": mc.flops / hw.peak_flops,
        "memory": mc.hbm_bytes / hw.hbm_bw,
        "collective": mc.wire_bytes / hw.ici_bw,
    }
    dominant = max(terms, key=terms.get)
    return Roofline(
        compute_s=terms["compute"], memory_s=terms["memory"],
        collective_s=terms["collective"], flops_per_dev=mc.flops,
        bytes_per_dev=mc.hbm_bytes, wire_bytes_per_dev=mc.wire_bytes,
        coll_out_bytes_per_dev=mc.coll_out_bytes,
        collectives={k: {"count": v} for k, v in mc.coll_counts.items()},
        dominant=dominant)


def analyze(cost: Dict[str, float], hlo_text: str,
            hw: Hardware = V5E) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    colls = parse_collectives(hlo_text)
    wire = sum(s.wire_bytes for s in colls.values())
    raw = sum(s.out_bytes for s in colls.values())
    terms = {
        "compute": flops / hw.peak_flops,
        "memory": bytes_acc / hw.hbm_bw,
        "collective": wire / hw.ici_bw,
    }
    dominant = max(terms, key=terms.get)
    return Roofline(
        compute_s=terms["compute"], memory_s=terms["memory"],
        collective_s=terms["collective"], flops_per_dev=flops,
        bytes_per_dev=bytes_acc, wire_bytes_per_dev=wire,
        coll_out_bytes_per_dev=raw,
        collectives={k: dataclasses.asdict(v) for k, v in colls.items()},
        dominant=dominant)


def model_flops(cfg, shape, chips: int) -> Tuple[float, str]:
    """MODEL_FLOPS (global, matmul-only ideal): 6·N·D training,
    2·N_active·D inference (D = tokens processed per step)."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n_active * d, "6*N_active*D"
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n_active * d, "2*N_active*D"
    d = shape.global_batch          # one token per sequence
    return 2.0 * n_active * d, "2*N_active*B"
