"""Trip-count-aware cost extraction from post-optimization HLO text.

``compiled.cost_analysis()`` counts each ``while`` body ONCE regardless of
trip count (verified empirically), so for scan-over-layers models it
undercounts FLOPs/bytes by ~num_layers x.  This module re-derives the three
roofline inputs directly from the HLO text, weighting every computation by
the product of enclosing loop trip counts (XLA records
``known_trip_count`` in each while's backend_config):

  * FLOPs       — from dot ops (2 * prod(output dims) * contracted size,
                  batch dims excluded from output product... they are part
                  of the output shape, so included exactly once) plus a
                  convolution estimate; dots inside fusion computations are
                  attributed to the computation that references the fusion.
  * HBM bytes   — fusion-boundary traffic: for each executable instruction,
                  output bytes + operand bytes, with slice-type ops
                  (dynamic-slice / dynamic-update-slice / gather / scatter)
                  counted at their *slice* size, and free ops (tuple, GTE,
                  parameter, bitcast, while) at zero.  Fusion internals are
                  registers/VMEM by construction and contribute no bytes.
  * collective  — wire bytes per device with ring-algorithm factors and
                  replica-group sizes (see roofline.py), trip-weighted.

Executable computations = ENTRY + while bodies/conditions + conditional
branches; fusion/reducer computations are internal (flops-only).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+"
                     r"([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_EXPL_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "while", "after-all", "optimization-barrier",
             "conditional", "call", "custom-call", "partition-id",
             "replica-id", "iota", "rng-bit-generator"}
_SLICE_OUT_OPS = {"dynamic-slice", "gather", "slice"}
_SLICE_IN_OPS = {"dynamic-update-slice", "scatter"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"}
_WIRE_FACTOR = {
    "all-reduce": lambda n: 2.0 * (n - 1) / n,
    "all-gather": lambda n: (n - 1) / n,
    "reduce-scatter": lambda n: float(n - 1),
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """Normalize ``Compiled.cost_analysis()`` across jax versions: 0.4.x
    returns a one-element list of per-program dicts, >= 0.5 returns the dict
    itself.  Always returns a dict (empty when XLA reports nothing)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str) -> List[int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    out_type: str
    op: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr] = dataclasses.field(default_factory=list)


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m and ("->" in line):
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        dm = _DEF_RE.match(line)
        if dm:
            cur.instrs.append(Instr(dm.group(1), dm.group(2), dm.group(3),
                                    line))
    return comps


def _build_symbols(comps: Dict[str, Computation]) -> Dict[str, str]:
    sym: Dict[str, str] = {}
    for c in comps.values():
        for ins in c.instrs:
            sym[ins.name] = ins.out_type
    return sym


def _operands(ins: Instr) -> List[str]:
    """Operand names inside the op's parens (attribute refs excluded)."""
    start = ins.line.find(ins.op + "(")
    if start < 0:
        return []
    depth = 0
    seg = []
    for ch in ins.line[start + len(ins.op):]:
        if ch == "(":
            depth += 1
            if depth == 1:
                continue
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        seg.append(ch)
    return _OPERAND_RE.findall("".join(seg))


def _dot_flops(ins: Instr, sym: Dict[str, str]) -> float:
    out_dims = _shape_dims(ins.out_type)
    out_prod = 1
    for d in out_dims:
        out_prod *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
    ops = _operands(ins)
    if not m or not ops:
        return 2.0 * out_prod  # degenerate
    lhs_dims = _shape_dims(sym.get(ops[0], ""))
    contracted = 1
    for i in [int(x) for x in m.group(1).split(",") if x]:
        if i < len(lhs_dims):
            contracted *= lhs_dims[i]
    return 2.0 * out_prod * contracted


def _conv_flops(ins: Instr, sym: Dict[str, str]) -> float:
    out_dims = _shape_dims(ins.out_type)
    ops = _operands(ins)
    out_prod = 1
    for d in out_dims:
        out_prod *= d
    if len(ops) < 2:
        return 2.0 * out_prod
    k_dims = _shape_dims(sym.get(ops[1], ""))
    k_prod = 1
    for d in k_dims:
        k_prod *= d
    out_feat = out_dims[-1] if out_dims else 1
    return 2.0 * out_prod * max(k_prod // max(out_feat, 1), 1)


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _EXPL_GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 2


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    wire_bytes: float = 0.0
    coll_out_bytes: float = 0.0
    coll_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    children: List[Tuple[str, int]] = dataclasses.field(
        default_factory=list)       # (computation, trip_weight)
    fusion_refs: List[str] = dataclasses.field(default_factory=list)


def _fusion_dus_bytes(comp: Computation, sym: Dict[str, str]
                      ) -> Optional[float]:
    """If a fusion computation performs dynamic-update-slices (the donated
    in-place KV-cache pattern), its real traffic is the update slices, not
    the full aliased buffer.  Returns None for ordinary fusions."""
    dus = [i for i in comp.instrs if i.op in _SLICE_IN_OPS]
    if not dus:
        return None
    total = 0.0
    for ins in dus:
        ops = _operands(ins)
        upd = (_shape_bytes(sym.get(ops[1], "")) if len(ops) > 1
               else _shape_bytes(ins.out_type))
        total += 2.0 * upd
    return total


def _local_cost(comp: Computation, sym: Dict[str, str],
                comps: Dict[str, Computation]) -> CompCost:
    cost = CompCost()
    for ins in comp.instrs:
        op = ins.op
        if op == "dot":
            cost.flops += _dot_flops(ins, sym)
        elif op == "convolution":
            cost.flops += _conv_flops(ins, sym)
        elif op == "fusion":
            m = re.search(r"calls=%([\w\.\-]+)", ins.line)
            if m:
                cost.fusion_refs.append(m.group(1))
                callee = comps.get(m.group(1))
                if callee is not None:
                    dus_b = _fusion_dus_bytes(callee, sym)
                    if dus_b is not None:
                        # in-place update: slice writes + non-buffer reads
                        out_b = _shape_bytes(ins.out_type)
                        reads = sum(
                            _shape_bytes(sym.get(n, ""))
                            for n in _operands(ins)
                            if _shape_bytes(sym.get(n, "")) < out_b)
                        cost.hbm_bytes += dus_b + reads
                        continue
        elif op == "while":
            mb = re.search(r"body=%([\w\.\-]+)", ins.line)
            mc = re.search(r"condition=%([\w\.\-]+)", ins.line)
            mt = _TRIP_RE.search(ins.line)
            trip = int(mt.group(1)) if mt else 1
            if mb:
                cost.children.append((mb.group(1), trip))
            if mc:
                cost.children.append((mc.group(1), trip))
        elif op == "conditional":
            for m in re.finditer(r"%([\w\.\-]+)", ins.line.split(
                    "branch_computations")[-1]):
                if m.group(1) in sym:
                    continue
                cost.children.append((m.group(1), 1))

        base = op.removesuffix("-start").removesuffix("-done")
        if base in _COLLECTIVES and not op.endswith("-done"):
            out_b = _shape_bytes(ins.out_type)
            n = _group_size(ins.line)
            cost.wire_bytes += _WIRE_FACTOR[base](max(n, 2)) * out_b
            cost.coll_out_bytes += out_b
            cost.coll_counts[base] = cost.coll_counts.get(base, 0) + 1
            cost.hbm_bytes += 2.0 * out_b
            continue

        # ---- HBM traffic model ----
        if op in _FREE_OPS:
            continue
        out_b = _shape_bytes(ins.out_type)
        if op in _SLICE_OUT_OPS:
            cost.hbm_bytes += 2.0 * out_b
        elif op in _SLICE_IN_OPS:
            upd = _operands(ins)
            upd_b = (_shape_bytes(sym.get(upd[1], "")) if len(upd) > 1
                     else out_b)
            cost.hbm_bytes += 2.0 * upd_b
        else:
            cost.hbm_bytes += out_b
            for name in _operands(ins):
                cost.hbm_bytes += _shape_bytes(sym.get(name, ""))
    return cost


@dataclasses.dataclass
class ModuleCost:
    flops: float
    hbm_bytes: float
    wire_bytes: float
    coll_out_bytes: float
    coll_counts: Dict[str, int]
    trip_weighted: bool = True

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze_text(text: str, entry: Optional[str] = None) -> ModuleCost:
    comps = parse_module(text)
    sym = _build_symbols(comps)
    local = {name: _local_cost(c, sym, comps) for name, c in comps.items()}

    # attribute fusion-computation dot flops to the referrer (fusions can
    # nest; resolve with memoization)
    def fusion_flops(name: str, seen=None) -> float:
        seen = seen or set()
        if name in seen or name not in local:
            return 0.0
        seen.add(name)
        c = local[name]
        return c.flops + sum(fusion_flops(r, seen) for r in c.fusion_refs)

    if entry is None:
        entry = next((n for n in comps if n.startswith("main")), None) \
            or next(iter(comps))

    total = ModuleCost(0.0, 0.0, 0.0, 0.0, {})

    def walk(name: str, weight: float, stack: Tuple[str, ...] = ()):
        if name not in local or name in stack:
            return
        c = local[name]
        total.flops += weight * (
            c.flops + sum(fusion_flops(r) for r in c.fusion_refs))
        total.hbm_bytes += weight * c.hbm_bytes
        total.wire_bytes += weight * c.wire_bytes
        total.coll_out_bytes += weight * c.coll_out_bytes
        for op, n in c.coll_counts.items():
            total.coll_counts[op] = (total.coll_counts.get(op, 0)
                                     + int(weight * n))
        for child, trip in c.children:
            walk(child, weight * trip, stack + (name,))

    walk(entry, 1.0)
    return total
