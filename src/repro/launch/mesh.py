"""Production mesh + TPU v5e hardware model.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets the
512-placeholder-device flag before any jax import, and everything else
(tests, benches) sees the real single CPU device.
"""

from __future__ import annotations

import dataclasses

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions: ``axis_types`` (and the
    ``jax.sharding.AxisType`` enum) only exist in jax >= 0.5; 0.4.x builds
    the same Auto-typed mesh without the kwarg."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


@dataclasses.dataclass(frozen=True)
class Hardware:
    """TPU v5e chip model (the lowering TARGET; this container is CPU)."""
    name: str = "tpu-v5e"
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link (~per-chip budget)
    hbm_bytes: float = 16e9


V5E = Hardware()
