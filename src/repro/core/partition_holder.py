"""Partition holders (§6.3): bounded, partition-aligned queues that let data
frames cross job boundaries.

A **passive** holder (tail of the intake job) buffers frames and waits for
computing jobs to *pull*; an **active** holder (head of the storage job)
*pushes* received frames to its downstream consumer from its own worker
thread.  Every holder registers with a per-node ``PartitionHolderManager``
so jobs locate each other by (job, partition) — the paper's holder IDs.

Bounded capacity gives backpressure end-to-end: a slow storage job
eventually blocks the computing jobs, which stop pulling, which blocks the
intake adapter — no unbounded queue growth anywhere (the paper's "queue with
a limited size").

Extras beyond the paper, used by the runtime layer:
  * service-time EWMA + depth metrics per holder (straggler detection),
  * ``steal()`` so idle computing workers can take work from the deepest
    queue (work stealing / straggler mitigation),
  * a ``StopRecord`` sentinel implementing the paper's §7.1 drain protocol.
"""

from __future__ import annotations

import collections
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple


class StopRecord:
    """The 'special data record' of §7.1: computing jobs finish their
    current partial batch when they see it; the storage job closes after the
    last computing job."""
    __slots__ = ()

    def __repr__(self):
        return "<stop>"


STOP = StopRecord()


def frame_rows(frame: Any) -> int:
    """Rows in a frame, for backlog accounting: dict frames (pre-parsed
    struct-of-arrays) count their leading dim, byte frames their lines."""
    if isinstance(frame, dict):
        v = next(iter(frame.values()))
        return int(v.shape[0])
    try:
        return len(frame)
    except TypeError:
        return 1


def frame_bytes(frame: Any) -> int:
    if isinstance(frame, dict):
        return int(sum(v.nbytes for v in frame.values()))
    if isinstance(frame, (list, tuple)):
        return sum(len(line) for line in frame)
    return 0


class PartitionHolder:
    def __init__(self, holder_id: Tuple[str, int], capacity: int = 16):
        self.holder_id = holder_id
        self.capacity = capacity
        self._q: collections.deque = collections.deque()  # guarded-by: _lock
        self._lock = threading.Lock()       # lock-name: holder
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)
        self._closed = False                # guarded-by: _lock
        # metrics: mutated under the holder lock by producers/consumers,
        # read lock-free by stats collection after join
        self.pushed = 0                     # write-guarded-by: _lock
        self.pulled = 0                     # write-guarded-by: _lock
        self.push_wait_s = 0.0              # write-guarded-by: _lock
        self.pull_wait_s = 0.0              # write-guarded-by: _lock
        self.service_ewma_s = 0.0   # updated by consumers via record_service

    # ------------------------------------------------------------------ push
    def push(self, frame: Any, timeout: Optional[float] = None) -> bool:
        t0 = time.perf_counter()
        with self._not_full:
            while len(self._q) >= self.capacity and not self._closed:
                if not self._not_full.wait(timeout):
                    return False
            if self._closed and not isinstance(frame, StopRecord):
                raise RuntimeError(f"push to closed holder {self.holder_id}")
            self._q.append(frame)
            if isinstance(frame, StopRecord):
                # close is atomic with the STOP enqueue: a racing push must
                # RAISE (so the elastic intake/inter-group round-robin
                # re-targets it) rather than land behind the StopRecord,
                # where a retiring worker would never see it
                self._closed = True
                self._not_full.notify_all()
                self._not_empty.notify_all()
            self.pushed += 1
            self.push_wait_s += time.perf_counter() - t0
            self._not_empty.notify()
            return True

    # ------------------------------------------------------------------ pull
    def pull(self, timeout: Optional[float] = None) -> Optional[Any]:
        """Blocks until a frame is available; returns None on timeout.
        StopRecord is re-queued so every consumer observes it."""
        t0 = time.perf_counter()
        with self._not_empty:
            while not self._q:
                if self._closed:
                    return STOP
                if not self._not_empty.wait(timeout):
                    return None
            frame = self._q.popleft()
            if isinstance(frame, StopRecord):
                self._q.appendleft(frame)   # visible to all consumers
                self._closed = True
                self._not_empty.notify_all()
                self._not_full.notify_all()
                return STOP
            self.pulled += 1
            self.pull_wait_s += time.perf_counter() - t0
            self._not_full.notify()
            return frame

    def pull_nowait(self, predicate: Optional[Callable[[Any], bool]] = None
                    ) -> Optional[Any]:
        """Non-blocking pull from the head; returns None when the queue is
        empty, the head is the StopRecord (left in place so the drain
        protocol is untouched), or ``predicate`` rejects the head frame.
        Used by the worker micro-batcher to coalesce backlogged frames."""
        with self._lock:
            if not self._q or isinstance(self._q[0], StopRecord):
                return None
            if predicate is not None and not predicate(self._q[0]):
                return None
            frame = self._q.popleft()
            self.pulled += 1
            self._not_full.notify()
            return frame

    def steal(self) -> Optional[Any]:
        """Non-blocking take from the *tail* (most recently queued) — used by
        idle workers for straggler mitigation; never steals the StopRecord."""
        with self._lock:
            # a closed holder keeps its StopRecord at the tail; steal the
            # newest real frame just before it
            for i in (-1, -2):
                if len(self._q) >= -i and not isinstance(self._q[i],
                                                         StopRecord):
                    if i == -1:
                        frame = self._q.pop()
                    else:
                        frame = self._q[i]
                        del self._q[i]
                    self.pulled += 1
                    self._not_full.notify()
                    return frame
            return None

    def close(self) -> None:
        self.push(STOP)

    @property
    def depth(self) -> int:
        with self._lock:
            return len(self._q)

    def backlog(self) -> Tuple[int, int]:
        """(rows, bytes) currently queued, StopRecords excluded — the
        elasticity controller's load signal.  O(depth), and depth is
        bounded by ``capacity``, so sampling stays cheap."""
        with self._lock:
            frames = list(self._q)
        rows = nbytes = 0
        for f in frames:
            if isinstance(f, StopRecord):
                continue
            rows += frame_rows(f)
            nbytes += frame_bytes(f)
        return rows, nbytes

    @property
    def closed(self) -> bool:
        with self._lock:
            return self._closed

    def record_service(self, seconds: float, alpha: float = 0.2) -> None:
        self.service_ewma_s = (alpha * seconds
                               + (1 - alpha) * self.service_ewma_s)


class ActivePartitionHolder(PartitionHolder):
    """Push-mode holder: a worker thread drains the queue into ``consumer``.
    The storage job's head is one of these."""

    def __init__(self, holder_id: Tuple[str, int],
                 consumer: Callable[[Any], None], capacity: int = 16,
                 obs=None):
        super().__init__(holder_id, capacity)
        self._consumer = consumer
        self._obs = obs   # FeedObs for sink.append spans (None = untraced)
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(
            target=self._run, name=f"active-holder-{holder_id}", daemon=True)
        self._thread.start()

    def _run(self):
        while True:
            frame = self.pull(timeout=0.1)
            if frame is None:
                continue
            if isinstance(frame, StopRecord):
                return
            try:
                t0 = time.perf_counter()
                self._consumer(frame)
                dt = time.perf_counter() - t0
                self.record_service(dt)
                if self._obs is not None:
                    sids = getattr(frame, "span_ids", ())
                    if sids:
                        # consumer call and span emission both run with
                        # no lock held (feedlint R3/R6 discipline)
                        self._obs.emit("sink.append", sids,
                                       t0=time.monotonic() - dt, dur=dt,
                                       sink=self.holder_id[0])
            except BaseException as e:   # surfaced by join()
                self._err = e
                # fail fast, don't deadlock: close + drain so producers
                # blocked in push() wake up (they see a closed holder)
                # instead of waiting forever on a queue nobody drains
                with self._lock:
                    self._closed = True
                    self._q.clear()
                    self._not_full.notify_all()
                    self._not_empty.notify_all()
                return

    @property
    def error(self) -> Optional[BaseException]:
        return self._err

    def join(self, timeout: Optional[float] = None) -> None:
        self._thread.join(timeout)
        if self._err is not None:
            raise self._err


class PartitionHolderManager:
    """Per-node registry: jobs look up the holders of other jobs by ID."""

    def __init__(self):
        self._holders: Dict[Tuple[str, int], PartitionHolder] = {}  # guarded-by: _lock
        self._lock = threading.Lock()       # lock-name: holder-registry

    def register(self, holder: PartitionHolder) -> PartitionHolder:
        with self._lock:
            if holder.holder_id in self._holders:
                raise KeyError(f"holder {holder.holder_id} already exists")
            self._holders[holder.holder_id] = holder
            return holder

    def lookup(self, job: str, partition: int) -> PartitionHolder:
        # feedlint R1 fix: this read used to race register/unregister
        with self._lock:
            return self._holders[(job, partition)]

    def partitions(self, job: str) -> List[PartitionHolder]:
        with self._lock:
            return [h for (j, _), h in sorted(self._holders.items())
                    if j == job]

    def deepest(self, job: str,
                exclude: Optional[int] = None) -> Optional[PartitionHolder]:
        """The most-backlogged holder of a job (work-stealing target)."""
        best, depth = None, 0
        for h in self.partitions(job):
            if exclude is not None and h.holder_id[1] == exclude:
                continue
            d = h.depth
            if d > depth:
                best, depth = h, d
        return best

    def unregister(self, holder_id: Tuple[str, int]) -> None:
        with self._lock:
            self._holders.pop(holder_id, None)
