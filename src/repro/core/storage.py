"""The storage job (§6.2, §7.2): hash-partition enriched records by primary
key and append them to partitioned column stores.

Idempotence: each partition keeps a primary-key index; re-written keys are
skipped (insert mode) or replace the previous row logically (upsert mode).
With the feed manager's at-least-once batch retry this yields exactly-once
*storage* semantics — the property the hypothesis tests pin down.  The
index is a sorted pair of numpy arrays (pk, latest global row): membership
is one vectorized ``searchsorted`` probe and updates are bulk merges, so
the per-batch insert path has no per-row Python loop.

Durability: partitions buffer columns in memory and flush immutable
``.npz`` segments plus a JSON manifest (atomic rename) when ``spill_dir``
is set — an LSM-flavored, crash-consistent layout; ``recover()`` reloads
manifested segments after a crash.

Read side (core/query.py): at flush every segment records **zone maps**
(per-column min/max, persisted in the manifest, restored by ``recover()``)
so analytical scans can prune segments a predicate provably cannot match;
``sort_key`` optionally sorts each segment's rows at flush (an
ingestion-time layout decision à la INGESTBASE).  ``snapshot_view()``
returns a pinned, consistent view — the unit list, a copy of the pk index,
and the row watermark, captured under one lock — that stays readable (old
segment files are retained) while ingest, repair, and compaction keep
mutating the partition.

Lineage (core/repair.py): every appended chunk — and, after flush, every
segment — records the **reference-version lineage** its rows were enriched
under (``{table: RefTable.version}`` as of the computing job's snapshot).
The manifest persists per-segment lineage so ``recover()`` restores it,
and the repair scheduler compares it against current table versions to
find stale rows.  Repairs are in-place upserts with a conditional index
check (``repair_rows``): a row is only remapped if its index entry still
points at the scanned position, so a concurrent ingest upsert always wins
and re-scans are idempotent — exactly-once repair under live ingestion.

Compaction (core/compaction.py drives it; the primitives live here):
superseded and deleted row versions accumulate append-only — tracked
exactly in per-segment ``dead`` counters — until ``compact_segment`` /
``compact_chunks`` rewrite a unit without them and rebuild its zone maps.
Compaction **renumbers** global row positions (the one operation that
does; sorted flush only permutes within the new segment), so every
partition carries a **layout epoch**, bumped on each renumbering.  In-
flight repair captures the epoch with its unit scan and passes it back as
``expect_epoch`` to ``repair_rows``/``delete_rows``/``update_lineage``:
after a shrink, freed position numbers are reused by later appends, so a
stale conditional check could spuriously match — the epoch check closes
that hole (the rejected unit simply stays stale and is re-scanned).
Pinned snapshot views keep replaced segment files on disk until released.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import nputil
from repro.core.durability import fsync_dir

Lineage = Dict[str, int]          # ref table name -> version enriched under

ZoneMap = Dict[str, Tuple[float, float]]   # column -> (min, max) over a unit


def merge_lineage(lineages: List[Optional[Lineage]]) -> Lineage:
    """Combine chunk lineages into one segment lineage, per-table **min**
    (oldest wins): conservative for staleness — a merged segment is checked
    against the oldest version any of its rows might carry.  A ``None``
    (unversioned) member or a table missing from any member drops the
    table, which the repair scheduler treats as always-stale."""
    if not lineages or any(lin is None for lin in lineages):
        return {}
    tables = set(lineages[0])
    for lin in lineages[1:]:
        tables &= set(lin)
    return {t: min(lin[t] for lin in lineages) for t in tables}


def compute_zone_map(cols: Dict[str, np.ndarray],
                     zone_map_cols: Optional[Tuple[str, ...]]) -> ZoneMap:
    """Per-column (min, max) over a unit's rows — the pruning metadata the
    query subsystem checks predicates against.  ``zone_map_cols=None`` maps
    every eligible column (1-D numeric; bools and tensor columns like
    ``text_tokens`` are not range-prunable); ``()`` disables.  Values are
    plain python numbers so the manifest stays JSON."""
    out: ZoneMap = {}
    for k, v in cols.items():
        if zone_map_cols is not None and k not in zone_map_cols:
            continue
        if v.ndim != 1 or v.shape[0] == 0:
            continue
        if not np.issubdtype(v.dtype, np.number) or v.dtype == np.bool_:
            continue
        if np.issubdtype(v.dtype, np.floating):
            if np.isnan(v).any():
                # NaN breaks interval pruning BOTH ways: min/max become
                # NaN (every maybe() -> False: wrong prunes), and a NaN
                # row satisfies != even when [min,max] is a single point
                # (a nan-ignoring interval would wrongly prune that).
                # No zone map = never pruned = always correct.
                continue
            out[k] = (float(v.min()), float(v.max()))
        else:
            out[k] = (int(v.min()), int(v.max()))
    return out


class _PkIndex:
    """Sorted-array primary-key index: pk -> latest global row.

    Replaces the former dict + per-row Python loops on the hot storage
    path: membership is one ``np.searchsorted`` probe over the batch
    (``nputil.sorted_find``), updates are a bulk in-place overwrite plus
    one ``np.insert`` merge (O(index) memmove in C, amortized fine at
    segment scale)."""

    __slots__ = ("_pks", "_rows")

    def __init__(self):
        self._pks = np.empty(0, np.int64)
        self._rows = np.empty(0, np.int64)

    def __len__(self) -> int:
        return int(self._pks.shape[0])

    def contains(self, ids: np.ndarray) -> np.ndarray:
        return nputil.sorted_find(self._pks,
                                  np.asarray(ids, np.int64))[0]

    def lookup(self, ids: np.ndarray) -> np.ndarray:
        """Latest global row per id, -1 where absent."""
        ids = np.asarray(ids, np.int64)
        found, loc, _ = nputil.sorted_find(self._pks, ids)
        out = np.full(ids.shape[0], -1, np.int64)
        out[found] = self._rows[loc[found]]
        return out

    def get(self, pk: int) -> Optional[int]:
        row = self.lookup(np.asarray([pk], np.int64))[0]
        return None if row < 0 else int(row)

    def put(self, ids: np.ndarray, rows: np.ndarray) -> None:
        """Map each id to its row; within the batch the LAST occurrence
        wins (matches append order: later rows supersede earlier)."""
        ids = np.asarray(ids, np.int64)
        if ids.size == 0:
            return
        uniq, last = nputil.keep_last(ids)
        rows_u = np.asarray(rows, np.int64)[last]
        found, loc, pos = nputil.sorted_find(self._pks, uniq)
        self._rows[loc[found]] = rows_u[found]
        new = ~found
        if new.any():
            self._pks = np.insert(self._pks, pos[new], uniq[new])
            self._rows = np.insert(self._rows, pos[new], rows_u[new])

    def remove(self, ids: np.ndarray) -> int:
        """Drop entries for ``ids`` (absent ids are ignored)."""
        ids = np.asarray(ids, np.int64)
        if ids.size == 0:
            return 0
        found, loc, _ = nputil.sorted_find(self._pks, ids)
        if not found.any():
            return 0
        drop = np.unique(loc[found])
        self._pks = np.delete(self._pks, drop)
        self._rows = np.delete(self._rows, drop)
        return int(drop.shape[0])

    def remap_span(self, lo: int, hi: int, new_abs: np.ndarray) -> None:
        """Rewrite entries pointing into global rows [lo, hi) through
        ``new_abs`` (old offset -> new absolute row).  Used by sorted flush
        (permutation) and compaction (shrink)."""
        m = (self._rows >= lo) & (self._rows < hi)
        self._rows[m] = new_abs[self._rows[m] - lo]

    def shift_from(self, start: int, delta: int) -> None:
        """Shift every entry at global row >= ``start`` by ``delta``
        (compaction moved the suffix of the position space)."""
        if delta:
            self._rows[self._rows >= start] += delta

    def snapshot_arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        return self._pks.copy(), self._rows.copy()


@dataclasses.dataclass(frozen=True)
class SnapshotUnit:
    """One scannable unit of a partition snapshot: a flushed segment (read
    from its immutable file) or a buffered chunk (arrays are never mutated
    after append, so holding the dict is safe)."""
    base: int                       # first global row (at snapshot time)
    rows: int
    path: Optional[str] = None      # segment file; None -> in-memory chunk
    chunk: Optional[Dict[str, np.ndarray]] = None
    zone_map: Optional[ZoneMap] = None   # None: not prunable (chunks, legacy)

    def read(self, cols: Optional[Tuple[str, ...]] = None
             ) -> Dict[str, np.ndarray]:
        """Columns of this unit; ``cols=None`` reads all.  Segment reads
        decompress only the requested members (predicate/column pushdown:
        a pruned column set is an actual IO reduction, not cosmetic)."""
        if self.chunk is not None:
            if cols is None:
                return dict(self.chunk)
            return {k: self.chunk[k] for k in cols if k in self.chunk}
        with np.load(self.path) as seg:
            names = seg.files if cols is None else \
                [k for k in cols if k in seg.files]
            return {k: seg[k] for k in names}


class PartitionSnapshot:
    """A consistent, pinned view of one partition: unit list + pk-index
    copy + row watermark captured under a single lock acquisition.  While
    pinned, compaction defers deleting replaced segment files, so every
    unit stays readable.  ``release()`` (or the context manager) unpins."""

    def __init__(self, part: "StoragePartition", units: List[SnapshotUnit],
                 pks: np.ndarray, rows: np.ndarray, watermark: int,
                 epoch: int):
        self._part = part
        self.units = units
        self._pks = pks
        self._rows = rows
        self.watermark = watermark          # rows_total at snapshot time
        self.epoch = epoch
        self._released = False

    @property
    def pid(self) -> int:
        return self._part.pid

    def live_mask(self, ids: np.ndarray, base: int) -> np.ndarray:
        """Latest-wins over superseded/deleted versions: a scanned row is
        live iff the snapshot's pk index still points at its position."""
        ids = np.asarray(ids, np.int64)
        found, loc, _ = nputil.sorted_find(self._pks, ids)
        cur = np.full(ids.shape[0], -1, np.int64)
        cur[found] = self._rows[loc[found]]
        return cur == np.arange(base, base + ids.shape[0])

    @property
    def live_rows(self) -> int:
        return int(self._pks.shape[0])

    def release(self) -> None:
        if not self._released:
            self._released = True
            self._part._unpin()

    def __enter__(self) -> "PartitionSnapshot":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class StoragePartition:
    # deferred-durability window for repair's lineage advances: the
    # manifest rewrite (JSON + rename, under the partition lock) happens
    # at most once per this many seconds outside of flushes — a crash in
    # the window only regresses lineage to an OLDER version, which the
    # repair scheduler treats as stale and safely re-probes
    LINEAGE_SYNC_S = 1.0

    def __init__(self, pid: int, spill_dir: Optional[str] = None,
                 segment_rows: int = 100_000,
                 zone_map_cols: Optional[Tuple[str, ...]] = None,
                 sort_key: Optional[str] = None, obs=None):
        self.pid = pid
        # observability (core/obs): flush telemetry is RECORDED under the
        # partition lock (plain list append) but PUBLISHED — histogram
        # observe + span emit — only after release, by the next public
        # write/flush on this partition (feedlint R6 discipline)
        self._obs = obs
        self._flush_hist = (obs.registry.histogram("store_flush_s")
                            if obs is not None else None)
        # (rows, dur, span ids) per queued flush; span ids are the trace
        # stamps of the batches buffered since the previous flush, so a
        # traced journey closes at store.flush (core/obs/profile.py)
        self._flush_events: List[Tuple[int, float, Tuple[int, ...]]] = \
            []                                          # guarded-by: _lock
        self._pending_sids: List[int] = []              # guarded-by: _lock
        self.spill_dir = spill_dir
        self.segment_rows = segment_rows
        # None = zone-map every eligible column; () disables
        self.zone_map_cols = zone_map_cols
        # sort each segment's rows by this column at flush (ingestion-time
        # clustering).  NOTE: with a sort key, scan() order within a
        # segment is no longer append order — latest-wins resolution must
        # go through the pk index (snapshot_view), which is remapped with
        # the permutation and stays exact.
        self.sort_key = sort_key
        self._chunks: List[Dict[str, np.ndarray]] = []      # guarded-by: _lock
        self._chunk_lineage: List[Optional[Lineage]] = []   # guarded-by: _lock
        self._rows_buffered = 0                             # guarded-by: _lock
        self._index = _PkIndex()     # guarded-by: _lock — pk -> global row
        self._rows_total = 0                                # guarded-by: _lock
        self._seg_seq = 0            # guarded-by: _lock — file-name counter
        self._seg_files: List[str] = []                     # guarded-by: _lock
        self._seg_rows: List[int] = []                      # guarded-by: _lock
        self._seg_lineage: List[Lineage] = []               # guarded-by: _lock
        self._seg_zmaps: List[ZoneMap] = []                 # guarded-by: _lock
        self._seg_dead: List[int] = []   # guarded-by: _lock — dead/segment
        self._seg_level: List[int] = []  # guarded-by: _lock — merge generation
        self._chunk_dead = 0             # guarded-by: _lock — dead, buffered
        self._epoch = 0              # guarded-by: _lock — layout epoch
        self._pins = 0               # guarded-by: _lock — live snapshot views
        self._garbage: List[str] = []    # guarded-by: _lock — awaiting unpin
        self._manifest_dirty = False                        # guarded-by: _lock
        self._manifest_last_s = float("-inf")   # guarded-by: _lock
        self._lock = threading.Lock()           # lock-name: partition
        if spill_dir:
            os.makedirs(os.path.join(spill_dir, f"p{pid}"), exist_ok=True)

    # ------------------------------------------------------------- internals
    def _seg_path(self, fname: str) -> str:
        return os.path.join(self.spill_dir, f"p{self.pid}", fname)

    def _flushed_rows_locked(self) -> int:  # requires-lock: _lock
        return int(sum(self._seg_rows))

    def _note_dead_locked(self, old_rows: np.ndarray) -> None:  # requires-lock: _lock
        """Exact garbage accounting: ``old_rows`` are global positions
        whose row version just became superseded or deleted."""
        if old_rows.size == 0:
            return
        flushed = self._flushed_rows_locked()
        seg_side = old_rows[old_rows < flushed]
        self._chunk_dead += int(old_rows.shape[0] - seg_side.shape[0])
        if seg_side.size:
            bounds = np.cumsum(self._seg_rows)
            seg_of = np.searchsorted(bounds, seg_side, side="right")
            for s, c in zip(*np.unique(seg_of, return_counts=True)):
                self._seg_dead[int(s)] += int(c)

    # ---------------------------------------------------------------- writes
    def insert(self, batch: Dict[str, np.ndarray], upsert: bool,
               lineage: Optional[Lineage] = None,
               span_ids: Tuple[int, ...] = ()) -> int:
        """Insert valid rows; returns #rows newly stored (duplicates skipped
        in insert mode, remapped in upsert mode).  ``lineage`` is the ref
        versions the batch was enriched under, recorded per chunk;
        ``span_ids`` are the batch's trace stamps — buffered until the
        next flush so its ``store.flush`` span names the journeys it
        closed."""
        valid = batch["valid"]
        ids = np.asarray(batch["id"][valid], np.int64)
        if ids.size == 0:
            return 0
        with self._lock:
            if span_ids and self._obs is not None:
                self._pending_sids.extend(span_ids)
                if len(self._pending_sids) > 4096:
                    # bounded like the sample rings: drop oldest stamps
                    del self._pending_sids[:len(self._pending_sids) // 2]
            fresh_mask = ~self._index.contains(ids)
            take = np.ones(len(ids), bool) if upsert else fresh_mask
            if not take.any():
                return 0
            rows = {k: v[valid][take] for k, v in batch.items()}
            n = int(take.sum())
            base = self._rows_total
            if upsert:
                # positions this batch supersedes: previous versions of the
                # re-written pks (each counted once, however many times the
                # batch repeats the pk), plus within-batch duplicates — the
                # index keeps the last occurrence, so earlier copies of the
                # same pk in this chunk are dead on arrival
                uniq = np.unique(ids[take])
                old = self._index.lookup(uniq)
                self._note_dead_locked(old[old >= 0])
                self._chunk_dead += n - int(uniq.shape[0])
            self._index.put(ids[take], np.arange(base, base + n))
            self._append_locked(rows, n, lineage)
            stored = int((fresh_mask & take).sum())
        self._drain_flush_events()
        return stored

    def _append_locked(self,  # requires-lock: _lock
                       rows: Dict[str, np.ndarray], n: int,
                       lineage: Optional[Lineage]) -> None:
        self._chunks.append(rows)
        self._chunk_lineage.append(dict(lineage) if lineage else None)
        self._rows_buffered += n
        self._rows_total += n
        if self.spill_dir and self._rows_buffered >= self.segment_rows:
            self._flush_locked()

    def _flush_locked(self) -> None:  # requires-lock: _lock
        # feedlint: allow[blocking-under-lock] flush is atomic by design:
        # segment write + manifest + index update in one lock window
        if not self._chunks:
            return
        t_flush = time.perf_counter()
        seg = {k: np.concatenate([c[k] for c in self._chunks])
               for k in self._chunks[0]}
        n = int(seg["id"].shape[0])
        lo = self._flushed_rows_locked()
        if self.sort_key is not None and self.sort_key in seg:
            order = np.argsort(seg[self.sort_key], kind="stable")
            if not np.array_equal(order, np.arange(n)):
                seg = {k: v[order] for k, v in seg.items()}
                inv = np.empty(n, np.int64)
                inv[order] = np.arange(n)
                # pure permutation: positions move within [lo, lo+n) only,
                # so no epoch bump — a stale conditional check can never
                # spuriously match (the checked pk's OWN position moved)
                self._index.remap_span(lo, lo + n, lo + inv)
        fname = f"seg{self._seg_seq:06d}.npz"
        self._seg_seq += 1
        path = self._seg_path(fname)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:  # file handle: savez won't append ".npz"
            np.savez_compressed(f, **seg)
            f.flush()
            os.fsync(f.fileno())    # durable BEFORE the manifest cites it
        os.replace(tmp, path)       # atomic commit
        self._seg_files.append(fname)
        self._seg_rows.append(n)
        self._seg_lineage.append(merge_lineage(self._chunk_lineage))
        self._seg_zmaps.append(compute_zone_map(seg, self.zone_map_cols))
        self._seg_level.append(0)   # fresh flushes enter at level 0
        # exact recount for the new segment; buffered garbage moved with it
        live = self._index.lookup(seg["id"]) == np.arange(lo, lo + n)
        self._seg_dead.append(int(n - live.sum()))
        self._chunk_dead = 0
        self._write_manifest_locked()
        self._chunks = []
        self._chunk_lineage = []
        self._rows_buffered = 0
        if self._obs is not None:
            self._flush_events.append((n, time.perf_counter() - t_flush,
                                       tuple(self._pending_sids)))
            self._pending_sids.clear()

    def _write_manifest_locked(self) -> None:  # requires-lock: _lock
        # feedlint: allow[blocking-under-lock] manifest rewrite must be
        # consistent with the in-memory segment tables it snapshots
        man = self._seg_path("MANIFEST.json")
        # format history: 1 = counts only (seg_files/lineage implicit),
        # 2 = + per-segment lineage and zone maps, 3 = + per-segment
        # merge levels.  recover() reads all three; see docs/STORAGE.md.
        manifest = {"format": 3,
                    "segments": len(self._seg_files),
                    "rows": int(sum(self._seg_rows)),
                    "seq": self._seg_seq,
                    "seg_files": self._seg_files,
                    "seg_rows": self._seg_rows,
                    "lineage": self._seg_lineage,
                    "zone_maps": [
                        {k: [v[0], v[1]] for k, v in zm.items()}
                        for zm in self._seg_zmaps],
                    "levels": self._seg_level}
        with open(man + ".tmp", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())  # a crash must never leave a torn/empty
        if os.path.exists(man):   # manifest; the previous one survives as
            os.replace(man, man + ".bak")  # .bak for recover()'s fallback
        os.replace(man + ".tmp", man)
        fsync_dir(os.path.dirname(man))
        self._manifest_dirty = False
        self._manifest_last_s = time.monotonic()

    def _lineage_sync_locked(self) -> None:  # requires-lock: _lock
        """Durability for a lineage-only manifest change, throttled: repair
        advances segment lineage far more often than segments flush, and a
        JSON rewrite under the partition lock would stall concurrent
        ingest inserts — so at most one rewrite per LINEAGE_SYNC_S, the
        rest deferred to the next flush/sync (a crash in the window just
        re-probes: lineage only ever regresses to OLDER = stale = safe)."""
        if time.monotonic() - self._manifest_last_s >= self.LINEAGE_SYNC_S:
            self._write_manifest_locked()
        else:
            self._manifest_dirty = True

    def flush(self) -> None:
        if self.spill_dir:
            with self._lock:
                self._flush_locked()
                if self._manifest_dirty:
                    self._write_manifest_locked()
            self._drain_flush_events()

    def _drain_flush_events(self) -> None:
        """Publish queued flush telemetry with NO lock held.  Flushes
        that happen inside other lock windows (compaction's hazard
        flush) stay queued until the next public write/flush — late,
        never lost, never emitted under a core lock."""
        if self._obs is None:
            return
        with self._lock:
            if not self._flush_events:
                return
            events, self._flush_events = self._flush_events, []
        for n, dur, sids in events:
            self._flush_hist.observe(dur)
            self._obs.emit("store.flush", sids, t0=time.monotonic() - dur,
                           dur=dur, rows=n, partition=self.pid)

    def _load_manifest_locked(self) -> Optional[Dict]:
        # requires-lock: _lock
        # feedlint: allow[blocking-under-lock] cold-start manifest read
        """Load MANIFEST.json, falling back to the ``.bak`` predecessor
        when the current file is torn/empty (a pre-fsync-era crash, or a
        filesystem that reordered the rename).  Falling back one
        manifest is sound: every writer commits the new manifest BEFORE
        unlinking any segment file it dropped (see compact_segment), so
        a .bak's segment list is still fully on disk.  No manifest at
        all = fresh partition; an unreadable manifest with no readable
        .bak raises — silently recovering empty would drop data."""
        man = self._seg_path("MANIFEST.json")
        if not (os.path.exists(man) or os.path.exists(man + ".bak")):
            return None
        err: Optional[Exception] = None
        for path in (man, man + ".bak"):
            try:
                with open(path) as f:
                    doc = json.load(f)
                if isinstance(doc, dict) and "segments" in doc:
                    return doc
                err = err or ValueError(f"malformed manifest {path}")
            except (OSError, json.JSONDecodeError) as e:
                err = err or e
        raise RuntimeError(
            f"partition {self.pid}: MANIFEST.json unreadable and no "
            f"usable .bak fallback ({err})")

    def reset_lineage(self) -> None:
        """Recovery degrade path (core/recovery.py): when a restarted
        process's rebuilt ref tables don't fingerprint-match the
        checkpoint, recovered lineage versions are meaningless — reset
        every unit to ``{}`` (always-stale to the repair scheduler) so
        the feed re-scans everything rather than ever treating a row as
        silently current."""
        with self._lock:
            self._seg_lineage = [{} for _ in self._seg_files]
            self._chunk_lineage = [None] * len(self._chunks)
            if self.spill_dir and self._seg_files:
                self._write_manifest_locked()

    def recover(self) -> "StoragePartition":
        """Crash recovery: reload the manifested (durable) segments —
        counts, pk index, per-segment lineage, and zone maps; unflushed
        buffered chunks are, by definition, lost.  Pre-lineage and
        pre-zone-map manifests recover with empty lineage (always-stale to
        the repair scheduler) and no zone maps (never pruned) — both the
        safe side.  Dead-row counters are recomputed exactly from the
        rebuilt index."""
        if not self.spill_dir:
            raise RuntimeError("recover() requires spill_dir")
        # feedlint: allow[blocking-under-lock] cold-start reload: manifest
        # + segment reads happen before any concurrent user exists
        with self._lock:
            self._chunks, self._chunk_lineage = [], []
            self._rows_buffered = 0
            self._chunk_dead = 0
            self._index = _PkIndex()
            self._rows_total = 0
            self._seg_files, self._seg_rows = [], []
            self._seg_lineage, self._seg_zmaps, self._seg_dead = [], [], []
            self._seg_level = []
            manifest = self._load_manifest_locked()
            if manifest is None:
                return self
            nseg = int(manifest["segments"])
            files = manifest.get("seg_files") or \
                [f"seg{s:06d}.npz" for s in range(nseg)]
            lineage = manifest.get("lineage") or []
            zmaps = manifest.get("zone_maps") or []
            # format < 3 has no "levels": every segment recovers as
            # level 0, i.e. merge-eligible — the merge path then rebuilds
            # zone maps unconditionally, so legacy segments regain
            # pruning as they age
            levels = manifest.get("levels") or []
            seg_ids: List[np.ndarray] = []
            row = 0
            for s in range(nseg):
                with np.load(self._seg_path(files[s])) as seg:
                    ids = np.asarray(seg["id"], np.int64)
                n = int(ids.shape[0])
                self._index.put(ids, np.arange(row, row + n))
                seg_ids.append(ids)
                self._seg_files.append(files[s])
                self._seg_rows.append(n)
                self._seg_lineage.append(
                    dict(lineage[s]) if s < len(lineage) else {})
                self._seg_zmaps.append(
                    {k: (v[0], v[1]) for k, v in zmaps[s].items()}
                    if s < len(zmaps) else {})
                self._seg_level.append(
                    int(levels[s]) if s < len(levels) else 0)
                row += n
            self._seg_seq = int(manifest.get("seq", nseg))
            self._rows_total = row
            lo = 0
            for ids in seg_ids:
                n = ids.shape[0]
                live = self._index.lookup(ids) == np.arange(lo, lo + n)
                self._seg_dead.append(int(n - live.sum()))
                lo += n
        return self

    # ------------------------------------------------------------- snapshots
    def snapshot_view(self) -> PartitionSnapshot:
        """Pin and return a consistent view for the query subsystem: unit
        list, pk-index copy, and watermark under ONE lock acquisition.
        Chunks' arrays are immutable after append; segment files replaced
        by compaction stay on disk until the last pin releases."""
        with self._lock:
            self._pins += 1
            units: List[SnapshotUnit] = []
            base = 0
            for f, n, zm in zip(self._seg_files, self._seg_rows,
                                self._seg_zmaps):
                units.append(SnapshotUnit(base, n, path=self._seg_path(f),
                                          zone_map=zm or None))
                base += n
            for c in self._chunks:
                n = int(c["id"].shape[0])
                units.append(SnapshotUnit(base, n, chunk=c))
                base += n
            pks, rows = self._index.snapshot_arrays()
            return PartitionSnapshot(self, units, pks, rows,
                                     self._rows_total, self._epoch)

    def _unpin(self) -> None:
        with self._lock:
            self._pins -= 1
            if self._pins == 0:
                self._gc_locked()

    def _gc_locked(self) -> None:  # requires-lock: _lock
        # feedlint: allow[blocking-under-lock] unlink of replaced files;
        # must not race a concurrent compaction's swap
        for f in self._garbage:
            try:
                os.unlink(f)
            except OSError:
                pass
        self._garbage = []

    # ------------------------------------------------------------ compaction
    @property
    def epoch(self) -> int:
        with self._lock:
            return self._epoch

    @property
    def dead_rows(self) -> int:
        """Superseded/deleted row versions still occupying storage."""
        with self._lock:
            return int(sum(self._seg_dead)) + self._chunk_dead

    def garbage_units(self) -> List[Tuple[Optional[int], int, int]]:
        """Compaction candidates: ``(segment_index | None, rows, dead)``
        — one entry per flushed segment plus one (``None``) for the
        buffered chunks."""
        with self._lock:
            out: List[Tuple[Optional[int], int, int]] = [
                (s, n, d) for s, (n, d)
                in enumerate(zip(self._seg_rows, self._seg_dead))]
            out.append((None, self._rows_buffered, self._chunk_dead))
            return out

    def segment_stats(self) -> List[Tuple[int, int, int]]:
        """Merge-policy input: ``(rows, dead, level)`` per flushed
        segment, in segment order (the list index IS the segment index a
        subsequent ``merge_segments`` call takes — callers must tolerate
        rejection if the layout moved in between)."""
        with self._lock:
            return list(zip(self._seg_rows, self._seg_dead,
                            self._seg_level))

    def level_histogram(self) -> Dict[int, int]:
        """``{level: segment count}`` over the flushed segments."""
        with self._lock:
            hist: Dict[int, int] = {}
            for lv in self._seg_level:
                hist[lv] = hist.get(lv, 0) + 1
            return hist

    def compact_segment(self, si: int) -> int:
        """Rewrite flushed segment ``si`` without its superseded/deleted
        row versions and rebuild its zone maps; returns rows dropped.
        Runs entirely under the partition lock (decide + rewrite + swap in
        one atomic window — a budgeted background caller amortizes the
        stall; see core/compaction.py).  Renumbers the position space when
        rows drop, so the layout epoch bumps and in-flight conditional
        repairs against the old numbering are rejected, not misapplied.
        The replaced file is deleted once no snapshot pins remain.  A
        segment with no dead rows only refreshes missing zone maps."""
        # feedlint: allow[blocking-under-lock] deliberate: decide + rewrite
        # + swap in ONE lock window so the renumbering is atomic; the
        # caller (compaction.py) budgets the stall
        with self._lock:
            if not (0 <= si < len(self._seg_files)):
                raise IndexError(f"segment {si} out of range")
            path = self._seg_path(self._seg_files[si])
            with np.load(path) as f:
                seg = {k: f[k] for k in f.files}
            n = int(seg["id"].shape[0])
            lo = int(sum(self._seg_rows[:si]))
            pos = self._index.lookup(seg["id"])
            live = pos == np.arange(lo, lo + n)
            # a superseded version whose NEWER version still sits in a
            # buffered chunk (repair_rows re-appends at the tail) is the
            # row's only durable copy: flush inside this lock window
            # before physically dropping it, or a crash before the next
            # flush loses the row outright — its WAL frame was already
            # truncated by the checkpoint that made THIS version durable
            if bool((~live & (pos >= self._flushed_rows_locked())).any()):
                self._flush_locked()
            m = int(live.sum())
            if m == n:
                self._seg_dead[si] = 0
                if not self._seg_zmaps[si]:
                    self._seg_zmaps[si] = compute_zone_map(
                        seg, self.zone_map_cols)
                    self._write_manifest_locked()
                return 0
            if m == 0:
                # zero survivors: remove the segment entry outright (same
                # as a zero-survivor merge run).  Writing a 0-row segment
                # instead would wedge repair: lineage_units() would report
                # a permanently-stale empty unit that read_rows() cannot
                # return, so the unit never converges
                self._index.shift_from(lo + n, -n)
                del self._seg_files[si]
                del self._seg_rows[si]
                del self._seg_lineage[si]
                del self._seg_zmaps[si]
                del self._seg_dead[si]
                del self._seg_level[si]
                self._rows_total -= n
                self._epoch += 1
                self._write_manifest_locked()
                self._garbage.append(path)
                if self._pins == 0:
                    self._gc_locked()
                return n
            kept = {k: v[live] for k, v in seg.items()}
            fname = f"seg{self._seg_seq:06d}.npz"
            self._seg_seq += 1
            new_path = self._seg_path(fname)
            tmp = new_path + ".tmp"
            with open(tmp, "wb") as f:
                np.savez_compressed(f, **kept)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, new_path)
            # renumber: kept rows compact to [lo, lo+m); the suffix of the
            # position space shifts down.  Every index entry in the span
            # points at a live row by construction.
            new_abs = np.full(n, -1, np.int64)
            new_abs[live] = lo + np.arange(m)
            self._index.remap_span(lo, lo + n, new_abs)
            self._index.shift_from(lo + n, -(n - m))
            self._seg_files[si] = fname
            self._seg_rows[si] = m
            self._seg_zmaps[si] = compute_zone_map(kept, self.zone_map_cols)
            self._seg_dead[si] = 0
            self._rows_total -= n - m
            self._epoch += 1
            # manifest BEFORE dropping the old file: a crash in between
            # must never leave the manifest pointing at a deleted segment
            self._write_manifest_locked()
            self._garbage.append(path)
            if self._pins == 0:
                self._gc_locked()
            return n - m

    def merge_segments(self, si: int, count: int) -> Tuple[int, int]:
        """Merge ``count`` adjacent flushed segments [si, si+count) into
        ONE segment at level ``max(input levels) + 1``: drop dead row
        versions, re-sort the union on ``sort_key`` (clustered layout
        deepens as data ages — the INGESTBASE argument for ingestion-time
        layout), rebuild zone maps **unconditionally** (legacy format-2
        segments regain pruning here), and min-merge lineage (oldest
        wins, conservative for staleness).  Returns ``(rows_merged,
        rows_dropped)``.

        Concurrency contract mirrors ``compact_segment``: decide +
        rewrite + swap in one lock window; the layout epoch ALWAYS bumps
        (cross-segment re-sort renumbers positions even with zero dead
        rows), so in-flight conditional repairs are rejected wholesale
        and simply re-scan; the replaced files outlive any snapshot pin
        and the manifest commits before they are queued for GC."""
        # feedlint: allow[blocking-under-lock] deliberate, same shape as
        # compact_segment: the merge must be atomic w.r.t. renumbering;
        # the caller (compaction.py) budgets the stall
        with self._lock:
            if count < 2 or si < 0 or si + count > len(self._seg_files):
                raise IndexError(
                    f"merge [{si}, {si + count}) out of range "
                    f"({len(self._seg_files)} segments)")
            paths = [self._seg_path(f)
                     for f in self._seg_files[si:si + count]]
            parts: List[Dict[str, np.ndarray]] = []
            for p in paths:
                with np.load(p) as f:
                    parts.append({k: f[k] for k in f.files})
            keys = set(parts[0])
            for part in parts[1:]:
                keys &= set(part)
            merged = {k: np.concatenate([p[k] for p in parts])
                      for k in keys}
            n = int(merged["id"].shape[0])
            lo = int(sum(self._seg_rows[:si]))
            pos = self._index.lookup(merged["id"])
            live = pos == np.arange(lo, lo + n)
            # same hazard as compact_segment: never drop a superseded
            # durable version while its successor is still buffered —
            # flush first (position-preserving, so ``lo``/``live`` and
            # the [si, si+count) window stay valid; the new segments
            # land after it and are untouched by the splice below)
            if bool((~live & (pos >= self._flushed_rows_locked())).any()):
                self._flush_locked()
            m = int(live.sum())
            dropped = n - m
            level = max(self._seg_level[si:si + count]) + 1
            lin = merge_lineage(
                [dict(x) for x in self._seg_lineage[si:si + count]])
            if m == 0:
                # nothing lives: the merged segment would be empty — drop
                # the inputs outright instead of writing a 0-row file
                self._index.shift_from(lo + n, -n)
                del self._seg_files[si:si + count]
                del self._seg_rows[si:si + count]
                del self._seg_lineage[si:si + count]
                del self._seg_zmaps[si:si + count]
                del self._seg_dead[si:si + count]
                del self._seg_level[si:si + count]
            else:
                kept = {k: v[live] for k, v in merged.items()}
                # destination offset of each surviving input row: compact
                # to [0, m), then permute by the sort order
                dest = np.arange(m)
                if self.sort_key is not None and self.sort_key in kept:
                    order = np.argsort(kept[self.sort_key], kind="stable")
                    if not np.array_equal(order, np.arange(m)):
                        kept = {k: v[order] for k, v in kept.items()}
                        inv = np.empty(m, np.int64)
                        inv[order] = np.arange(m)
                        dest = inv
                fname = f"seg{self._seg_seq:06d}.npz"
                self._seg_seq += 1
                new_path = self._seg_path(fname)
                tmp = new_path + ".tmp"
                with open(tmp, "wb") as f:
                    np.savez_compressed(f, **kept)
                    f.flush()
                    os.fsync(f.fileno())    # durable BEFORE the manifest
                os.replace(tmp, new_path)
                new_abs = np.full(n, -1, np.int64)
                new_abs[live] = lo + dest
                self._index.remap_span(lo, lo + n, new_abs)
                self._index.shift_from(lo + n, -dropped)
                self._seg_files[si:si + count] = [fname]
                self._seg_rows[si:si + count] = [m]
                self._seg_lineage[si:si + count] = [lin]
                self._seg_zmaps[si:si + count] = [
                    compute_zone_map(kept, self.zone_map_cols)]
                self._seg_dead[si:si + count] = [0]
                self._seg_level[si:si + count] = [level]
            self._rows_total -= dropped
            self._epoch += 1
            # manifest BEFORE dropping the old files: a crash in between
            # must never leave the manifest citing a deleted segment
            self._write_manifest_locked()
            self._garbage.extend(paths)
            if self._pins == 0:
                self._gc_locked()
            return n, dropped

    def compact_chunks(self) -> int:
        """Drop superseded/deleted row versions from the buffered
        (unflushed) chunks — the whole story for spill-less in-memory
        partitions; returns rows dropped.  Merges the survivors into one
        chunk carrying the min-merged lineage (conservative, like flush)."""
        with self._lock:
            if self._chunk_dead == 0 or not self._chunks:
                return 0
            merged = {k: np.concatenate([c[k] for c in self._chunks])
                      for k in self._chunks[0]}
            n = int(merged["id"].shape[0])
            lo = self._flushed_rows_locked()
            live = self._index.lookup(merged["id"]) == \
                np.arange(lo, lo + n)
            m = int(live.sum())
            if m == n:
                self._chunk_dead = 0
                return 0
            kept = {k: v[live] for k, v in merged.items()}
            lin = merge_lineage(self._chunk_lineage)
            new_abs = np.full(n, -1, np.int64)
            new_abs[live] = lo + np.arange(m)
            self._index.remap_span(lo, lo + n, new_abs)
            self._chunks = [kept] if m else []
            self._chunk_lineage = [lin or None] if m else []
            self._rows_buffered = m
            self._rows_total -= n - m
            self._chunk_dead = 0
            self._epoch += 1
            return n - m

    def compact(self, min_dead_frac: float = 0.0) -> int:
        """Compact every unit whose dead fraction reaches
        ``min_dead_frac`` (0.0 = reclaim everything); returns rows
        dropped.  Synchronous; the background job budgets the same
        primitives instead."""
        dropped = 0
        # reversed: an all-dead segment is deleted outright, shifting
        # later indices — walking high-to-low keeps pending ones valid
        for si, rows, dead in reversed(self.garbage_units()):
            if rows == 0 or dead == 0 or dead / rows < min_dead_frac:
                continue
            dropped += (self.compact_chunks() if si is None
                        else self.compact_segment(si))
        return dropped

    # -------------------------------------------------------------- lineage
    def lineage_units(self) -> List[Tuple[int, int, Lineage]]:
        """Snapshot of storage units for the repair scheduler: a list of
        ``(start_row, rows, lineage)`` covering flushed segments then
        buffered chunks, in global row order.  Unversioned chunks surface
        as ``{}`` (always stale when consulted)."""
        with self._lock:
            units: List[Tuple[int, int, Lineage]] = []
            cum = 0
            for r, lin in zip(self._seg_rows, self._seg_lineage):
                # skip 0-row segments (possible in legacy manifests):
                # an empty unit can never be read back, so surfacing it
                # would hand the repair scheduler unconvergeable work
                if r:
                    units.append((cum, r, dict(lin)))
                cum += r
            for c, lin in zip(self._chunks, self._chunk_lineage):
                r = int(c["id"].shape[0])
                units.append((cum, r, dict(lin) if lin else {}))
                cum += r
            return units

    def update_lineage(self, start_row: int, rows: int,
                       lineage: Lineage,
                       expect_epoch: Optional[int] = None) -> bool:
        """Advance one unit's lineage (per-table max) after the repair
        scheduler proved its rows current — e.g. a dirty-key probe matched
        nothing.  No-op (returns False) when the unit boundary no longer
        exists (it was flushed and merged into a segment mid-scan) or the
        layout epoch moved (compaction renumbered: the 'same' boundary may
        now cover different rows): the unit keeps its old lineage, stays
        stale, and is simply re-scanned — the conditional repair path
        makes that idempotent."""
        with self._lock:
            if expect_epoch is not None and expect_epoch != self._epoch:
                return False
            cum = 0
            for i, r in enumerate(self._seg_rows):
                if cum == start_row and r == rows:
                    self._seg_lineage[i] = {
                        t: max(self._seg_lineage[i].get(t, -1), v)
                        for t, v in lineage.items()}
                    self._lineage_sync_locked()
                    return True
                cum += r
            for i, c in enumerate(self._chunks):
                r = int(c["id"].shape[0])
                if cum == start_row and r == rows:
                    old = self._chunk_lineage[i] or {}
                    self._chunk_lineage[i] = {
                        t: max(old.get(t, -1), v)
                        for t, v in lineage.items()}
                    return True
                cum += r
            return False

    def read_rows(self, start: int, n: int) -> Dict[str, np.ndarray]:
        """Columns for global rows [start, start+n) — from disk segments
        and/or buffered chunks.  The span list AND the segment file names
        are captured under the lock, and the partition stays pinned for
        the duration, so the read is consistent even while a concurrent
        compaction replaces files (their content outlives the pin)."""
        with self._lock:
            self._pins += 1
            spans = [(self._seg_path(f), r) for f, r
                     in zip(self._seg_files, self._seg_rows)]
            chunks = list(self._chunks)
        try:
            parts: List[Dict[str, np.ndarray]] = []
            end = start + n
            cum = 0
            for path, r in spans:
                lo, hi = cum, cum + r
                cum += r
                if hi <= start or lo >= end:
                    continue
                with np.load(path) as seg:
                    a, b = max(start - lo, 0), min(end, hi) - lo
                    parts.append({k: seg[k][a:b] for k in seg.files})
            for c in chunks:
                r = int(c["id"].shape[0])
                lo, hi = cum, cum + r
                cum += r
                if hi <= start or lo >= end:
                    continue
                a, b = max(start - lo, 0), min(end, hi) - lo
                parts.append({k: v[a:b] for k, v in c.items()})
            if not parts:
                raise IndexError(f"rows [{start}, {end}) out of range")
            if len(parts) == 1:
                return parts[0]
            return {k: np.concatenate([p[k] for p in parts])
                    for k in parts[0]}
        finally:
            self._unpin()

    def repair_rows(self, batch: Dict[str, np.ndarray],
                    global_rows: np.ndarray,
                    lineage: Optional[Lineage],
                    expect_epoch: Optional[int] = None) -> int:
        """In-place upsert of re-enriched rows, exactly-once under
        concurrent ingestion: a row is applied only if the pk index still
        points at the global row it was scanned from — a concurrent ingest
        upsert (which remapped the pk) always wins, and a repeated scan of
        the same unit is a no-op.  ``expect_epoch`` extends the guarantee
        across compaction: after a renumbering, freed position numbers can
        be reused, so the positional check alone could spuriously match —
        an epoch mismatch rejects the whole batch (the unit stays stale
        and is re-scanned).  Returns #rows actually repaired."""
        ids = np.asarray(batch["id"], np.int64)
        if ids.size == 0:
            return 0
        with self._lock:
            if expect_epoch is not None and expect_epoch != self._epoch:
                return 0
            live = self._index.lookup(ids) == np.asarray(global_rows,
                                                         np.int64)
            if not live.any():
                return 0
            rows = {k: v[live] for k, v in batch.items()}
            n = int(live.sum())
            base = self._rows_total
            self._note_dead_locked(
                np.asarray(global_rows, np.int64)[live])
            self._index.put(ids[live], np.arange(base, base + n))
            self._append_locked(rows, n, lineage)
        self._drain_flush_events()
        return n

    def delete_rows(self, ids: np.ndarray, global_rows: np.ndarray,
                    expect_epoch: Optional[int] = None) -> int:
        """Conditionally delete rows (repair filter-deletes): a pk is
        removed from the index only if it still points at the global row
        it was scanned from, so a concurrent ingest upsert always wins and
        re-scans are no-ops — the same exactly-once contract as
        ``repair_rows``, epoch check included.  The row versions become
        dead storage, reclaimed by compaction.  Returns #rows deleted."""
        ids = np.asarray(ids, np.int64)
        if ids.size == 0:
            return 0
        with self._lock:
            if expect_epoch is not None and expect_epoch != self._epoch:
                return 0
            live = self._index.lookup(ids) == np.asarray(global_rows,
                                                         np.int64)
            if not live.any():
                return 0
            self._note_dead_locked(
                np.asarray(global_rows, np.int64)[live])
            return self._index.remove(ids[live])

    @property
    def count(self) -> int:
        with self._lock:
            return len(self._index)

    @property
    def rows_total(self) -> int:
        """All stored row versions, including logically superseded ones
        (shrinks when compaction reclaims them)."""
        with self._lock:
            return self._rows_total

    def scan(self):
        """Yield column chunks (flushed segments read back from disk, then
        buffered chunks).  Superseded row versions still appear; without a
        ``sort_key`` they resolve by 'latest occurrence wins' in scan
        order, but the exact contract — deletes included — is the pk
        index, i.e. ``snapshot_view()``/the query subsystem."""
        with self._lock:
            self._pins += 1
            paths = [self._seg_path(f) for f in self._seg_files]
            chunks = list(self._chunks)
        try:
            for path in paths:
                with np.load(path) as seg:
                    yield {k: seg[k] for k in seg.files}
            yield from chunks
        finally:
            self._unpin()

    def get(self, pk: int) -> Optional[Dict[str, Any]]:
        seg_path = None
        with self._lock:
            row = self._index.get(int(pk))
            if row is None:
                return None
            # locate the row across flushed segments + buffered chunks
            offset = self._rows_total - sum(
                c["id"].shape[0] for c in self._chunks)
            if row >= offset:
                r = row - offset
                for c in self._chunks:
                    if r < c["id"].shape[0]:
                        return {k: v[r] for k, v in c.items()}
                    r -= c["id"].shape[0]
            if not self.spill_dir:
                return None
            r = row
            for fname, n in zip(self._seg_files, self._seg_rows):
                if r < n:
                    seg_path = self._seg_path(fname)
                    break
                r -= n
            if seg_path is None:
                return None
            # pin like scan()/read_rows(): the segment decompress happens
            # OUTSIDE the partition lock, and the pin keeps the file on
            # disk if compaction replaces it mid-read (feedlint R3 found
            # the old version holding the lock across np.load).
            self._pins += 1
        try:
            with np.load(seg_path) as seg:
                return {k: seg[k][r] for k in seg.files}
        finally:
            self._unpin()


class StorageJob:
    """Hash partitioner + P column-store partitions (paper Fig 23's Storage
    Partition Holder feeds this through an active holder — see feed.py)."""

    def __init__(self, num_partitions: int, spill_dir: Optional[str] = None,
                 upsert: bool = False, segment_rows: int = 100_000,
                 zone_map_cols: Optional[Tuple[str, ...]] = None,
                 sort_key: Optional[str] = None, obs=None):
        self.partitions = [StoragePartition(i, spill_dir, segment_rows,
                                            zone_map_cols, sort_key, obs=obs)
                           for i in range(num_partitions)]
        self.upsert = upsert
        # counters are write-guarded: mutated under the stats lock by
        # concurrent holder workers, read lock-free after join/drain
        self.stored = 0          # write-guarded-by: _lock
        self.batches = 0         # write-guarded-by: _lock — write() calls
        self.write_s = 0.0       # write-guarded-by: _lock
        # per-unit read tallies from the query layer ((pid, unit tag) ->
        # count; the PIQUE roadmap item's access-frequency signal)
        self._seg_reads: Dict[Tuple[int, str], int] = {}  # guarded-by: _lock
        self._lock = threading.Lock()    # lock-name: store-stats

    def write(self, batch: Dict[str, np.ndarray],
              lineage: Optional[Lineage] = None,
              span_ids: Tuple[int, ...] = ()) -> int:
        """Hash-partition one enriched batch by primary key and insert.
        The batch may be shared with other sinks of the same plan (tee
        fan-out): treated as read-only — rows are masked into fresh arrays,
        never mutated in place.  ``lineage`` is the ref-version tuple the
        batch was enriched under (recorded per stored chunk); ``span_ids``
        are the batch's trace stamps, threaded to each touched partition
        so its next ``store.flush`` span carries them."""
        t0 = time.perf_counter()
        npart = len(self.partitions)
        part = (batch["id"] % npart).astype(np.int64)
        stored = 0
        for p in range(npart):
            m = (part == p) & batch["valid"]
            if not m.any():
                continue
            sub = {k: v[m] for k, v in batch.items()}
            sub["valid"] = np.ones(int(m.sum()), bool)
            stored += self.partitions[p].insert(sub, self.upsert, lineage,
                                                span_ids=span_ids)
        with self._lock:
            self.stored += stored
            self.batches += 1
            self.write_s += time.perf_counter() - t0
        return stored

    @property
    def count(self) -> int:
        return sum(p.count for p in self.partitions)

    @property
    def dead_rows(self) -> int:
        return sum(p.dead_rows for p in self.partitions)

    @property
    def rows_total(self) -> int:
        return sum(p.rows_total for p in self.partitions)

    @property
    def segment_count(self) -> int:
        """Flushed segments across all partitions (the per-unit scan
        overhead the merge policy exists to shrink)."""
        return sum(len(p.segment_stats()) for p in self.partitions)

    def level_histogram(self) -> Dict[int, int]:
        """``{level: segment count}`` across all partitions — level 0 is
        fresh flushes, level k+1 holds merges of level-<=k segments."""
        hist: Dict[int, int] = {}
        for p in self.partitions:
            for lv, c in p.level_histogram().items():
                hist[lv] = hist.get(lv, 0) + c
        return hist

    def note_unit_reads(self, items) -> None:
        """Record per-unit read counts from a query execution.  The query
        layer tallies locally per ``execute()`` and publishes here ONCE,
        outside every scan lock, so the hot per-unit loop never touches
        this lock."""
        with self._lock:
            for key, n in items:
                self._seg_reads[key] = self._seg_reads.get(key, 0) + n

    def segment_read_counts(self) -> Dict[Tuple[int, str], int]:
        """``(partition, unit tag) -> reads`` since startup — how often
        each segment/chunk was scanned by the query subsystem (the
        access-frequency input a PIQUE-style adaptive layout needs)."""
        with self._lock:
            return dict(self._seg_reads)

    def scan(self):
        for p in self.partitions:
            yield from p.scan()

    def get(self, pk: int) -> Optional[Dict[str, Any]]:
        return self.partitions[int(pk) % len(self.partitions)].get(pk)

    def flush(self) -> None:
        for p in self.partitions:
            p.flush()

    def compact(self, min_dead_frac: float = 0.0) -> int:
        """Synchronously reclaim superseded/deleted row versions across
        every partition; returns rows dropped (the background job in
        core/compaction.py budgets the same primitives instead)."""
        return sum(p.compact(min_dead_frac) for p in self.partitions)

    def query(self) -> "Query":  # noqa: F821 (forward ref, lazy import)
        """Entry point of the analytical query subsystem: a composable
        ``Query`` builder over a snapshot-consistent view of this store
        (see core/query.py)."""
        from repro.core.query import Query
        return Query(self)

    def snapshot(self) -> "StoreSnapshot":  # noqa: F821
        from repro.core.query import StoreSnapshot
        return StoreSnapshot(self)

    def recover(self) -> "StorageJob":
        for p in self.partitions:
            p.recover()
        return self

    def reset_lineage(self) -> None:
        """All units in every partition -> always-stale (see
        StoragePartition.reset_lineage)."""
        for p in self.partitions:
            p.reset_lineage()
