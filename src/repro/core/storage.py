"""The storage job (§6.2, §7.2): hash-partition enriched records by primary
key and append them to partitioned column stores.

Idempotence: each partition keeps a primary-key index; re-written keys are
skipped (insert mode) or replace the previous row logically (upsert mode).
With the feed manager's at-least-once batch retry this yields exactly-once
*storage* semantics — the property the hypothesis tests pin down.  The
index is a sorted pair of numpy arrays (pk, latest global row): membership
is one vectorized ``searchsorted`` probe and updates are bulk merges, so
the per-batch insert path has no per-row Python loop.

Durability: partitions buffer columns in memory and flush immutable
``.npz`` segments plus a JSON manifest (atomic rename) when ``spill_dir``
is set — an LSM-flavored, crash-consistent layout; ``recover()`` reloads
manifested segments after a crash.

Lineage (core/repair.py): every appended chunk — and, after flush, every
segment — records the **reference-version lineage** its rows were enriched
under (``{table: RefTable.version}`` as of the computing job's snapshot).
The manifest persists per-segment lineage so ``recover()`` restores it,
and the repair scheduler compares it against current table versions to
find stale rows.  Repairs are in-place upserts with a conditional index
check (``repair_rows``): a row is only remapped if its index entry still
points at the scanned position, so a concurrent ingest upsert always wins
and re-scans are idempotent — exactly-once repair under live ingestion.
Global row positions are stable (append-only; flush moves bytes, never
positions), which is what makes (start_row, rows) a durable unit identity.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import nputil

Lineage = Dict[str, int]          # ref table name -> version enriched under


def merge_lineage(lineages: List[Optional[Lineage]]) -> Lineage:
    """Combine chunk lineages into one segment lineage, per-table **min**
    (oldest wins): conservative for staleness — a merged segment is checked
    against the oldest version any of its rows might carry.  A ``None``
    (unversioned) member or a table missing from any member drops the
    table, which the repair scheduler treats as always-stale."""
    if not lineages or any(lin is None for lin in lineages):
        return {}
    tables = set(lineages[0])
    for lin in lineages[1:]:
        tables &= set(lin)
    return {t: min(lin[t] for lin in lineages) for t in tables}


class _PkIndex:
    """Sorted-array primary-key index: pk -> latest global row.

    Replaces the former dict + per-row Python loops on the hot storage
    path: membership is one ``np.searchsorted`` probe over the batch
    (``nputil.sorted_find``), updates are a bulk in-place overwrite plus
    one ``np.insert`` merge (O(index) memmove in C, amortized fine at
    segment scale)."""

    __slots__ = ("_pks", "_rows")

    def __init__(self):
        self._pks = np.empty(0, np.int64)
        self._rows = np.empty(0, np.int64)

    def __len__(self) -> int:
        return int(self._pks.shape[0])

    def contains(self, ids: np.ndarray) -> np.ndarray:
        return nputil.sorted_find(self._pks,
                                  np.asarray(ids, np.int64))[0]

    def lookup(self, ids: np.ndarray) -> np.ndarray:
        """Latest global row per id, -1 where absent."""
        ids = np.asarray(ids, np.int64)
        found, loc, _ = nputil.sorted_find(self._pks, ids)
        out = np.full(ids.shape[0], -1, np.int64)
        out[found] = self._rows[loc[found]]
        return out

    def get(self, pk: int) -> Optional[int]:
        row = self.lookup(np.asarray([pk], np.int64))[0]
        return None if row < 0 else int(row)

    def put(self, ids: np.ndarray, rows: np.ndarray) -> None:
        """Map each id to its row; within the batch the LAST occurrence
        wins (matches append order: later rows supersede earlier)."""
        ids = np.asarray(ids, np.int64)
        if ids.size == 0:
            return
        uniq, last = nputil.keep_last(ids)
        rows_u = np.asarray(rows, np.int64)[last]
        found, loc, pos = nputil.sorted_find(self._pks, uniq)
        self._rows[loc[found]] = rows_u[found]
        new = ~found
        if new.any():
            self._pks = np.insert(self._pks, pos[new], uniq[new])
            self._rows = np.insert(self._rows, pos[new], rows_u[new])


class StoragePartition:
    # deferred-durability window for repair's lineage advances: the
    # manifest rewrite (JSON + rename, under the partition lock) happens
    # at most once per this many seconds outside of flushes — a crash in
    # the window only regresses lineage to an OLDER version, which the
    # repair scheduler treats as stale and safely re-probes
    LINEAGE_SYNC_S = 1.0

    def __init__(self, pid: int, spill_dir: Optional[str] = None,
                 segment_rows: int = 100_000):
        self.pid = pid
        self.spill_dir = spill_dir
        self.segment_rows = segment_rows
        self._chunks: List[Dict[str, np.ndarray]] = []
        self._chunk_lineage: List[Optional[Lineage]] = []
        self._rows_buffered = 0
        self._index = _PkIndex()     # pk -> global row (latest wins)
        self._rows_total = 0
        self._segments = 0
        self._seg_rows: List[int] = []
        self._seg_lineage: List[Lineage] = []
        self._manifest_dirty = False
        self._manifest_last_s = float("-inf")   # first lineage write is
        self._lock = threading.Lock()           # immediate, then throttled
        if spill_dir:
            os.makedirs(os.path.join(spill_dir, f"p{pid}"), exist_ok=True)

    def insert(self, batch: Dict[str, np.ndarray], upsert: bool,
               lineage: Optional[Lineage] = None) -> int:
        """Insert valid rows; returns #rows newly stored (duplicates skipped
        in insert mode, remapped in upsert mode).  ``lineage`` is the ref
        versions the batch was enriched under, recorded per chunk."""
        valid = batch["valid"]
        ids = np.asarray(batch["id"][valid], np.int64)
        if ids.size == 0:
            return 0
        with self._lock:
            fresh_mask = ~self._index.contains(ids)
            take = np.ones(len(ids), bool) if upsert else fresh_mask
            if not take.any():
                return 0
            rows = {k: v[valid][take] for k, v in batch.items()}
            n = int(take.sum())
            base = self._rows_total
            self._index.put(ids[take], np.arange(base, base + n))
            self._append_locked(rows, n, lineage)
            return int((fresh_mask & take).sum())

    def _append_locked(self, rows: Dict[str, np.ndarray], n: int,
                       lineage: Optional[Lineage]) -> None:
        self._chunks.append(rows)
        self._chunk_lineage.append(dict(lineage) if lineage else None)
        self._rows_buffered += n
        self._rows_total += n
        if self.spill_dir and self._rows_buffered >= self.segment_rows:
            self._flush_locked()

    def _flush_locked(self) -> None:
        if not self._chunks:
            return
        seg = {k: np.concatenate([c[k] for c in self._chunks])
               for k in self._chunks[0]}
        path = os.path.join(self.spill_dir, f"p{self.pid}",
                            f"seg{self._segments:06d}.npz")
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:  # file handle: savez won't append ".npz"
            np.savez_compressed(f, **seg)
        os.replace(tmp, path)       # atomic commit
        self._segments += 1
        self._seg_rows.append(int(seg["id"].shape[0]))
        self._seg_lineage.append(merge_lineage(self._chunk_lineage))
        self._write_manifest_locked()
        self._chunks = []
        self._chunk_lineage = []
        self._rows_buffered = 0

    def _write_manifest_locked(self) -> None:
        man = os.path.join(self.spill_dir, f"p{self.pid}", "MANIFEST.json")
        manifest = {"segments": self._segments,
                    "rows": int(sum(self._seg_rows)),
                    "seg_rows": self._seg_rows,
                    "lineage": self._seg_lineage}
        with open(man + ".tmp", "w") as f:
            json.dump(manifest, f)
        os.replace(man + ".tmp", man)
        self._manifest_dirty = False
        self._manifest_last_s = time.monotonic()

    def _lineage_sync_locked(self) -> None:
        """Durability for a lineage-only manifest change, throttled: repair
        advances segment lineage far more often than segments flush, and a
        JSON rewrite under the partition lock would stall concurrent
        ingest inserts — so at most one rewrite per LINEAGE_SYNC_S, the
        rest deferred to the next flush/sync (a crash in the window just
        re-probes: lineage only ever regresses to OLDER = stale = safe)."""
        if time.monotonic() - self._manifest_last_s >= self.LINEAGE_SYNC_S:
            self._write_manifest_locked()
        else:
            self._manifest_dirty = True

    def flush(self) -> None:
        if self.spill_dir:
            with self._lock:
                self._flush_locked()
                if self._manifest_dirty:
                    self._write_manifest_locked()

    def recover(self) -> "StoragePartition":
        """Crash recovery: reload the manifested (durable) segments —
        counts, pk index, and per-segment lineage; unflushed buffered
        chunks are, by definition, lost.  Pre-lineage manifests recover
        with empty lineage (treated always-stale by the repair scheduler:
        safe, since repair is idempotent)."""
        if not self.spill_dir:
            raise RuntimeError("recover() requires spill_dir")
        with self._lock:
            self._chunks, self._chunk_lineage = [], []
            self._rows_buffered = 0
            self._index = _PkIndex()
            self._segments, self._rows_total = 0, 0
            self._seg_rows, self._seg_lineage = [], []
            man = os.path.join(self.spill_dir, f"p{self.pid}",
                               "MANIFEST.json")
            if not os.path.exists(man):
                return self
            with open(man) as f:
                manifest = json.load(f)
            nseg = int(manifest["segments"])
            lineage = manifest.get("lineage") or []
            row = 0
            for s in range(nseg):
                seg = np.load(os.path.join(self.spill_dir, f"p{self.pid}",
                                           f"seg{s:06d}.npz"))
                n = int(seg["id"].shape[0])
                self._index.put(np.asarray(seg["id"], np.int64),
                                np.arange(row, row + n))
                self._seg_rows.append(n)
                self._seg_lineage.append(
                    dict(lineage[s]) if s < len(lineage) else {})
                row += n
            self._segments = nseg
            self._rows_total = row
        return self

    # -------------------------------------------------------------- lineage
    def lineage_units(self) -> List[Tuple[int, int, Lineage]]:
        """Snapshot of storage units for the repair scheduler: a list of
        ``(start_row, rows, lineage)`` covering flushed segments then
        buffered chunks, in global row order.  Unversioned chunks surface
        as ``{}`` (always stale when consulted)."""
        with self._lock:
            units: List[Tuple[int, int, Lineage]] = []
            cum = 0
            for r, lin in zip(self._seg_rows, self._seg_lineage):
                units.append((cum, r, dict(lin)))
                cum += r
            for c, lin in zip(self._chunks, self._chunk_lineage):
                r = int(c["id"].shape[0])
                units.append((cum, r, dict(lin) if lin else {}))
                cum += r
            return units

    def update_lineage(self, start_row: int, rows: int,
                       lineage: Lineage) -> bool:
        """Advance one unit's lineage (per-table max) after the repair
        scheduler proved its rows current — e.g. a dirty-key probe matched
        nothing.  No-op (returns False) when the unit boundary no longer
        exists (it was flushed and merged into a segment mid-scan): the
        merged segment keeps its conservative min-lineage and is simply
        re-scanned, which the conditional repair path makes idempotent."""
        with self._lock:
            cum = 0
            for i, r in enumerate(self._seg_rows):
                if cum == start_row and r == rows:
                    self._seg_lineage[i] = {
                        t: max(self._seg_lineage[i].get(t, -1), v)
                        for t, v in lineage.items()}
                    self._lineage_sync_locked()
                    return True
                cum += r
            for i, c in enumerate(self._chunks):
                r = int(c["id"].shape[0])
                if cum == start_row and r == rows:
                    old = self._chunk_lineage[i] or {}
                    self._chunk_lineage[i] = {
                        t: max(old.get(t, -1), v)
                        for t, v in lineage.items()}
                    return True
                cum += r
            return False

    def read_rows(self, start: int, n: int) -> Dict[str, np.ndarray]:
        """Columns for global rows [start, start+n) — from disk segments
        and/or buffered chunks.  Positions are append-stable, so a unit
        snapshot stays readable across a concurrent flush."""
        with self._lock:
            seg_rows = list(self._seg_rows)
            chunks = list(self._chunks)
        parts: List[Dict[str, np.ndarray]] = []
        end = start + n
        cum = 0
        for s, r in enumerate(seg_rows):
            lo, hi = cum, cum + r
            cum += r
            if hi <= start or lo >= end:
                continue
            seg = np.load(os.path.join(self.spill_dir, f"p{self.pid}",
                                       f"seg{s:06d}.npz"))
            a, b = max(start - lo, 0), min(end, hi) - lo
            parts.append({k: seg[k][a:b] for k in seg.files})
        for c in chunks:
            r = int(c["id"].shape[0])
            lo, hi = cum, cum + r
            cum += r
            if hi <= start or lo >= end:
                continue
            a, b = max(start - lo, 0), min(end, hi) - lo
            parts.append({k: v[a:b] for k, v in c.items()})
        if not parts:
            raise IndexError(f"rows [{start}, {end}) out of range")
        if len(parts) == 1:
            return parts[0]
        return {k: np.concatenate([p[k] for p in parts])
                for k in parts[0]}

    def repair_rows(self, batch: Dict[str, np.ndarray],
                    global_rows: np.ndarray,
                    lineage: Optional[Lineage]) -> int:
        """In-place upsert of re-enriched rows, exactly-once under
        concurrent ingestion: a row is applied only if the pk index still
        points at the global row it was scanned from — a concurrent ingest
        upsert (which remapped the pk) always wins, and a repeated scan of
        the same unit is a no-op.  Returns #rows actually repaired."""
        ids = np.asarray(batch["id"], np.int64)
        if ids.size == 0:
            return 0
        with self._lock:
            live = self._index.lookup(ids) == np.asarray(global_rows,
                                                         np.int64)
            if not live.any():
                return 0
            rows = {k: v[live] for k, v in batch.items()}
            n = int(live.sum())
            base = self._rows_total
            self._index.put(ids[live], np.arange(base, base + n))
            self._append_locked(rows, n, lineage)
            return n

    @property
    def count(self) -> int:
        with self._lock:
            return len(self._index)

    @property
    def rows_total(self) -> int:
        """All appended rows, including logically superseded versions."""
        with self._lock:
            return self._rows_total

    def scan(self):
        """Yield buffered column chunks (analytical-query surface; flushed
        segments are read back from disk).  Superseded row versions still
        appear — in global row order, so 'latest occurrence wins' resolves
        them exactly like the pk index does."""
        with self._lock:
            chunks = list(self._chunks)
            nseg = self._segments
        for s in range(nseg):
            seg = np.load(os.path.join(self.spill_dir, f"p{self.pid}",
                                       f"seg{s:06d}.npz"))
            yield {k: seg[k] for k in seg.files}
        yield from chunks

    def get(self, pk: int) -> Optional[Dict[str, Any]]:
        with self._lock:
            row = self._index.get(int(pk))
            if row is None:
                return None
            # locate the row across flushed segments + buffered chunks
            offset = self._rows_total - sum(
                c["id"].shape[0] for c in self._chunks)
            if row >= offset:
                r = row - offset
                for c in self._chunks:
                    if r < c["id"].shape[0]:
                        return {k: v[r] for k, v in c.items()}
                    r -= c["id"].shape[0]
            if not self.spill_dir:
                return None
            r = row
            for s in range(self._segments):
                seg = np.load(os.path.join(
                    self.spill_dir, f"p{self.pid}", f"seg{s:06d}.npz"))
                n = seg["id"].shape[0]
                if r < n:
                    return {k: seg[k][r] for k in seg.files}
                r -= n
            return None


class StorageJob:
    """Hash partitioner + P column-store partitions (paper Fig 23's Storage
    Partition Holder feeds this through an active holder — see feed.py)."""

    def __init__(self, num_partitions: int, spill_dir: Optional[str] = None,
                 upsert: bool = False, segment_rows: int = 100_000):
        self.partitions = [StoragePartition(i, spill_dir, segment_rows)
                           for i in range(num_partitions)]
        self.upsert = upsert
        self.stored = 0
        self.batches = 0         # write() calls — exactly-once fan-out tests
        self.write_s = 0.0
        self._lock = threading.Lock()

    def write(self, batch: Dict[str, np.ndarray],
              lineage: Optional[Lineage] = None) -> int:
        """Hash-partition one enriched batch by primary key and insert.
        The batch may be shared with other sinks of the same plan (tee
        fan-out): treated as read-only — rows are masked into fresh arrays,
        never mutated in place.  ``lineage`` is the ref-version tuple the
        batch was enriched under (recorded per stored chunk)."""
        t0 = time.perf_counter()
        npart = len(self.partitions)
        part = (batch["id"] % npart).astype(np.int64)
        stored = 0
        for p in range(npart):
            m = (part == p) & batch["valid"]
            if not m.any():
                continue
            sub = {k: v[m] for k, v in batch.items()}
            sub["valid"] = np.ones(int(m.sum()), bool)
            stored += self.partitions[p].insert(sub, self.upsert, lineage)
        with self._lock:
            self.stored += stored
            self.batches += 1
            self.write_s += time.perf_counter() - t0
        return stored

    @property
    def count(self) -> int:
        return sum(p.count for p in self.partitions)

    def scan(self):
        for p in self.partitions:
            yield from p.scan()

    def get(self, pk: int) -> Optional[Dict[str, Any]]:
        return self.partitions[int(pk) % len(self.partitions)].get(pk)

    def flush(self) -> None:
        for p in self.partitions:
            p.flush()

    def recover(self) -> "StorageJob":
        for p in self.partitions:
            p.recover()
        return self
