"""The storage job (§6.2, §7.2): hash-partition enriched records by primary
key and append them to partitioned column stores.

Idempotence: each partition keeps a primary-key index; re-written keys are
skipped (insert mode) or replace the previous row logically (upsert mode).
With the feed manager's at-least-once batch retry this yields exactly-once
*storage* semantics — the property the hypothesis tests pin down.

Durability: partitions buffer columns in memory and flush immutable
``.npz`` segments plus a JSON manifest (atomic rename) when ``spill_dir``
is set — an LSM-flavored, crash-consistent layout; ``recover()`` reloads
manifested segments after a crash.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

import numpy as np


class StoragePartition:
    def __init__(self, pid: int, spill_dir: Optional[str] = None,
                 segment_rows: int = 100_000):
        self.pid = pid
        self.spill_dir = spill_dir
        self.segment_rows = segment_rows
        self._chunks: List[Dict[str, np.ndarray]] = []
        self._rows_buffered = 0
        self._index: Dict[int, int] = {}    # pk -> global row (latest wins)
        self._rows_total = 0
        self._segments = 0
        self._lock = threading.Lock()
        if spill_dir:
            os.makedirs(os.path.join(spill_dir, f"p{pid}"), exist_ok=True)

    def insert(self, batch: Dict[str, np.ndarray], upsert: bool) -> int:
        """Insert valid rows; returns #rows newly stored (duplicates skipped
        in insert mode, remapped in upsert mode)."""
        valid = batch["valid"]
        ids = batch["id"][valid]
        if ids.size == 0:
            return 0
        with self._lock:
            fresh_mask = np.fromiter(
                (int(i) not in self._index for i in ids), bool, len(ids))
            take = np.ones(len(ids), bool) if upsert else fresh_mask
            if not take.any():
                return 0
            rows = {k: v[valid][take] for k, v in batch.items()}
            base = self._rows_total
            for j, pk in enumerate(ids[take]):
                self._index[int(pk)] = base + j
            n = int(take.sum())
            self._chunks.append(rows)
            self._rows_buffered += n
            self._rows_total += n
            stored_new = int((fresh_mask & take).sum())
            if self.spill_dir and self._rows_buffered >= self.segment_rows:
                self._flush_locked()
            return stored_new

    def _flush_locked(self) -> None:
        if not self._chunks:
            return
        seg = {k: np.concatenate([c[k] for c in self._chunks])
               for k in self._chunks[0]}
        path = os.path.join(self.spill_dir, f"p{self.pid}",
                            f"seg{self._segments:06d}.npz")
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:  # file handle: savez won't append ".npz"
            np.savez_compressed(f, **seg)
        os.replace(tmp, path)       # atomic commit
        man = os.path.join(self.spill_dir, f"p{self.pid}", "MANIFEST.json")
        manifest = {"segments": self._segments + 1,
                    "rows": self._rows_total - self._rows_buffered
                    + int(seg["id"].shape[0])}
        with open(man + ".tmp", "w") as f:
            json.dump(manifest, f)
        os.replace(man + ".tmp", man)
        self._segments += 1
        self._chunks = []
        self._rows_buffered = 0

    def flush(self) -> None:
        if self.spill_dir:
            with self._lock:
                self._flush_locked()

    @property
    def count(self) -> int:
        with self._lock:
            return len(self._index)

    def scan(self):
        """Yield buffered column chunks (analytical-query surface; flushed
        segments are read back from disk)."""
        with self._lock:
            chunks = list(self._chunks)
            nseg = self._segments
        for s in range(nseg):
            seg = np.load(os.path.join(self.spill_dir, f"p{self.pid}",
                                       f"seg{s:06d}.npz"))
            yield {k: seg[k] for k in seg.files}
        yield from chunks

    def get(self, pk: int) -> Optional[Dict[str, Any]]:
        with self._lock:
            row = self._index.get(int(pk))
            if row is None:
                return None
            # locate the row across flushed segments + buffered chunks
            offset = self._rows_total - sum(
                c["id"].shape[0] for c in self._chunks)
            if row >= offset:
                r = row - offset
                for c in self._chunks:
                    if r < c["id"].shape[0]:
                        return {k: v[r] for k, v in c.items()}
                    r -= c["id"].shape[0]
            if not self.spill_dir:
                return None
            r = row
            for s in range(self._segments):
                seg = np.load(os.path.join(
                    self.spill_dir, f"p{self.pid}", f"seg{s:06d}.npz"))
                n = seg["id"].shape[0]
                if r < n:
                    return {k: seg[k][r] for k in seg.files}
                r -= n
            return None


class StorageJob:
    """Hash partitioner + P column-store partitions (paper Fig 23's Storage
    Partition Holder feeds this through an active holder — see feed.py)."""

    def __init__(self, num_partitions: int, spill_dir: Optional[str] = None,
                 upsert: bool = False):
        self.partitions = [StoragePartition(i, spill_dir)
                           for i in range(num_partitions)]
        self.upsert = upsert
        self.stored = 0
        self.batches = 0         # write() calls — exactly-once fan-out tests
        self.write_s = 0.0
        self._lock = threading.Lock()

    def write(self, batch: Dict[str, np.ndarray]) -> int:
        """Hash-partition one enriched batch by primary key and insert.
        The batch may be shared with other sinks of the same plan (tee
        fan-out): treated as read-only — rows are masked into fresh arrays,
        never mutated in place."""
        t0 = time.perf_counter()
        npart = len(self.partitions)
        part = (batch["id"] % npart).astype(np.int64)
        stored = 0
        for p in range(npart):
            m = (part == p) & batch["valid"]
            if not m.any():
                continue
            sub = {k: v[m] for k, v in batch.items()}
            sub["valid"] = np.ones(int(m.sum()), bool)
            stored += self.partitions[p].insert(sub, self.upsert)
        with self._lock:
            self.stored += stored
            self.batches += 1
            self.write_s += time.perf_counter() - t0
        return stored

    @property
    def count(self) -> int:
        return sum(p.count for p in self.partitions)

    def scan(self):
        for p in self.partitions:
            yield from p.scan()

    def get(self, pk: int) -> Optional[Dict[str, Any]]:
        return self.partitions[int(pk) % len(self.partitions)].get(pk)

    def flush(self) -> None:
        for p in self.partitions:
            p.flush()
