"""Shared vectorized membership idioms.

The storage pk index (storage.py ``_PkIndex``) and the versioned ref
tables (refdata.py ``RefTable.upsert``) both replace per-row Python loops
with the same two primitives; keeping them here means a boundary/dtype
fix lands in both at once.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def keep_last(ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Deduplicate ``ids`` keeping each value's LAST occurrence (matching
    sequential replace semantics: later rows supersede earlier).  Returns
    ``(unique_values_sorted, last_occurrence_positions)`` — index ``ids``
    (or a parallel payload array) with the positions."""
    uniq, rev_first = np.unique(ids[::-1], return_index=True)
    return uniq, ids.shape[0] - 1 - rev_first


def sorted_find(values: np.ndarray, needles: np.ndarray,
                sorter: Optional[np.ndarray] = None
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized membership probe of ``needles`` against ``values``
    (sorted ascending, or unsorted with an argsort ``sorter``).  Returns
    ``(found_mask, locations, insert_pos)``: ``locations`` indexes into
    ``values`` for each found needle (undefined where not found);
    ``insert_pos`` is the searchsorted insertion point (for merge-inserts
    into the sorted layout — only meaningful without ``sorter``)."""
    n = int(values.shape[0])
    pos = np.searchsorted(values, needles, sorter=sorter)
    if n == 0:
        return np.zeros(needles.shape[0], bool), pos, pos
    clamped = np.minimum(pos, n - 1)
    loc = clamped if sorter is None else sorter[clamped]
    found = (pos < n) & (values[loc] == needles)
    return found, loc, pos
