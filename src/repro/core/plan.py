"""Declarative ingestion plans: the ``Pipeline`` builder and the immutable
``IngestPlan`` it compiles into.

The paper frames ingestion-time enrichment *declaratively* — a feed is a
query plan (adapter -> parse -> UDFs -> dataset), compiled once and invoked
per batch.  This module is that abstraction for this repo:

    plan = (pipeline(adapter, "tweets")
            .parse(batch_size=420)
            .enrich(Q.Q1)
            .enrich(Q.Q2)
            .filter(lambda b: b["safety_level"] >= 0, name="joined_only")
            .project("safety_level", "religious_population")
            .tee(lm_data_plane_sink)
            .store(spill_dir="/data/enriched"))
    handle = manager.submit(plan)

``compile()`` performs the whole-plan optimizations and validations that a
per-batch runtime cannot:

  * **Stage fusion** — consecutive ``enrich``/``filter`` stages fuse into
    ONE ``EnrichUDF`` (``queries.chain``): a single predeployed apply (one
    jit / one kernel dispatch per batch) over the union of the stages' ref
    tables, with per-stage ``state_fn``s so Model-2/3 state semantics are
    preserved *per stage* (see ``ComputingRunner._get_staged_state``).
  * **Up-front validation** — every referenced table must exist in the
    ``RefStore``, and each stage is abstractly traced (``jax.eval_shape``)
    against the tweet schema + actual reference dtypes, so dtype/shape
    errors and unknown columns raise ``PlanError`` at compile time, not
    mid-feed in a worker thread.
  * **Multi-sink lowering** — each ``tee``/``store`` sink becomes one
    ``ActivePartitionHolder`` on the feed's fan-out, so every enriched
    batch is delivered to every sink exactly once, each sink consuming
    from its own bounded queue (independent backpressure).

``FeedConfig`` + ``FeedManager.start`` remain as a thin compatibility shim
that builds a one-stage plan (see feed.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

from repro.core import records
from repro.core.compaction import CompactionSpec
from repro.core.durability import DurableSpec
from repro.core.elasticity import ElasticSpec
from repro.core.enrich.queries import EnrichUDF, chain, make_filter
from repro.core.intake import Adapter
from repro.core.obs import HealthSpec, ProfileSpec, TraceSpec
from repro.core.refdata import RefStore
from repro.core.repair import RepairSpec


class PlanError(ValueError):
    """Invalid ingestion plan, detected at compile time."""


@dataclasses.dataclass(frozen=True)
class StoreSpec:
    """The storage-job sink (partitioned column store, see storage.py).
    ``refresh`` attaches a progressive re-enrichment policy: a background
    ``RepairJob`` (core/repair.py) keeps the stored rows' enrichments
    current as reference tables are upserted mid- and post-ingestion.

    Read-side layout (core/query.py consumes these — INGESTBASE-style
    ingestion-time decisions the analytical scan path exploits):
    ``zone_map_cols`` picks the columns whose per-segment min/max is
    persisted at flush for predicate pruning (None = every eligible 1-D
    numeric column; () disables); ``sort_key`` clusters each flushed
    segment by that column.  ``compact`` attaches a budgeted background
    ``CompactionJob`` (core/compaction.py) reclaiming superseded/deleted
    row versions as upserts and repair churn the store.

    ``durable=DurableSpec(...)`` makes the whole FEED crash-restartable
    (core/durability.py): a write-ahead intake log, coordinated
    checkpoints, and ``FeedManager.resume`` replay with storage-side
    dedup — exactly-once ingestion across a kill.  Requires a resumable
    adapter (compile-checked); ``spill_dir`` defaults to a ``store/``
    subdirectory of the durable dir when unset."""
    partitions: int = 0            # 0 -> plan.num_partitions
    spill_dir: Optional[str] = None
    upsert: bool = False
    segment_rows: int = 100_000
    refresh: Optional[RepairSpec] = None
    zone_map_cols: Optional[Tuple[str, ...]] = None
    sort_key: Optional[str] = None
    compact: Optional[CompactionSpec] = None
    durable: Optional[DurableSpec] = None


@dataclasses.dataclass(frozen=True)
class SinkSpec:
    name: str
    consumer: Optional[Callable[[Dict], None]] = None   # tee sink
    store: Optional[StoreSpec] = None                   # storage sink

    @property
    def is_store(self) -> bool:
        return self.store is not None


@dataclasses.dataclass(frozen=True)
class StageGroup:
    """One independently-scalable segment of the compiled chain: its own
    fused UDF, its own worker pool + holders at runtime, its own elastic
    bounds.  Groups are linked by intermediate ``PartitionHolder``s, so a
    heavy-state stage (Q6) scales — and later, places — independently of
    cheap probe stages."""
    name: str
    udf: Optional[EnrichUDF]       # fused sub-chain of this group (or None)
    partitions: int = 0            # 0 -> plan.num_partitions
    elastic: Optional[ElasticSpec] = None


# FeedConfig knobs a plan carries through to the feed runtime
_OPTION_KEYS = ("num_partitions", "holder_capacity", "work_stealing",
                "max_retries", "retry_backoff_s", "coalesce_rows",
                "coalesce_bytes", "fault_hook", "elastic", "trace",
                "profile", "health")


def _coerce_elastic(value) -> Optional[ElasticSpec]:
    if value is None or isinstance(value, ElasticSpec):
        return value
    if isinstance(value, dict):
        try:
            return ElasticSpec(**value)
        except (TypeError, ValueError) as e:
            raise PlanError(f"invalid elastic spec {value!r}: {e}") from e
    raise PlanError("elastic must be an ElasticSpec or dict, got "
                    f"{type(value).__name__}")


def _coerce_trace(value) -> Optional[TraceSpec]:
    if value is None or isinstance(value, TraceSpec):
        return value
    if value is True:
        return TraceSpec()
    if value is False:
        return None
    if isinstance(value, dict):
        try:
            return TraceSpec(**value)
        except (TypeError, ValueError) as e:
            raise PlanError(f"invalid trace spec {value!r}: {e}") from e
    raise PlanError("trace must be a TraceSpec, dict, or bool, got "
                    f"{type(value).__name__}")


def _coerce_profile(value) -> Optional[ProfileSpec]:
    if value is None or isinstance(value, ProfileSpec):
        return value
    if value is True:
        return ProfileSpec()
    if value is False:
        return None
    if isinstance(value, dict):
        try:
            return ProfileSpec(**value)
        except (TypeError, ValueError) as e:
            raise PlanError(f"invalid profile spec {value!r}: {e}") from e
    raise PlanError("profile must be a ProfileSpec, dict, or bool, got "
                    f"{type(value).__name__}")


def _coerce_health(value) -> Optional[HealthSpec]:
    if value is None or isinstance(value, HealthSpec):
        return value
    if value is True:
        return HealthSpec()
    if value is False:
        return None
    if isinstance(value, dict):
        try:
            return HealthSpec(**value)
        except (TypeError, ValueError) as e:
            raise PlanError(f"invalid health spec {value!r}: {e}") from e
    raise PlanError("health must be a HealthSpec, dict, or bool, got "
                    f"{type(value).__name__}")


def _coerce_repair(value) -> Optional[RepairSpec]:
    if value is None or isinstance(value, RepairSpec):
        return value
    if isinstance(value, dict):
        try:
            return RepairSpec(**value)
        except (TypeError, ValueError) as e:
            raise PlanError(f"invalid refresh spec {value!r}: {e}") from e
    raise PlanError("store(refresh=...) takes a RepairSpec or dict, got "
                    f"{type(value).__name__}")


def _coerce_compact(value) -> Optional[CompactionSpec]:
    if value is None or isinstance(value, CompactionSpec):
        return value
    if isinstance(value, dict):
        try:
            return CompactionSpec(**value)
        except (TypeError, ValueError) as e:
            raise PlanError(f"invalid compact spec {value!r}: {e}") from e
    raise PlanError("store(compact=...) takes a CompactionSpec or dict, "
                    f"got {type(value).__name__}")


def _coerce_durable(value) -> Optional[DurableSpec]:
    if value is None or isinstance(value, DurableSpec):
        return value
    if isinstance(value, dict):
        try:
            return DurableSpec(**value)
        except (TypeError, ValueError) as e:
            raise PlanError(f"invalid durable spec {value!r}: {e}") from e
    raise PlanError("store(durable=...) takes a DurableSpec or dict, "
                    f"got {type(value).__name__}")


@dataclasses.dataclass(frozen=True)
class IngestPlan:
    """A compiled, immutable ingestion plan.  ``FeedManager.submit``
    executes it; everything here is validated and fused already."""
    name: str
    adapter: Adapter
    udf: Optional[EnrichUDF]             # fused enrich+filter chain (or None)
    stage_names: Tuple[str, ...]         # fused stages, in order
    sinks: Tuple[SinkSpec, ...]          # >= 1; at most one store
    output_columns: Tuple[str, ...]      # columns sinks receive (validated)
    project_cols: Optional[Tuple[str, ...]] = None
    batch_size: int = 420
    model: str = "per_batch"
    refresh: str = "always"
    num_partitions: int = 1
    holder_capacity: int = 8
    work_stealing: bool = True
    max_retries: int = 3
    retry_backoff_s: float = 0.05
    coalesce_rows: Optional[int] = None  # None -> feed.py's auto default
    coalesce_bytes: int = 8 << 20
    fault_hook: Optional[Callable[[int], bool]] = None
    # per-stage parallelism: >= 1 independently-scalable segments of the
    # fused chain (always at least one group; single-group plans execute
    # exactly as before).  Plan-level ``elastic`` is the default bound set
    # for groups that do not declare their own.
    stage_groups: Tuple[StageGroup, ...] = ()
    elastic: Optional[ElasticSpec] = None
    # batch-span tracing policy (core/obs): metrics are always on, but
    # per-hop span emission is opt-in via ``.options(trace=...)``
    trace: Optional[TraceSpec] = None
    # feedscope (core/obs): journey profiler policy — implies a default
    # tracer when ``trace`` is unset — and SLO thresholds for the feed
    # health model (``FeedHandle.profile()`` / ``health()``, /profile
    # and /health on the live ops endpoint)
    profile: Optional[ProfileSpec] = None
    health: Optional[HealthSpec] = None

    @property
    def store_spec(self) -> Optional[StoreSpec]:
        for s in self.sinks:
            if s.is_store:
                return s.store
        return None

    def restrict(self, out: Dict) -> Dict:
        """Apply the plan's projection to an enriched batch (id + valid
        always flow).  Shared by the feed's sink fan-out and the repair
        job, so repaired rows carry exactly the stored column set."""
        if self.project_cols is None:
            return out
        return {k: out[k] for k in self.project_cols if k in out}


def pipeline(adapter: Adapter, name: str = "pipeline") -> "Pipeline":
    """Entry point of the declarative API: a builder over ``adapter``."""
    return Pipeline(adapter, name)


class Pipeline:
    """Ordered stage recorder.  Builder calls only record; all validation
    (ordering, ref tables, dtypes) happens in ``compile`` so a bad plan
    fails in one place, before any job starts."""

    def __init__(self, adapter: Adapter, name: str = "pipeline"):
        self._adapter = adapter
        self._name = name
        self._parse: Dict[str, Any] = dict(batch_size=420,
                                           model="per_batch",
                                           refresh="always")
        self._opts: Dict[str, Any] = {}
        # ordered log of ("enrich"|"filter"|"project"|"tee"|"store", payload)
        self._stages: list = []
        self._n_filters = 0

    # ------------------------------------------------------------- builders
    def parse(self, batch_size: int = 420, model: str = "per_batch",
              refresh: str = "always") -> "Pipeline":
        self._parse = dict(batch_size=batch_size, model=model,
                           refresh=refresh)
        return self

    def options(self, **kw: Any) -> "Pipeline":
        """Feed-runtime knobs: num_partitions, holder_capacity,
        work_stealing, max_retries, retry_backoff_s, coalesce_rows,
        coalesce_bytes, fault_hook, elastic (an ``ElasticSpec`` or kwargs
        dict — the feed-wide default elastic bounds; per-stage bounds go on
        ``enrich(..., elastic=...)``), trace (a ``TraceSpec``, kwargs dict,
        or True — enables per-hop batch span tracing, see core/obs),
        profile (a ``ProfileSpec``, kwargs dict, or True — journey
        reconstruction + critical-path bottleneck attribution via
        ``handle.profile()``; implies a default tracer), health (a
        ``HealthSpec``, kwargs dict, or True — SLO thresholds for
        ``handle.health()``; defaults apply even without the option)."""
        for k in kw:
            if k not in _OPTION_KEYS:
                raise PlanError(f"unknown option {k!r} "
                                f"(valid: {', '.join(_OPTION_KEYS)})")
        if "elastic" in kw:
            kw = dict(kw, elastic=_coerce_elastic(kw["elastic"]))
        if "trace" in kw:
            kw = dict(kw, trace=_coerce_trace(kw["trace"]))
        if "profile" in kw:
            kw = dict(kw, profile=_coerce_profile(kw["profile"]))
        if "health" in kw:
            kw = dict(kw, health=_coerce_health(kw["health"]))
        self._opts.update(kw)
        return self

    def enrich(self, udf: EnrichUDF, partitions: Optional[int] = None,
               elastic: Optional[ElasticSpec] = None) -> "Pipeline":
        """Add an enrichment stage.  Declaring ``partitions`` and/or
        ``elastic`` makes this stage a **stage-group boundary**: it gets its
        own holder + worker pool (following undeclared stages fuse into it),
        so a heavy stage scales independently of the rest of the chain."""
        if partitions is not None and partitions < 1:
            raise PlanError(
                f"enrich(partitions=...) must be >= 1, got {partitions}")
        self._stages.append(("enrich", (udf, partitions,
                                        _coerce_elastic(elastic))))
        return self

    def filter(self, pred: Callable, name: Optional[str] = None
               ) -> "Pipeline":
        self._n_filters += 1
        fname = name or f"filter_{self._n_filters}"
        self._stages.append(("filter", (make_filter(fname, pred),
                                        None, None)))
        return self

    def project(self, *cols: str) -> "Pipeline":
        self._stages.append(("project", tuple(cols)))
        return self

    def tee(self, sink: Callable[[Dict], None],
            name: Optional[str] = None) -> "Pipeline":
        self._stages.append(("tee", (name, sink)))
        return self

    def store(self, partitions: int = 0, spill_dir: Optional[str] = None,
              upsert: bool = False, segment_rows: int = 100_000,
              refresh=None, zone_map_cols: Optional[Tuple[str, ...]] = None,
              sort_key: Optional[str] = None, compact=None,
              durable=None) -> "Pipeline":
        """The column-store sink; at runtime ``FeedHandle.query()`` (or
        ``handle.storage.query()``) opens the analytical query subsystem
        over it (core/query.py).  ``refresh=RepairSpec(...)`` (or a kwargs
        dict) enables progressive re-enrichment: a background repair job
        re-runs the plan's enrich stages over stored rows whose ref-version
        lineage went stale (see core/repair.py).  ``zone_map_cols``/
        ``sort_key`` are the read-side layout knobs and ``compact=
        CompactionSpec(...)`` the background space-reclaim policy — see
        ``StoreSpec``.  ``durable=DurableSpec(...)`` (or a kwargs dict)
        makes the feed crash-restartable via a write-ahead intake log +
        checkpoints (core/durability.py; resume with
        ``FeedManager.resume``)."""
        dspec = _coerce_durable(durable)
        if dspec is not None and spill_dir is None:
            # a durable feed without a durable store is pointless — the
            # replay dedup needs the recovered pk index
            spill_dir = dspec.store_dir
        self._stages.append(("store", StoreSpec(
            partitions, spill_dir, upsert, segment_rows,
            _coerce_repair(refresh),
            tuple(zone_map_cols) if zone_map_cols is not None else None,
            sort_key, _coerce_compact(compact), dspec)))
        return self

    # -------------------------------------------------------------- compile
    def compile(self, refstore: RefStore) -> IngestPlan:
        """Validate + fuse + lower into an immutable ``IngestPlan``."""
        udfs, project_cols, sinks = self._split_stages()
        fused = self._fuse([u for u, _, _ in udfs])
        self._check_ref_tables(fused, refstore)
        out_cols = _validate_dtypes(fused, refstore,
                                    self._parse["batch_size"],
                                    self._parse["model"])
        if project_cols is not None:
            unknown = [c for c in project_cols if c not in out_cols]
            if unknown:
                raise PlanError(
                    f"project() references unknown column(s) {unknown}; "
                    f"available: {sorted(out_cols)}")
            # id + valid always flow: storage partitioning and validity
            # masking depend on them
            project_cols = tuple(dict.fromkeys(
                ("id", "valid") + tuple(project_cols)))
            delivered = project_cols
        else:
            delivered = tuple(out_cols)
        groups = self._group_stages(udfs, fused)
        for g in groups:
            if g.elastic is not None and g.partitions and not (
                    g.elastic.min_partitions <= g.partitions
                    <= g.elastic.max_partitions):
                raise PlanError(
                    f"stage group {g.name!r}: partitions={g.partitions} "
                    "outside elastic bounds "
                    f"[{g.elastic.min_partitions}, "
                    f"{g.elastic.max_partitions}]")
        self._check_repair(fused, sinks, project_cols, groups)
        self._check_store(sinks, delivered)
        self._check_durable(sinks, groups)
        return IngestPlan(
            name=self._name, adapter=self._adapter, udf=fused,
            stage_names=tuple(u.name for u in (
                fused.stages or (fused,))) if fused is not None else (),
            sinks=sinks, output_columns=delivered,
            project_cols=project_cols, stage_groups=groups,
            **self._parse, **self._opts)

    def _group_stages(self, udfs, fused) -> Tuple[StageGroup, ...]:
        """Split the chain at declared stage boundaries.  A stage with
        ``partitions``/``elastic`` opens a new group; undeclared stages fuse
        into the current one (a filter right after Q6 runs at Q6's
        parallelism).  Undeclared groups inherit the plan-level elastic
        default from ``options(elastic=...)``."""
        default_elastic = self._opts.get("elastic")
        if not udfs:
            return (StageGroup("parse", None, 0, default_elastic),)
        runs: list = []
        for udf, partitions, elastic in udfs:
            boundary = partitions is not None or elastic is not None
            if boundary or not runs:
                runs.append([partitions or 0, elastic, [udf]])
            else:
                runs[-1][2].append(udf)
        if len(runs) == 1:
            # single group: keep the WHOLE-chain fusion object so the
            # predeploy cache identity matches plan.udf (warmed elsewhere)
            p, el, _ = runs[0]
            return (StageGroup(fused.name, fused, p,
                               el or default_elastic),)
        groups = []
        for p, el, members in runs:
            gudf = (members[0] if len(members) == 1 else
                    chain(">".join(u.name for u in members), *members))
            groups.append(StageGroup(gudf.name, gudf, p,
                                     el or default_elastic))
        return tuple(groups)

    def _check_repair(self, fused, sinks, project_cols, groups) -> None:
        """Progressive re-enrichment preconditions, enforced at compile
        time so a repair-enabled plan can never reach a state it cannot
        repair from."""
        spec = next((s.store.refresh for s in sinks if s.is_store), None)
        if spec is None:
            return
        if fused is None:
            raise PlanError(
                "store(refresh=RepairSpec(...)) needs at least one "
                "enrich stage: there is nothing to re-enrich")
        if self._parse["model"] == "per_record":
            raise PlanError(
                "store(refresh=...) is incompatible with model="
                "'per_record': repair re-enriches at batch granularity "
                "through the per-batch predeployed executable")
        if self._parse["model"] == "stream":
            raise PlanError(
                "store(refresh=...) is incompatible with model='stream': "
                "stream feeds enrich every batch with feed-lifetime state "
                "built under the INITIAL ref versions, while lineage "
                "records the per-batch snapshot versions — rows enriched "
                "from stale state would be tagged fresh and never "
                "repaired (use model='per_batch' with refresh='version' "
                "for stream-like cost with repairable lineage)")
        if len(groups) > 1:
            raise PlanError(
                "store(refresh=...) requires a single stage group: with "
                "per-stage splits the storage-bound batch only carries "
                "the LAST group's ref-version lineage, so staleness of "
                "earlier groups' tables could be missed (fuse the chain, "
                "or use feed-wide options(elastic=...) which keeps one "
                "group)")
        if project_cols is not None:
            missing = [c for c in records.TWEET_SCHEMA
                       if c not in project_cols]
            if missing:
                raise PlanError(
                    "store(refresh=...) needs every input schema column "
                    "stored so rows can be re-enriched from scratch; "
                    f"project() drops {missing}")

    def _check_store(self, sinks, delivered) -> None:
        """Read-side layout knobs must name columns the store will actually
        receive — caught here, not as silently-absent zone maps or an
        unsorted 'sorted' store mid-feed."""
        spec = next((s.store for s in sinks if s.is_store), None)
        if spec is None:
            return
        unknown = [c for c in (spec.zone_map_cols or ())
                   if c not in delivered]
        if unknown:
            raise PlanError(
                f"store(zone_map_cols=...) references column(s) {unknown} "
                f"the store never receives; available: {sorted(delivered)}")
        if spec.sort_key is not None and spec.sort_key not in delivered:
            raise PlanError(
                f"store(sort_key={spec.sort_key!r}) is not a stored "
                f"column; available: {sorted(delivered)}")
        if spec.compact is not None and \
                spec.compact.level_target_rows > 0 and not spec.spill_dir:
            raise PlanError(
                "compact=CompactionSpec(level_target_rows=...) enables "
                "leveled segment merging, which only applies to FLUSHED "
                "segments — set store(spill_dir=...) (or durable=..., "
                "which implies one), or drop level_target_rows")

    def _check_durable(self, sinks, groups) -> None:
        """Durable-feed preconditions, rejected at compile time — not as
        a restart-time surprise when the crashed data is already gone."""
        spec = next((s.store.durable for s in sinks if s.is_store), None)
        if spec is None:
            return
        ad = self._adapter
        if not getattr(ad, "resumable", False):
            raise PlanError(
                f"store(durable=...) requires a resumable adapter, but "
                f"{type(ad).__name__} declares resumable=False — input "
                "lost in a crash could never be replayed (SocketAdapter: "
                "spool the stream to a file and use FileAdapter)")
        if len(groups) > 1:
            raise PlanError(
                "store(durable=...) requires a single stage group: the "
                "WAL sequence stamp rides the batch to the store sink, "
                "and per-stage splits drop it at the intermediate "
                "holder hand-off (fuse the chain, or use feed-wide "
                "options(elastic=...) which keeps one group)")
        if self._parse["model"] == "per_record":
            raise PlanError(
                "store(durable=...) is incompatible with model="
                "'per_record': the per-record path re-frames batches, "
                "losing the WAL sequence stamp the checkpoint watermark "
                "is driven by")

    # -------------------------------------------------------------- helpers
    def _split_stages(self):
        udfs: list = []
        project_cols: Optional[Tuple[str, ...]] = None
        sinks: list = []
        seen_sink = False
        store_seen = False
        tee_auto = 0
        for kind, payload in self._stages:
            if kind in ("enrich", "filter", "project") and seen_sink:
                raise PlanError(
                    f"{kind}() after a sink stage (tee/store): transform "
                    "stages must precede all sinks")
            if kind == "enrich":
                udf, _, _ = payload
                if not isinstance(udf, EnrichUDF):
                    raise PlanError(
                        "enrich() takes an EnrichUDF, got "
                        f"{type(udf).__name__}")
                udfs.append(payload)
            elif kind == "filter":
                udfs.append(payload)
            elif kind == "project":
                if project_cols is not None:
                    raise PlanError("project() may appear at most once")
                if not payload:
                    raise PlanError("project() needs at least one column")
                project_cols = payload
            elif kind == "tee":
                seen_sink = True
                name, sink = payload
                tee_auto += 1
                sinks.append(SinkSpec(name or f"tee_{tee_auto}",
                                      consumer=sink))
            elif kind == "store":
                seen_sink = True
                if store_seen:
                    raise PlanError("store() may appear at most once")
                store_seen = True
                sinks.append(SinkSpec("store", store=payload))
        if not sinks:
            raise PlanError(
                "plan has no sink: end with .store(...) and/or .tee(sink)")
        if self._parse["model"] not in ("per_record", "per_batch", "stream"):
            raise PlanError(f"unknown model {self._parse['model']!r}")
        if self._parse["refresh"] not in ("always", "version"):
            raise PlanError(f"unknown refresh {self._parse['refresh']!r}")
        return udfs, project_cols, tuple(sinks)

    def _fuse(self, udfs) -> Optional[EnrichUDF]:
        if not udfs:
            return None
        if len(udfs) == 1:
            return udfs[0]   # keep the original predeploy cache identity
        return chain(">".join(u.name for u in udfs), *udfs)

    def _check_ref_tables(self, fused: Optional[EnrichUDF],
                          refstore: RefStore) -> None:
        if fused is None:
            return
        for stage in (fused.stages or (fused,)):
            missing = [t for t in stage.ref_tables if t not in refstore]
            if missing:
                raise PlanError(
                    f"stage {stage.name!r} references missing reference "
                    f"table(s) {missing}: create/populate them in the "
                    "RefStore before compiling the plan")


def _batch_struct(batch_size: int) -> Dict[str, jax.ShapeDtypeStruct]:
    out = {}
    for k, dt in records.TWEET_SCHEMA.items():
        if dt.subdtype is not None:
            base, shape = dt.subdtype
            out[k] = jax.ShapeDtypeStruct((batch_size,) + shape, base)
        else:
            out[k] = jax.ShapeDtypeStruct((batch_size,), dt)
    out["valid"] = jax.ShapeDtypeStruct((batch_size,), np.dtype(bool))
    return out


def _validate_dtypes(fused: Optional[EnrichUDF], refstore: RefStore,
                     batch_size: int, model: str) -> Tuple[str, ...]:
    """Abstractly trace every stage against the tweet schema and the actual
    reference-table dtypes (``jax.eval_shape`` — no FLOPs, no compilation).
    Returns the ordered output column names sinks will receive.  Raises
    ``PlanError`` naming the offending stage for any dtype/shape/column
    error, so misconfigured plans never reach a worker thread."""
    batch = _batch_struct(batch_size)
    cols = dict(batch)
    if fused is None:
        return tuple(cols)
    b = 1 if model == "per_record" else batch_size
    if model == "per_record":
        batch = _batch_struct(1)
        cols.update(batch)
    for stage in (fused.stages or (fused,)):
        refs = {}
        for t in stage.ref_tables:
            snap = refstore[t].snapshot()
            refs[t] = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                       for k, v in snap.arrays.items()}
        try:
            state = (jax.eval_shape(stage.state_fn, refs)
                     if stage.state_fn is not None else ())
            out = jax.eval_shape(stage.apply_fn, batch, state, refs)
        except PlanError:
            raise
        except Exception as e:
            raise PlanError(
                f"stage {stage.name!r} failed dtype/shape validation "
                "against the tweet schema and current reference tables: "
                f"{type(e).__name__}: {e}") from e
        if not isinstance(out, dict):
            raise PlanError(
                f"stage {stage.name!r} must return a dict of columns, "
                f"got {type(out).__name__}")
        for k, v in out.items():
            if not hasattr(v, "shape") or not v.shape or v.shape[0] != b:
                raise PlanError(
                    f"stage {stage.name!r} output {k!r} must be batch-"
                    f"aligned (leading dim {b}), got shape "
                    f"{getattr(v, 'shape', None)}")
            if k == "valid" and v.dtype != np.dtype(bool):
                raise PlanError(
                    f"stage {stage.name!r} rewrites 'valid' with dtype "
                    f"{v.dtype}; filters must keep it bool")
        batch = dict(batch)
        batch.update(out)
        cols.update(out)
    return tuple(cols)
