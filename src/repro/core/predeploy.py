"""Parameterized predeployed jobs (§6.1) realized as a JAX AOT-compile
cache.

The paper compiles the computing job's query plan once, distributes the job
specification to the cluster, and then *invokes* it per batch with only the
new batch as a parameter.  The JAX equivalent: ``jax.jit(fn).lower(shapes)
.compile()`` once per (function x operand shapes), cache the executable,
and call it with fresh operands (the record batch AND the current reference
snapshot — shape-stable by construction, see refdata.py).

The win is the same one the paper measures, but larger: an XLA compile is
seconds while an invocation is micro/milliseconds, so repeatedly-invoked
computing jobs would be compile-bound without this cache (quantified in
benchmarks/fig24_basic_ingestion.py).
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, Tuple

import jax
import numpy as np


def tree_signature(tree: Any) -> Tuple:
    """Hashable (shape, dtype) signature of an operand pytree."""
    leaves, treedef = jax.tree.flatten(tree)

    def sig(x):
        if hasattr(x, "shape"):
            return (tuple(x.shape), np.dtype(x.dtype).str)
        return (type(x).__name__, repr(x))

    return (tuple(sig(x) for x in leaves), str(treedef))


class PredeployCache:
    """Executable cache keyed by (job name, operand signature)."""

    def __init__(self):
        self._cache: Dict[Tuple, Any] = {}  # guarded-by: _lock
        self._lock = threading.Lock()        # lock-name: predeploy
        self.compiles = 0                    # guarded-by: _lock
        self.invocations = 0                 # guarded-by: _lock
        self.compile_s = 0.0                 # guarded-by: _lock
        # per-job-name breakdown: tests pin down that a fused chain is ONE
        # apply executable (one compile per shape) instead of one per stage
        self.by_name: Dict[str, Dict[str, int]] = {}  # guarded-by: _lock

    def _name_stats(self, name: str) -> Dict[str, int]:  # requires-lock: _lock
        s = self.by_name.get(name)
        if s is None:
            s = self.by_name[name] = {"compiles": 0, "invocations": 0}
        return s

    def get(self, name: str, fn: Callable, *operands: Any):
        """Return the AOT-compiled executable for ``fn`` at these operand
        shapes, compiling (and 'predeploying') on first use.

        The key includes ``fn`` itself, not just ``name``: plan-built
        stages (filters, fused chains) are user closures under auto-
        generated names, and two different predicates that happen to share
        a name must NOT share an executable.  Stable module-level UDFs
        still hit across feeds; a freshly-composed chain costs one compile
        per composition (per shape), never a wrong-function cache hit."""
        key = (name, fn, tree_signature(operands))
        with self._lock:
            exe = self._cache.get(key)
        if exe is not None:
            return exe
        t0 = time.perf_counter()
        shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype)
            if hasattr(x, "shape") else x, operands)
        exe = jax.jit(fn).lower(*shapes).compile()
        dt = time.perf_counter() - t0
        with self._lock:
            self._cache.setdefault(key, exe)
            self.compiles += 1
            self.compile_s += dt
            self._name_stats(name)["compiles"] += 1
        return exe

    def invoke(self, name: str, fn: Callable, *operands: Any):
        exe = self.get(name, fn, *operands)
        with self._lock:
            self.invocations += 1
            self._name_stats(name)["invocations"] += 1
        return exe(*operands)

    def stats(self) -> Dict[str, float]:
        with self._lock:
            return {"compiles": self.compiles,
                    "invocations": self.invocations,
                    "compile_s": round(self.compile_s, 3)}
