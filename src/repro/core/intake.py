"""The intake job (§7.2): adapter -> round-robin partitioner -> passive
intake partition holders.

Adapters obtain/receive raw data and arrange it into frames (one frame = one
computing batch of JSON-line byte records).  The intake job never parses in
the new framework — parsing happens inside the (parallel) computing jobs,
which is exactly the difference the paper measures against "current feeds"
where a single intake node parses everything (Fig 24's bottleneck).
"""

from __future__ import annotations

import socket
import threading
import time
from typing import Iterator, List, Optional

from repro.core.partition_holder import PartitionHolder
from repro.core.records import SyntheticTweets, batch_rows


class Adapter:
    """Iterator of frames (list[bytes]); ``stop()`` requests early end."""

    def __init__(self):
        self._stop = threading.Event()

    def stop(self) -> None:
        self._stop.set()

    def frames(self) -> Iterator[List[bytes]]:
        raise NotImplementedError


class SyntheticAdapter(Adapter):
    """Deterministic tweet stream: ``total`` records in ``frame_size``
    frames, optionally rate-limited (records/second)."""

    def __init__(self, total: int, frame_size: int, seed: int = 0,
                 rate: Optional[float] = None):
        super().__init__()
        self.total, self.frame_size, self.rate = total, frame_size, rate
        self.source = SyntheticTweets(seed=seed)

    def frames(self) -> Iterator[List[bytes]]:
        t0 = time.perf_counter()
        sent = 0
        for frame in self.source.batches(self.total, self.frame_size):
            if self._stop.is_set():
                return
            if self.rate:
                target = t0 + sent / self.rate
                delay = target - time.perf_counter()
                if delay > 0:
                    time.sleep(delay)
            yield frame
            sent += len(frame)


class FileAdapter(Adapter):
    """JSON-lines file -> frames."""

    def __init__(self, path: str, frame_size: int):
        super().__init__()
        self.path, self.frame_size = path, frame_size

    def frames(self) -> Iterator[List[bytes]]:
        buf: List[bytes] = []
        with open(self.path, "rb") as f:
            for line in f:
                if self._stop.is_set():
                    return
                line = line.strip()
                if not line:
                    continue
                buf.append(line)
                if len(buf) >= self.frame_size:
                    yield buf
                    buf = []
        if buf:
            yield buf


class SocketAdapter(Adapter):
    """The paper's socket feed (Fig 4): newline-delimited JSON over TCP.
    Listens on (host, port); one connection at a time; EOF ends the feed."""

    def __init__(self, host: str, port: int, frame_size: int):
        super().__init__()
        self.host, self.port, self.frame_size = host, port, frame_size
        self._srv = socket.create_server((host, port))
        self._srv.settimeout(0.5)

    @property
    def address(self):
        return self._srv.getsockname()

    def frames(self) -> Iterator[List[bytes]]:
        try:
            while not self._stop.is_set():
                try:
                    conn, _ = self._srv.accept()
                    break
                except socket.timeout:
                    continue
            else:
                return
            buf: List[bytes] = []
            with conn, conn.makefile("rb") as f:
                for line in f:
                    if self._stop.is_set():
                        return
                    line = line.strip()
                    if not line:
                        continue
                    buf.append(line)
                    if len(buf) >= self.frame_size:
                        yield buf
                        buf = []
            if buf:
                yield buf
        finally:
            self._srv.close()


class IntakeJob(threading.Thread):
    """Long-running intake: distributes frames round-robin over the intake
    partition holders, then closes them (StopRecord drain, §7.1).

    ``holders`` is a live list — the elastic runtime appends (scale_up) and
    removes (scale_down) holders mid-feed under the feed handle's ``lock``;
    the round-robin partitioner re-targets automatically.  A push that
    lands on a holder retired between the snapshot and the push (it drained
    and closed) is retried against a fresh snapshot, so scale_down can
    never drop a frame.  On completion the intake flips ``closing`` under
    the lock *before* closing the holders — ``scale_up`` checks it under
    the same lock, so a late scale-up can never add a holder that would
    miss its StopRecord.
    """

    def __init__(self, adapter: Adapter, holders: List[PartitionHolder],
                 lock: Optional[threading.Lock] = None):
        super().__init__(name="intake-job", daemon=True)
        self.adapter = adapter
        self.holders = holders
        self.frames_in = 0
        self.records_in = 0
        self.closing = False     # guarded-by: _lock
        self.error: Optional[BaseException] = None
        # the decoupled path passes the feed-handle lock in, so
        # scale_up's closing check and the drain flip serialize on
        # the SAME lock; the coupled baseline gets a private one
        self._lock = lock or threading.Lock()   # lock-name: handle

    def run(self) -> None:
        try:
            i = 0
            for frame in self.adapter.frames():
                while True:
                    # snapshot the live holder list each frame (elasticity)
                    hs = list(self.holders)
                    target = hs[i % len(hs)]
                    try:
                        target.push(frame)
                        break
                    except RuntimeError:
                        if not target.closed:
                            raise
                        # holder retired mid-push: re-target round-robin
                i += 1
                self.frames_in += 1
                # dict frames arrive pre-parsed; len() would count COLUMNS
                self.records_in += (batch_rows(frame)
                                    if isinstance(frame, dict)
                                    else len(frame))
        except BaseException as e:
            self.error = e
        finally:
            with self._lock:
                self.closing = True
                hs = list(self.holders)
            for h in hs:                 # close OUTSIDE the lock: push of
                if not h.closed:         # the StopRecord may block briefly
                    h.close()
