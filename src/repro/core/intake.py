"""The intake job (§7.2): adapter -> round-robin partitioner -> passive
intake partition holders.

Adapters obtain/receive raw data and arrange it into frames (one frame = one
computing batch of JSON-line byte records).  The intake job never parses in
the new framework — parsing happens inside the (parallel) computing jobs,
which is exactly the difference the paper measures against "current feeds"
where a single intake node parses everything (Fig 24's bottleneck).

Durable feeds (core/durability.py) add a resumable-offset contract to the
adapter: ``offset`` is the position from which a restarted feed can
re-obtain everything after the frames already yielded, ``resume(offset)``
fast-forwards a fresh adapter to that position, and adapters that cannot
replay lost input (a live socket) declare ``resumable = False`` /
raise ``NotResumableError`` so plan compilation rejects ``durable=`` on
them up front.  When a WAL is attached, ``IntakeJob`` appends every live
frame to it *before* the first push (write-ahead ack) and stamps the
frame with its log sequence number, which rides to the store sink and
drives the checkpoint watermark.
"""

from __future__ import annotations

import socket
import threading
import time
from typing import Iterator, List, Optional, Tuple

from repro.core.partition_holder import PartitionHolder
from repro.core.records import SyntheticTweets, batch_rows


class NotResumableError(RuntimeError):
    """The adapter cannot re-obtain past input from an offset (so a
    durable plan over it cannot guarantee zero loss across a crash)."""


class TrackedFrame(list):
    """A raw frame carrying the WAL sequence number(s) of the intake-log
    record(s) it covers.  A plain ``list`` subclass so every downstream
    consumer (parser, coalescing, ``len``) treats it as the frame it is;
    the ``wal_seqs`` stamp rides through the worker to the store sink,
    where completion marks the ledger.  Replayed frames are built as
    TrackedFrames by recovery — the intake job logs only plain frames,
    so a replay is never re-appended to the WAL.

    The observability layer (core/obs) rides the same vehicle:
    ``span_ids`` are the trace span ids stamped at intake (coalescing
    unions them), and ``t_intake`` is the monotonic intake timestamp
    that store-visible latency (``ingest_visible_latency_s``) is
    measured from.  Both default empty/0 so WAL- and recovery-built
    frames are unchanged."""

    __slots__ = ("wal_seqs", "span_ids", "t_intake")

    def __init__(self, lines, wal_seqs: Tuple[int, ...] = (),
                 span_ids: Tuple[int, ...] = (), t_intake: float = 0.0):
        super().__init__(lines)
        self.wal_seqs = tuple(wal_seqs)
        self.span_ids = tuple(span_ids)
        self.t_intake = t_intake


class TrackedBatch(dict):
    """The columnar counterpart of ``TrackedFrame``: a pre-parsed batch
    (plain column dict) carrying the same stamps.  A ``dict`` subclass,
    so every consumer that branches on ``isinstance(frame, dict)`` —
    the parser's pre-parsed path, coalescing, row counting — treats it
    as the batch it is, while ``getattr(frame, "span_ids", ...)`` lifts
    the stamps exactly like it does off a TrackedFrame.

    Two producers build these: the intake job (dict frames from
    pre-parsed adapters) and ``FeedHandle._push_downstream`` (enriched
    batches crossing a stage-group boundary), which is what makes
    multi-group plans keep WAL seqs, span ids, and the intake timestamp
    end to end instead of dropping them at the intermediate holder
    hand-off."""

    __slots__ = ("wal_seqs", "span_ids", "t_intake")

    def __init__(self, batch, wal_seqs: Optional[Tuple[int, ...]] = None,
                 span_ids: Tuple[int, ...] = (), t_intake: float = 0.0):
        super().__init__(batch)
        self.wal_seqs = tuple(wal_seqs) if wal_seqs else None
        self.span_ids = tuple(span_ids)
        self.t_intake = t_intake


class Adapter:
    """Iterator of frames (list[bytes]); ``stop()`` requests early end.

    Resumable-offset contract: ``frames()`` keeps ``self.offset`` equal
    to the resume position *after* the most recently yielded frame (the
    unit is adapter-defined: bytes for files, records for the synthetic
    stream).  ``resume(offset)`` positions a fresh instance so its
    ``frames()`` yields exactly the post-``offset`` remainder; the base
    class declines (``resumable = False``)."""

    resumable = False

    def __init__(self):
        self._stop = threading.Event()
        self.offset = 0   # resume position after the last yielded frame

    def stop(self) -> None:
        self._stop.set()

    def resume(self, offset: int) -> None:
        raise NotResumableError(
            f"{type(self).__name__} cannot resume from an offset")

    def frames(self) -> Iterator[List[bytes]]:
        raise NotImplementedError


class SyntheticAdapter(Adapter):
    """Deterministic tweet stream: ``total`` records in ``frame_size``
    frames, optionally rate-limited (records/second).  Offset = records
    emitted; ``resume(n)`` regenerates and discards the first ``n``
    records (the stream is seed-deterministic, so the remainder is
    bitwise the one a crashed feed would have produced)."""

    resumable = True

    def __init__(self, total: int, frame_size: int, seed: int = 0,
                 rate: Optional[float] = None):
        super().__init__()
        self.total, self.frame_size, self.rate = total, frame_size, rate
        self.source = SyntheticTweets(seed=seed)
        self._resume_at = 0

    def resume(self, offset: int) -> None:
        offset = int(offset)
        if not 0 <= offset <= self.total:
            raise ValueError(
                f"resume offset {offset} outside [0, {self.total}]")
        self._resume_at = offset
        self.offset = offset

    def frames(self) -> Iterator[List[bytes]]:
        # Fast-forward by replaying EXACTLY the chunked draws the
        # original run made: raw_lines interleaves vectorized rng draws
        # sized by the call with per-record draws, so any other chunking
        # desyncs the stream.  A mid-frame offset lands inside one
        # original frame_size chunk — regenerate that chunk whole and
        # emit its unseen suffix as a short first frame.
        drawn = 0
        first: List[bytes] = []
        while drawn < self._resume_at:
            n = min(self.frame_size, self.total - drawn)
            chunk = self.source.raw_lines(n)
            rest = self._resume_at - drawn
            if rest < n:
                first = chunk[rest:]
            drawn += n

        def gen() -> Iterator[List[bytes]]:
            if first:
                yield first
            yield from self.source.batches(self.total - drawn,
                                           self.frame_size)

        t0 = time.perf_counter()
        sent = 0
        for frame in gen():
            if self._stop.is_set():
                return
            if self.rate:
                target = t0 + sent / self.rate
                delay = target - time.perf_counter()
                if delay > 0:
                    time.sleep(delay)
            sent += len(frame)
            self.offset = self._resume_at + sent
            yield frame


class FileAdapter(Adapter):
    """JSON-lines file -> frames.  Offset = byte position after the last
    line of the last yielded frame; ``resume(offset)`` seeks."""

    resumable = True

    def __init__(self, path: str, frame_size: int):
        super().__init__()
        self.path, self.frame_size = path, frame_size
        self._resume_at = 0

    def resume(self, offset: int) -> None:
        offset = int(offset)
        if offset < 0:
            raise ValueError(f"resume offset {offset} < 0")
        self._resume_at = offset
        self.offset = offset

    def frames(self) -> Iterator[List[bytes]]:
        buf: List[bytes] = []
        # manual readline loop (not ``for line in f``): the read-ahead
        # iterator would desync f.tell() from the consumed position,
        # and the offset contract needs the exact byte after the frame
        with open(self.path, "rb") as f:
            if self._resume_at:
                f.seek(self._resume_at)
            self.offset = f.tell()
            while True:
                line = f.readline()
                if not line:
                    break
                if self._stop.is_set():
                    return
                stripped = line.strip()
                if stripped:
                    buf.append(stripped)
                if len(buf) >= self.frame_size:
                    self.offset = f.tell()
                    yield buf
                    buf = []
            if buf:
                self.offset = f.tell()
                yield buf


class SocketAdapter(Adapter):
    """The paper's socket feed (Fig 4): newline-delimited JSON over TCP.
    Listens on (host, port); one connection at a time; EOF ends the feed.

    Explicitly not resumable: bytes a crashed feed failed to log are
    gone from a live socket, so ``durable=`` on this adapter is a
    compile-time ``PlanError`` (the upstream must re-send, e.g. via a
    file spool or a seekable broker) rather than a restart-time
    surprise."""

    resumable = False

    def __init__(self, host: str, port: int, frame_size: int):
        super().__init__()
        self.host, self.port, self.frame_size = host, port, frame_size
        self._srv = socket.create_server((host, port))
        self._srv.settimeout(0.5)

    @property
    def address(self):
        return self._srv.getsockname()

    def resume(self, offset: int) -> None:
        raise NotResumableError(
            "SocketAdapter cannot replay lost socket input from an "
            "offset; spool the stream to a file (FileAdapter) for "
            "durable ingestion")

    def frames(self) -> Iterator[List[bytes]]:
        try:
            while not self._stop.is_set():
                try:
                    conn, _ = self._srv.accept()
                    break
                except socket.timeout:
                    continue
            else:
                return
            buf: List[bytes] = []
            with conn, conn.makefile("rb") as f:
                for line in f:
                    if self._stop.is_set():
                        return
                    line = line.strip()
                    if not line:
                        continue
                    buf.append(line)
                    if len(buf) >= self.frame_size:
                        yield buf
                        buf = []
            if buf:
                yield buf
        finally:
            self._srv.close()


class IntakeJob(threading.Thread):
    """Long-running intake: distributes frames round-robin over the intake
    partition holders, then closes them (StopRecord drain, §7.1).

    ``holders`` is a live list — the elastic runtime appends (scale_up) and
    removes (scale_down) holders mid-feed under the feed handle's ``lock``;
    the round-robin partitioner re-targets automatically.  A push that
    lands on a holder retired between the snapshot and the push (it drained
    and closed) is retried against a fresh snapshot, so scale_down can
    never drop a frame.  On completion the intake flips ``closing`` under
    the lock *before* closing the holders — ``scale_up`` checks it under
    the same lock, so a late scale-up can never add a holder that would
    miss its StopRecord.

    With a WAL attached (durable plans), every *live* frame is appended
    to the log — together with the adapter's post-frame resume offset —
    before the first push attempt, and the frame is stamped with the
    record's sequence number.  Replayed frames (already ``TrackedFrame``)
    pass through unlogged.
    """

    def __init__(self, adapter: Adapter, holders: List[PartitionHolder],
                 lock: Optional[threading.Lock] = None,
                 wal=None, ledger=None, obs=None):
        super().__init__(name="intake-job", daemon=True)
        self.adapter = adapter
        self.holders = holders
        self.frames_in = 0
        self.records_in = 0
        self.closing = False     # guarded-by: _lock
        self.error: Optional[BaseException] = None
        self._wal = wal
        self._ledger = ledger
        self._obs = obs          # FeedObs (None for bare/test intakes)
        self._wal_hist = (obs.registry.histogram("wal_append_s")
                          if obs is not None and wal is not None else None)
        # the decoupled path passes the feed-handle lock in, so
        # scale_up's closing check and the drain flip serialize on
        # the SAME lock; the coupled baseline gets a private one
        self._lock = lock or threading.Lock()   # lock-name: handle

    def run(self) -> None:
        try:
            i = 0
            t_last = time.perf_counter()
            for frame in self.adapter.frames():
                draw_s = time.perf_counter() - t_last
                wal_s = None
                if self._wal is not None and not isinstance(
                        frame, (TrackedFrame, dict)):
                    # write-ahead ack: log before any holder sees it
                    off = getattr(self.adapter, "offset", 0)
                    t_wal = time.perf_counter()
                    seq = self._wal.append_frame(off, frame)
                    wal_s = time.perf_counter() - t_wal
                    self._ledger.note_logged(seq, off)
                    frame = TrackedFrame(frame, (seq,))
                if self._obs is not None:
                    # currency stamp (always) + span ids (tracing only);
                    # no lock is held here (feedlint R6 discipline).
                    # Pre-parsed dict frames ride a TrackedBatch, raw
                    # line frames a TrackedFrame — same stamps either way
                    if isinstance(frame, dict):
                        if not isinstance(frame, TrackedBatch):
                            frame = TrackedBatch(frame)
                        nrows = batch_rows(frame)
                    else:
                        if not isinstance(frame, TrackedFrame):
                            frame = TrackedFrame(frame)
                        nrows = len(frame)
                    frame.t_intake = time.monotonic()
                    if wal_s is not None:
                        self._wal_hist.observe(wal_s)
                    if self._obs.tracing:
                        frame.span_ids = (self._obs.new_span(),)
                        self._obs.emit("intake.draw", frame.span_ids,
                                       t0=frame.t_intake, dur=draw_s,
                                       rows=nrows)
                        if wal_s is not None:
                            self._obs.emit("wal.append", frame.span_ids,
                                           t0=frame.t_intake, dur=wal_s,
                                           rows=nrows)
                while True:
                    # snapshot the live holder list each frame (elasticity)
                    hs = list(self.holders)
                    target = hs[i % len(hs)]
                    try:
                        target.push(frame)
                        break
                    except RuntimeError:
                        if not target.closed:
                            raise
                        # holder retired mid-push: re-target round-robin
                i += 1
                self.frames_in += 1
                # dict frames arrive pre-parsed; len() would count COLUMNS
                self.records_in += (batch_rows(frame)
                                    if isinstance(frame, dict)
                                    else len(frame))
                t_last = time.perf_counter()
        except BaseException as e:
            self.error = e
        finally:
            with self._lock:
                self.closing = True
                hs = list(self.holders)
            for h in hs:                 # close OUTSIDE the lock: push of
                if not h.closed:         # the StopRecord may block briefly
                    h.close()
