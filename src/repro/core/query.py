"""Analytical queries over the enriched column store (the read side the
paper stores enrichments FOR: "stored (and queried) together with the
data" so complex analytical queries can use them, §1/§8).

    result = (store.query()
              .where(col("safety_level") >= 3)
              .group_by("country")
              .agg(total=agg.sum("religious_population"),
                   n=agg.count(),
                   top=agg.topk("religious_population", k=3))
              .execute())

Four properties, in execution order:

  * **Snapshot consistency** — ``execute()`` runs against a pinned
    ``StoreSnapshot``: per partition, the unit list (segments + buffered
    chunks), a copy of the pk index, and the row watermark are captured
    under ONE lock acquisition (``StoragePartition.snapshot_view``).
    Concurrent ingest appends, repair upserts, filter-deletes, and
    compactions land after the watermark or behind retained files — the
    query sees exactly one consistent version of every pk (per-partition
    snapshot isolation; a pk lives in exactly one hash partition, so
    latest-wins is globally exact).
  * **Latest-wins** — superseded row versions accumulate append-only
    (upserts, repairs) until compaction; a scanned row counts only if the
    snapshot's pk index still points at its position.  Deleted pks
    (repair filter-deletes) drop out the same way.
  * **Zone-map pruning** — structured predicates (``col("x") >= 3``,
    combinable with ``&``/``|``/``~``) are interval-checked against each
    segment's persisted per-column min/max BEFORE any IO: a segment the
    predicate provably cannot match is skipped entirely, and surviving
    segments decompress only the referenced + selected columns
    (predicate/column pushdown into the npz member reads).
  * **Kernel-backed aggregation** — group-by aggregates route through the
    enrichment dispatch layer (core/enrich/dispatch.py): ``count`` and
    32-bit ``sum`` ride ``dispatch.segment_sum`` (the one-hot x matmul
    MXU kernel on TPU), ``topk`` rides ``dispatch.segment_topk`` (the
    per-segment top-k Pallas kernel).  Group keys map to dense segment
    ids against an incrementally-grown sorted dictionary; the segment
    count is padded to a power-of-two bucket so the jit cache sees a
    bounded shape set, exactly like the write-side operators.  Integer
    sums are widened to int64 first (dispatch's EXPLICIT 64-bit XLA
    fallback — reported per query via ``QueryStats``) so totals are
    exact.  By default aggregation is **batched** (``execute(batched=
    True)``): surviving units' masked rows are concatenated in scan
    order (``dispatch.concat_rows``) so the whole query pays one
    dispatch per aggregate instead of one per unit — at 2K-row segments
    that is the difference between launch-overhead-bound and
    compute-bound aggregation.

``QueryStats`` (on every result) reports units scanned vs pruned and row
counts — the observability the fig_query benchmark and the pruning
acceptance criterion read.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.storage import StorageJob, PartitionSnapshot, ZoneMap


class QueryError(ValueError):
    """Invalid query, detected before any scan IO."""


# ---------------------------------------------------------------------------
# predicate algebra (zone-map-aware)
# ---------------------------------------------------------------------------

class Predicate:
    """Base class: ``mask(cols)`` evaluates vectorized over a unit's
    columns; ``maybe(zone_map)`` is the pruning test — False means the
    unit PROVABLY contains no matching row (conservative: unknown columns
    or missing zone maps answer True)."""

    def mask(self, cols: Dict[str, np.ndarray]) -> np.ndarray:
        raise NotImplementedError

    def maybe(self, zm: ZoneMap) -> bool:
        return True

    @property
    def columns(self) -> Optional[frozenset]:
        """Columns the predicate reads; None = unknown (read everything)."""
        return frozenset()

    def __and__(self, other: "Predicate") -> "Predicate":
        return _And(self, _as_pred(other))

    def __or__(self, other: "Predicate") -> "Predicate":
        return _Or(self, _as_pred(other))

    def __invert__(self) -> "Predicate":
        return _Not(self)


def _as_pred(p) -> Predicate:
    if isinstance(p, Predicate):
        return p
    if callable(p):
        return _Raw(p)
    raise QueryError(f"not a predicate: {p!r} (use col(...) comparisons "
                     "or a callable over the column dict)")


class _Cmp(Predicate):
    _OPS = ("==", "!=", "<", "<=", ">", ">=")

    def __init__(self, name: str, op: str, value):
        assert op in self._OPS
        self.name, self.op, self.value = name, op, value

    def mask(self, cols):
        c, v = cols[self.name], self.value
        return {"==": c == v, "!=": c != v, "<": c < v, "<=": c <= v,
                ">": c > v, ">=": c >= v}[self.op]

    def maybe(self, zm):
        if self.name not in zm:
            return True
        mn, mx = zm[self.name]
        v = self.value
        return {"==": mn <= v <= mx,
                "!=": not (mn == mx == v),
                "<": mn < v, "<=": mn <= v,
                ">": mx > v, ">=": mx >= v}[self.op]

    @property
    def columns(self):
        return frozenset((self.name,))

    def __repr__(self):
        return f"(col({self.name!r}) {self.op} {self.value!r})"


class _IsIn(Predicate):
    def __init__(self, name: str, values: Sequence):
        self.name = name
        self.values = np.asarray(sorted(values))
        if self.values.size == 0:
            raise QueryError("isin() needs at least one value")

    def mask(self, cols):
        return np.isin(cols[self.name], self.values)

    def maybe(self, zm):
        if self.name not in zm:
            return True
        mn, mx = zm[self.name]
        return bool(np.any((self.values >= mn) & (self.values <= mx)))

    @property
    def columns(self):
        return frozenset((self.name,))

    def __repr__(self):
        return f"(col({self.name!r}).isin({self.values.tolist()!r}))"


class _And(Predicate):
    def __init__(self, a: Predicate, b: Predicate):
        self.a, self.b = a, b

    def mask(self, cols):
        return self.a.mask(cols) & self.b.mask(cols)

    def maybe(self, zm):
        return self.a.maybe(zm) and self.b.maybe(zm)

    @property
    def columns(self):
        ca, cb = self.a.columns, self.b.columns
        return None if ca is None or cb is None else ca | cb

    def __repr__(self):
        return f"({self.a!r} & {self.b!r})"


class _Or(_And):
    def mask(self, cols):
        return self.a.mask(cols) | self.b.mask(cols)

    def maybe(self, zm):
        return self.a.maybe(zm) or self.b.maybe(zm)

    def __repr__(self):
        return f"({self.a!r} | {self.b!r})"


class _Not(Predicate):
    # zone maps answer "can [min,max] intersect the predicate's accepting
    # set"; the complement of an interval test is not interval-decidable
    # in general, so ~p never prunes (conservative, always correct)
    def __init__(self, p: Predicate):
        self.p = p

    def mask(self, cols):
        return ~self.p.mask(cols)

    @property
    def columns(self):
        return self.p.columns

    def __repr__(self):
        return f"(~{self.p!r})"


class _Raw(Predicate):
    """An opaque callable over the column dict: no pruning, and every
    stored column is read for it (prefer ``col(...)`` comparisons)."""

    def __init__(self, fn: Callable[[Dict[str, np.ndarray]], np.ndarray]):
        self.fn = fn

    def mask(self, cols):
        out = np.asarray(self.fn(cols))
        if out.dtype != np.bool_:
            raise QueryError("callable predicate must return a bool mask")
        return out

    @property
    def columns(self):
        return None


class ColRef:
    """``col("safety_level") >= 3`` — the builder predicates start from."""

    def __init__(self, name: str):
        self.name = name

    def __eq__(self, v):                                  # type: ignore
        return _Cmp(self.name, "==", v)

    def __ne__(self, v):                                  # type: ignore
        return _Cmp(self.name, "!=", v)

    def __lt__(self, v):
        return _Cmp(self.name, "<", v)

    def __le__(self, v):
        return _Cmp(self.name, "<=", v)

    def __gt__(self, v):
        return _Cmp(self.name, ">", v)

    def __ge__(self, v):
        return _Cmp(self.name, ">=", v)

    def isin(self, values: Sequence):
        return _IsIn(self.name, values)

    def between(self, lo, hi):
        """Inclusive range — the selective-scan idiom zone maps love."""
        return _Cmp(self.name, ">=", lo) & _Cmp(self.name, "<=", hi)

    __hash__ = None


def col(name: str) -> ColRef:
    return ColRef(name)


# ---------------------------------------------------------------------------
# aggregations
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AggSpec:
    kind: str                       # sum | count | mean | topk
    column: Optional[str] = None
    k: int = 0
    payload: Optional[str] = None   # topk: column returned (default: id)


class agg:
    """Aggregation constructors for ``Query.agg(name=...)``."""

    @staticmethod
    def sum(column: str) -> AggSpec:                      # noqa: A003
        return AggSpec("sum", column)

    @staticmethod
    def count() -> AggSpec:
        return AggSpec("count")

    @staticmethod
    def mean(column: str) -> AggSpec:
        return AggSpec("mean", column)

    @staticmethod
    def topk(column: str, k: int, payload: str = "id") -> AggSpec:
        """Per group: the ``payload`` values of the ``k`` largest
        ``column`` rows (value desc, ties by scan order), -1-filled.
        ``column`` must be non-negative integers (the segment_topk
        contract shared with the Q3 state builder)."""
        if k < 1:
            raise QueryError(f"topk k must be >= 1, got {k}")
        return AggSpec("topk", column, k=k, payload=payload)


def _bucket_segments(n: int) -> int:
    """Pad the dense group count to a power-of-two bucket (floor 128) so
    the dispatch layer's jit cache sees a bounded set of segment counts —
    the same recompile-avoidance ladder the probe rows use (and the same
    code: dispatch.bucket_rows)."""
    from repro.core.enrich import dispatch
    return dispatch.bucket_rows(n, minimum=128)


class _GroupedAggregator:
    """Streaming group-by aggregation over scan batches.

    Keys map to dense segment ids against a sorted dictionary that grows
    as new keys appear (accumulators are realigned with bulk
    ``np.insert``).  Per-batch partials run through the kernel dispatch
    layer; host-side accumulation is 64-bit so totals are exact.  ``topk``
    keeps only each batch's per-key winners as candidates (the global
    top-k is a subset of the per-batch top-ks) and merges them in one
    final dispatch call — candidate order preserves scan order, so
    tie-breaking matches a naive full scan exactly."""

    def __init__(self, key_col: Optional[str], aggs: Dict[str, AggSpec]):
        self.key_col = key_col
        self.aggs = aggs
        self.batched_units = 0      # units deferred into the one batch
        self.keys = np.empty(0, np.int64)
        self._acc: Dict[str, np.ndarray] = {}
        self._cnt: Dict[str, np.ndarray] = {}
        self._cand: Dict[str, List[Tuple[np.ndarray, np.ndarray,
                                         np.ndarray]]] = {}
        self.invocations = 0
        for name, a in aggs.items():
            if a.kind in ("sum", "mean"):
                # int64 until a float partial arrives (then float64): int
                # totals stay exact — bitwise-equal to a naive full scan
                self._acc[name] = np.empty(0, np.int64)
            if a.kind in ("count", "mean"):
                self._cnt[name] = np.empty(0, np.int64)
            if a.kind == "topk":
                self._cand[name] = []

    def columns_needed(self) -> Tuple[str, ...]:
        """Columns ``consume`` reads: the group key plus every
        aggregate's value/payload columns — what the batched path must
        buffer per surviving unit."""
        need = set()
        if self.key_col is not None:
            need.add(self.key_col)
        for a in self.aggs.values():
            if a.column is not None:
                need.add(a.column)
            if a.kind == "topk" and a.payload is not None:
                need.add(a.payload)
        return tuple(sorted(need))

    # ------------------------------------------------------------- consume
    def _dense_ids(self, kv: np.ndarray) -> np.ndarray:
        new = np.setdiff1d(kv, self.keys)   # unique + sorted
        if new.size:
            pos = np.searchsorted(self.keys, new)
            self.keys = np.insert(self.keys, pos, new)
            for d in (self._acc, self._cnt):
                for name in d:
                    d[name] = np.insert(d[name], pos, 0)
        return np.searchsorted(self.keys, kv).astype(np.int32)

    def consume(self, cols: Dict[str, np.ndarray], mask: np.ndarray
                ) -> None:
        import jax.numpy as jnp
        from repro.core.enrich import dispatch

        if not mask.any():
            return
        if self.key_col is None:
            kv = np.zeros(int(mask.sum()), np.int64)
        else:
            kv = np.asarray(cols[self.key_col][mask])
            if kv.ndim != 1:
                raise QueryError(
                    f"group_by column {self.key_col!r} must be 1-D")
            kv = kv.astype(np.int64)
        seg = self._dense_ids(kv)
        nseg = int(self.keys.shape[0])
        nseg_b = _bucket_segments(nseg)
        # pad rows to a power-of-two bucket with overflow-segment rows
        # (dropped on every path), so the eager jnp/XLA cache sees a
        # bounded set of shapes instead of one compile per unit's
        # match-count — the write side's recompile-avoidance scheme
        n = int(kv.shape[0])
        nb = dispatch.bucket_rows(n)
        seg_p = np.full(nb, nseg_b, np.int32)
        seg_p[:n] = seg
        seg_j = jnp.asarray(seg_p)

        def padded(v, dtype):
            out = np.zeros(nb, dtype)
            out[:n] = v
            return jnp.asarray(out)

        counted = False
        for name, a in self.aggs.items():
            if a.kind in ("count", "mean") and not counted:
                cnt = np.asarray(dispatch.segment_count(seg_j, nseg_b)
                                 )[:nseg].astype(np.int64)
                self.invocations += 1
                counted = True
            if a.kind == "count":
                self._cnt[name] += cnt
            elif a.kind in ("sum", "mean"):
                v = np.asarray(cols[a.column][mask])
                wide = (np.int64 if np.issubdtype(v.dtype, np.integer)
                        or v.dtype == np.bool_ else np.float64)
                part = np.asarray(dispatch.segment_sum(
                    padded(v, wide), seg_j, nseg_b))[:nseg]
                self.invocations += 1
                acc = self._acc[name]
                if np.issubdtype(part.dtype, np.floating) and \
                        acc.dtype != np.float64:
                    acc = acc.astype(np.float64)
                self._acc[name] = acc + part
                if a.kind == "mean":
                    self._cnt[name] += cnt
            elif a.kind == "topk":
                v = np.asarray(cols[a.column][mask])
                if not (np.issubdtype(v.dtype, np.integer)
                        or v.dtype == np.bool_):
                    raise QueryError(
                        f"topk column {a.column!r} must be integer "
                        f"(dtype {v.dtype}): ranking follows the "
                        "segment_topk integer-composite contract")
                if v.size and int(v.max()) > np.iinfo(np.int32).max:
                    # BOTH segment_topk paths rank within [0, 2^31):
                    # the reference's composite key saturates there and
                    # the kernel's winner table is int32 — wide values
                    # would silently tie at the top, so fail loudly
                    raise QueryError(
                        f"topk column {a.column!r} holds values above "
                        "int32 range; segment_topk ranks within "
                        "[0, 2^31) (negatives rank as 0)")
                # keep the native width: dispatch routes 64-bit (and
                # unsigned) dtypes to the reference path, never through
                # an int32 wrap
                v = v.astype(np.int32) if v.dtype == np.bool_ else v
                pay = np.asarray(cols[a.payload][mask])
                kidx = np.arange(nb, dtype=np.int64)
                pidx, _ = dispatch.segment_topk(
                    padded(v, v.dtype), seg_j, kidx, nseg_b, a.k)
                self.invocations += 1
                pidx = np.asarray(pidx)[:nseg]          # (nseg, k) into kidx
                sel = pidx[pidx >= 0]
                # candidates in scan order: rows within the batch ascend
                order = np.sort(sel)
                self._cand[name].append(
                    (self.keys[seg[order]], v[order], pay[order]))

    # -------------------------------------------------------------- finish
    def finish(self) -> Dict[str, np.ndarray]:
        import jax.numpy as jnp
        from repro.core.enrich import dispatch

        out: Dict[str, np.ndarray] = {}
        nseg = int(self.keys.shape[0])
        if self.key_col is not None:
            out[self.key_col] = self.keys.copy()
        for name, a in self.aggs.items():
            if a.kind == "count":
                out[name] = self._cnt[name].copy()
            elif a.kind == "sum":
                out[name] = self._acc[name].copy()
            elif a.kind == "mean":
                with np.errstate(invalid="ignore"):
                    out[name] = self._acc[name] / self._cnt[name]
            elif a.kind == "topk":
                cands = self._cand[name]
                if nseg == 0 or not cands:
                    out[name] = np.full((nseg, a.k), -1)
                    continue
                ck = np.concatenate([c[0] for c in cands])
                cv = np.concatenate([c[1] for c in cands])
                cp = np.concatenate([c[2] for c in cands])
                seg = np.searchsorted(self.keys, ck).astype(np.int32)
                nseg_b = _bucket_segments(nseg)
                n = int(cv.shape[0])
                nb = dispatch.bucket_rows(n)
                seg_p = np.full(nb, nseg_b, np.int32)
                seg_p[:n] = seg
                cv_p = np.zeros(nb, cv.dtype)
                cv_p[:n] = cv
                cp_p = np.zeros(nb, cp.dtype)
                cp_p[:n] = cp
                pay, _ = dispatch.segment_topk(
                    jnp.asarray(cv_p), jnp.asarray(seg_p),
                    jnp.asarray(cp_p), nseg_b, a.k)
                self.invocations += 1
                out[name] = np.asarray(pay)[:nseg]
        return out


# ---------------------------------------------------------------------------
# snapshots
# ---------------------------------------------------------------------------

class StoreSnapshot:
    """Pinned consistent view across every partition of a ``StorageJob``.
    Each partition is internally consistent (units + index + watermark
    from one lock hold); a pk hashes to exactly one partition, so
    latest-wins semantics are globally exact."""

    def __init__(self, storage: StorageJob):
        self.parts: List[PartitionSnapshot] = []
        try:
            for p in storage.partitions:
                self.parts.append(p.snapshot_view())
        except BaseException:
            self.close()
            raise

    @property
    def watermark(self) -> int:
        """Total row versions visible (sum of partition watermarks)."""
        return sum(ps.watermark for ps in self.parts)

    @property
    def live_rows(self) -> int:
        return sum(ps.live_rows for ps in self.parts)

    def close(self) -> None:
        for ps in self.parts:
            ps.release()

    def __enter__(self) -> "StoreSnapshot":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# the query builder
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class QueryStats:
    units: int = 0               # scannable units in the snapshot
    units_pruned: int = 0        # skipped via zone maps (no IO at all)
    segments: int = 0            # flushed-segment units among `units`
    segments_pruned: int = 0
    rows_scanned: int = 0        # rows of units actually read
    rows_live: int = 0           # after latest-wins
    rows_matched: int = 0        # after the predicate
    agg_invocations: int = 0     # dispatch-layer kernel calls
    agg_batched_units: int = 0   # units folded into the one-dispatch batch
    # execution-path split of the aggregate dispatches (dispatch.py's
    # per-thread path tape): kernel vs fallback, with the wide-dtype XLA
    # fallback (64-bit sums — kernel accumulates in 32 bits) called out
    agg_kernel_dispatches: int = 0
    agg_fallback_dispatches: int = 0
    agg_64bit_fallbacks: int = 0
    wall_s: float = 0.0


class QueryResult(dict):
    """Column dict (numpy arrays) + ``stats``; group-by results are keyed
    by the group column (ascending) + one entry per aggregate."""

    def __init__(self, columns: Dict[str, np.ndarray], stats: QueryStats,
                 snapshot_watermark: int):
        super().__init__(columns)
        self.stats = stats
        self.watermark = snapshot_watermark

    @property
    def rows(self) -> int:
        for v in self.values():
            return int(v.shape[0])
        return 0


class Query:
    """Composable analytical query over a ``StorageJob`` — build with
    ``where``/``select``/``group_by``/``agg``, run with ``execute()``."""

    def __init__(self, storage: StorageJob):
        self._storage = storage
        self._pred: Optional[Predicate] = None
        self._select: Optional[Tuple[str, ...]] = None
        self._group: Optional[str] = None
        self._aggs: Dict[str, AggSpec] = {}

    # ------------------------------------------------------------- builders
    def where(self, *preds) -> "Query":
        """AND-combine predicates (``col(...)`` comparisons or callables
        over the column dict; only the former can prune segments)."""
        if not preds:
            raise QueryError("where() needs at least one predicate")
        for p in preds:
            p = _as_pred(p)
            self._pred = p if self._pred is None else (self._pred & p)
        return self

    def select(self, *cols: str) -> "Query":
        if not cols:
            raise QueryError("select() needs at least one column")
        self._select = tuple(dict.fromkeys(cols))
        return self

    def group_by(self, column: str) -> "Query":
        if self._group is not None:
            raise QueryError("group_by() may appear at most once")
        self._group = column
        return self

    def agg(self, **aggs: AggSpec) -> "Query":
        for name, a in aggs.items():
            if not isinstance(a, AggSpec):
                raise QueryError(
                    f"agg {name}={a!r}: use agg.sum/count/mean/topk")
        self._aggs.update(aggs)
        return self

    # -------------------------------------------------------------- execute
    def _needed_columns(self) -> Optional[Tuple[str, ...]]:
        """Columns the scan must materialize; None = all (opaque
        predicate).  'id' always rides along (latest-wins needs it)."""
        pred_cols = self._pred.columns if self._pred is not None \
            else frozenset()
        if pred_cols is None:
            return None
        need = {"id"} | set(pred_cols)
        if self._aggs:
            if self._group is not None:
                need.add(self._group)
            for a in self._aggs.values():
                if a.column is not None:
                    need.add(a.column)
                if a.payload is not None:
                    need.add(a.payload)
        elif self._select is not None:
            need |= set(self._select)
        else:
            return None                       # plain scan: all columns
        return tuple(need)

    def execute(self, prune: bool = True,
                snapshot: Optional[StoreSnapshot] = None,
                batched: bool = True) -> QueryResult:
        """Run the query.  ``prune=False`` disables zone-map pruning (the
        benchmark's A/B axis — results must be identical).  ``batched``
        (default) defers aggregation: surviving units' masked rows are
        concatenated in scan order (``dispatch.concat_rows``) and the
        whole query pays ONE ``segment_*`` dispatch per aggregate instead
        of one per unit — results are identical either way (int sums are
        64-bit exact and order-free, top-k tie-breaking is scan-order on
        both paths).  Passing a ``snapshot`` runs against a view taken
        earlier (the caller keeps ownership and must ``close()`` it);
        otherwise a fresh snapshot is pinned for exactly this
        execution."""
        if self._group is not None and not self._aggs:
            raise QueryError("group_by() without agg(): add at least one "
                             "aggregate (agg.count() counts group sizes)")
        if self._aggs and self._select is not None:
            raise QueryError("select() and agg() are mutually exclusive: "
                             "aggregates define the output columns")
        from repro.core.enrich import dispatch
        t0 = time.perf_counter()
        stats = QueryStats()
        own = snapshot is None
        snap = StoreSnapshot(self._storage) if own else snapshot
        tape = bool(self._aggs)
        if tape:
            dispatch.path_tape_start()
        try:
            need = self._needed_columns()
            gagg = _GroupedAggregator(self._group, self._aggs) \
                if self._aggs else None
            # batched-agg: per-unit masked slices of the columns consume
            # reads (at least one column so the row count survives even
            # a bare count() with no group key)
            agg_cols = (gagg.columns_needed() or ("id",)) \
                if gagg is not None else ()
            pending: List[Dict[str, np.ndarray]] = []
            scanned: Dict[str, List[np.ndarray]] = {}
            sel_cols: Optional[Tuple[str, ...]] = None
            # per-unit read tally (segment path or chunk tag), kept local
            # through the scan and published ONCE afterwards — the hot
            # loop never touches the store-stats lock
            reads: Dict[Tuple[int, str], int] = {}
            for ps in snap.parts:
                for unit in ps.units:
                    is_seg = unit.path is not None
                    stats.units += 1
                    stats.segments += int(is_seg)
                    if unit.rows == 0:
                        continue
                    if prune and self._pred is not None and \
                            unit.zone_map is not None and \
                            not self._pred.maybe(unit.zone_map):
                        stats.units_pruned += 1
                        stats.segments_pruned += int(is_seg)
                        continue
                    cols = unit.read(need)
                    tag = (unit.path if unit.path is not None
                           else f"chunk@{unit.base}")
                    key = (ps.pid, tag)
                    reads[key] = reads.get(key, 0) + 1
                    stats.rows_scanned += unit.rows
                    m = ps.live_mask(cols["id"], unit.base)
                    stats.rows_live += int(m.sum())
                    if self._pred is not None:
                        m = m & self._pred.mask(cols)
                    stats.rows_matched += int(m.sum())
                    if gagg is not None:
                        if batched:
                            if m.any():
                                pending.append(
                                    {k: np.asarray(cols[k])[m]
                                     for k in agg_cols})
                                stats.agg_batched_units += 1
                        else:
                            gagg.consume(cols, m)
                        continue
                    if sel_cols is None:
                        sel_cols = self._select if self._select is not None \
                            else tuple(cols)
                    for k in sel_cols:
                        if k not in cols:
                            raise QueryError(
                                f"unknown column {k!r}; stored columns: "
                                f"{sorted(cols)}")
                        scanned.setdefault(k, []).append(
                            np.asarray(cols[k])[m])
            if gagg is not None:
                if pending:
                    joined, n = dispatch.concat_rows(pending)
                    gagg.consume(joined, np.ones(n, bool))
                out = gagg.finish()
                stats.agg_invocations = gagg.invocations
            elif sel_cols is None:       # empty store
                out = {k: np.empty(0) for k in (self._select or ())}
            else:
                out = {k: np.concatenate(scanned[k]) if scanned[k]
                       else np.empty(0) for k in sel_cols}
            if tape:
                tape = False
                paths = dispatch.path_tape_stop()
                for (_op, path), c in paths.items():
                    if path == "kernel":
                        stats.agg_kernel_dispatches += c
                    else:
                        stats.agg_fallback_dispatches += c
                        if path == "xla_64bit":
                            stats.agg_64bit_fallbacks += c
            if reads:
                self._storage.note_unit_reads(reads.items())
            stats.wall_s = time.perf_counter() - t0
            return QueryResult(out, stats, snap.watermark)
        finally:
            if tape:
                dispatch.path_tape_stop()
            if own:
                snap.close()
