"""Progressive re-enrichment (the repair subsystem): keep *stored*
enrichments current as reference data changes.

The paper's adaptiveness (Model 2) refreshes reference snapshots for
**in-flight** batches only — rows already in the column store keep
whatever enrichment was current at ingest time and silently go stale when
a ``RefTable`` is upserted.  This module closes that gap with the
pay-as-you-go re-enrichment model of PIQUE (Ghosh et al., 1805.12033),
declared — per INGESTBASE's argument (Jindal et al., 1701.06093) that
post-ingestion logic belongs in the ingestion plan — on the plan itself:

    pipeline(adapter).parse(...).enrich(Q.Q1)
        .store(refresh=RepairSpec(budget_rows_s=..., max_lag_s=...))

Four pieces:

  * **Lineage** — every stored chunk/segment records the ref-version map
    its rows were enriched under (captured at the computing job's snapshot,
    persisted in the manifest; see storage.py).
  * **Staleness index** — ``RefTable`` upsert/delete listeners publish
    (version, time, changed keys); a stored unit is stale when its lineage
    trails any subscribed table's current version.  Coarse version match
    first; where the UDF declares ``repair_keys`` (table -> probe column),
    a vectorized dirty-key probe against the stored join-key column
    refines the unit down to the rows actually affected — often to zero,
    in which case the unit's lineage is simply advanced.
  * **Repair scheduler** — this thread drains a priority queue of stale
    units (oldest staleness first), re-runs the plan's fused enrich stages
    through a ``ComputingRunner`` that SHARES the feed's ``PredeployCache``
    (same UDF identity + same padded batch shape -> the already-compiled
    executable; zero recompilation), and upserts results in place with
    ``StoragePartition.repair_rows`` — a conditional index check gives
    exactly-once semantics under concurrent ingestion (a racing ingest
    upsert always wins; re-scans are no-ops).  A token bucket caps repair
    at ``budget_rows_s`` scanned rows/s, and the scheduler *yields* while
    the feed has real ingestion backlog (or its elastic groups are scaled
    above their minimum), so repair never competes with the paper's
    primary job.  ``drain()`` runs unbudgeted after the feed ends so
    ``join()`` returns a converged store.
  * **Currency metrics** — ``RepairStats``: stale/repaired/superseded/
    refined row counts and ``repair_lag`` p50/p95 (ref upsert -> repaired
    row), surfaced through ``FeedStats`` and the fig_repair benchmark.

Semantics notes: filters are re-evaluated during repair, and a stored row
the re-evaluated filter now rejects is **deleted** from the store
(``StoragePartition.delete_rows`` — the same conditional index check as
``repair_rows``, so a concurrent ingest upsert always wins and re-scans
are no-ops; counted ``invalidated_rows``/``deleted_rows``).  Superseded
and deleted row versions accumulate append-only until compaction
(core/compaction.py) reclaims them; repair coordinates with compaction —
and with its leveled segment MERGES, which additionally dissolve unit
boundaries and re-sort rows across them — through the partition's
**layout epoch**: a unit's epoch is captured with its scan and passed
back to every conditional write, so a renumbering mid-repair rejects the
batch instead of letting a reused position number spuriously match (the
unit stays stale and is simply re-scanned).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, NamedTuple, Optional, Tuple

import numpy as np

from repro.core import records
from repro.core.computing import ComputingRunner, ComputingSpec
from repro.core.refdata import RefStore
from repro.core.storage import Lineage, StorageJob


@dataclasses.dataclass(frozen=True)
class RepairSpec:
    """Repair policy for one plan's store sink (``.store(refresh=...)``).

    ``budget_rows_s`` is the token-bucket rate of *scanned* stored rows per
    second (scan + probe + re-enrich all ride on it) — the knob trading
    freshness against ingestion interference; ``max_lag_s`` is the
    staleness SLO: while the oldest unserviced ref change is younger than
    this, repair politely yields to any ingestion backlog — once it is
    older, repair stops yielding (the budget still applies), so sustained
    backlog can delay freshness by at most ~max_lag_s; ``priority``
    orders stale units across repair jobs sharing a node (lower = first,
    tie-broken by oldest staleness)."""
    budget_rows_s: float = 10_000.0
    max_lag_s: float = 5.0
    priority: int = 0
    interval_s: float = 0.02       # scheduler cadence while events pend
    # yield while queued ingestion backlog exceeds this many batches per
    # partition.  Default 0: ANY queued frame defers repair — ingestion is
    # the primary job, repair takes the idle gaps (and the post-feed drain)
    yield_backlog_batches: float = 0.0
    # token-bucket depth: small on purpose — a deep bucket lets a step that
    # begins in a momentary idle gap (e.g. the feed's final batches) spend
    # a large accumulated burst against ingestion's last stretch
    burst_s: float = 0.05

    def __post_init__(self):
        if self.budget_rows_s <= 0 or self.max_lag_s <= 0:
            raise ValueError("budget_rows_s and max_lag_s must be > 0")
        if self.interval_s <= 0 or self.burst_s <= 0:
            raise ValueError("interval_s and burst_s must be > 0")
        if self.yield_backlog_batches < 0:
            raise ValueError("yield_backlog_batches must be >= 0")


@dataclasses.dataclass
class RepairStats:
    """Currency accounting for one feed's repair job."""
    stale_rows: int = 0          # rows found needing re-enrichment
    repaired_rows: int = 0       # rows actually upserted in place
    superseded_rows: int = 0     # skipped: a concurrent ingest upsert won
    refined_rows: int = 0        # skipped via dirty-key probe refinement
    invalidated_rows: int = 0    # re-run filter rejected the stored row
    deleted_rows: int = 0        # ... and the conditional delete applied
    units_scanned: int = 0
    units_refined: int = 0       # advanced lineage without re-enriching
    repair_invocations: int = 0  # predeployed apply calls issued
    steps: int = 0
    yields: int = 0              # cycles skipped for ingestion backlog
    repair_s: float = 0.0        # scheduler time, scan through upsert
    drain_s: float = 0.0         # post-feed convergence time (join())
    # bounded ring: newest samples win, so the percentiles track the
    # recent window instead of leaking memory on long-running feeds
    lag_samples: List[float] = dataclasses.field(default_factory=list)

    MAX_LAG_SAMPLES = 4096
    # class attr (not a dataclass field, so merge/asdict never see it):
    # optional core/obs histogram the lag samples dual-write into
    _hist = None

    def attach_histogram(self, hist) -> None:
        """Dual-write lag samples into an obs histogram (the feed's
        ``repair_currency_s``) in addition to the local ring — the
        registry number and the dataclass percentiles then come from the
        same observations, which is exactly what the benchmark's
        registry-vs-driver cross-check validates."""
        self._hist = hist

    def add_lag(self, lag: float) -> None:
        self.lag_samples.append(lag)
        if len(self.lag_samples) > self.MAX_LAG_SAMPLES:
            del self.lag_samples[:len(self.lag_samples) // 2]
        if self._hist is not None:
            # callers hold at most the repair-step lock (blocking-ok):
            # histogram observes are legal there (feedlint R6)
            self._hist.observe(lag)

    def _lag_q(self, q: float) -> float:
        if not self.lag_samples:
            return 0.0
        xs = sorted(self.lag_samples)
        return float(xs[min(len(xs) - 1, int(q * len(xs)))])

    @property
    def repair_lag_p50_s(self) -> float:
        return self._lag_q(0.50)

    @property
    def repair_lag_p95_s(self) -> float:
        return self._lag_q(0.95)


def feed_busy(handle, per_part_rows: float) -> bool:
    """The yield test the background maintenance jobs share (repair here,
    compaction in core/compaction.py): True while the feed's computing
    workers have real ingestion backlog above ``per_part_rows`` queued
    rows per partition, or any elastic group is scaled above its floor
    (the controller judged the feed busy) — ingestion is the primary job;
    background work takes the idle gaps."""
    if handle is None or handle._live_workers <= 0:
        return False                 # feed drained: nobody to yield to
    for g in list(handle.stage_groups):
        holders = list(g.holders)
        rows = sum(hh.backlog()[0] for hh in holders)
        if rows > per_part_rows * max(1, len(holders)):   # 0-threshold:
            return True                                   # any backlog
        if g.elastic is not None and \
                len(holders) > g.elastic.min_partitions:
            return True
    return False


class _RefEvent(NamedTuple):
    version: int                  # table version AFTER the write
    t: float                      # monotonic publish time (lag metric)
    keys: Optional[np.ndarray]    # changed keys; None = unknown (coalesced)


class RepairJob(threading.Thread):
    """Background repair scheduler for one feed (one thread; its
    ``ComputingRunner`` is confined to it, ``step()`` is serialized by an
    internal lock so tests and ``drain()`` may call it directly)."""

    MAX_EVENTS = 512              # per-table event log bound (coalesced)
    REFINE_MAX_KEYS = 262_144     # dirty-key union cap for the probe

    def __init__(self, plan, storage: StorageJob, refstore: RefStore,
                 predeploy=None, handle=None):
        super().__init__(name=f"{plan.name}-repair", daemon=True)
        spec = plan.store_spec.refresh
        assert spec is not None and plan.udf is not None
        self.plan = plan
        self.spec: RepairSpec = spec
        self.storage = storage
        self.refstore = refstore
        self.handle = handle      # duck-typed FeedHandle (None in tests)
        self.stats = RepairStats()
        self._obs = getattr(handle, "obs", None)
        if self._obs is not None:
            self.stats.attach_histogram(
                self._obs.registry.histogram("repair_currency_s"))
        self.error: Optional[BaseException] = None
        self._tables: Tuple[str, ...] = plan.udf.ref_tables
        # table -> ALL declared probe columns (a chain may probe one table
        # through several batch columns; a row is affected if ANY hits)
        self._probe_cols: Dict[str, Tuple[str, ...]] = {}
        for t, col in plan.udf.repair_keys:
            self._probe_cols[t] = self._probe_cols.get(t, ()) + (col,)
        # version-gated Model 2 regardless of the plan's model: repair must
        # see fresh state per changed version, at Model-3 cost when quiet
        self._runner = ComputingRunner(
            ComputingSpec(plan.udf, plan.batch_size, "per_batch", "version"),
            refstore, predeploy)
        self._events: Dict[str, List[_RefEvent]] = {t: [] for t
                                                    in self._tables}  # guarded-by: _events_lock
        self._events_lock = threading.Lock()   # lock-name: repair-events
        # serializes step(); a dedicated background lock, so blocking work
        # (scans, re-enrichment dispatch) under it is by design
        self._step_lock = threading.Lock()     # lock-name: repair-step blocking-ok
        self._wake = threading.Event()
        self._stop_evt = threading.Event()
        self._tokens = spec.budget_rows_s * spec.burst_s  # guarded-by: _step_lock
        self._last_refill = time.monotonic()              # guarded-by: _step_lock
        # event-driven fast path: scanning every partition's lineage units
        # is cheap but not free — skip it entirely until a ref write (or
        # new stored data racing one) can have made something stale
        # _maybe_stale is a benign monotonic hint: set lock-free by
        # writers (_on_change), consumed under the step lock; a lost
        # update only costs one extra scan pass (left unguarded on
        # purpose — see docs/CONCURRENCY.md)
        self._maybe_stale = True
        self._clean_rows = -1                             # guarded-by: _step_lock
        # arrival time of the oldest ref change not yet fully serviced
        # (cleared on a clean pass) — drives the max_lag_s SLO override
        self._oldest_pending: Optional[float] = None
        refstore.subscribe(self._tables, self._on_change)

    # -------------------------------------------------------- change intake
    def _on_change(self, table: str, version: int,
                   keys: np.ndarray) -> None:
        """RefTable listener (runs on the writer's thread — cheap)."""
        with self._events_lock:
            log = self._events[table]
            log.append(_RefEvent(version, time.monotonic(),
                                 np.asarray(keys, np.int64)))
            if len(log) > self.MAX_EVENTS:
                # coalesce the oldest half into one keyless event (refines
                # to coarse matching for that version span, never misses)
                half = log[:len(log) // 2]
                merged = _RefEvent(max(e.version for e in half),
                                   min(e.t for e in half), None)
                self._events[table] = [merged] + log[len(log) // 2:]
            if self._oldest_pending is None:
                self._oldest_pending = log[-1].t
        self._maybe_stale = True
        self._wake.set()

    # ------------------------------------------------ durability (PR 7)
    SNAPSHOT_MAX_KEYS = 8192      # per-event key cap in a checkpoint

    def snapshot_events(self) -> Dict[str, List]:
        """JSON-serializable image of the per-table ref-event log for a
        coordinated checkpoint (core/durability.py).  Times are stored as
        *ages* (seconds before the snapshot) because ``time.monotonic``
        does not survive a process restart; oversized key sets degrade to
        ``None`` (coarse version matching — never misses, just probes
        less precisely)."""
        now = time.monotonic()
        with self._events_lock:
            return {
                t: [[int(e.version), max(0.0, now - e.t),
                     None if e.keys is None or
                     e.keys.size > self.SNAPSHOT_MAX_KEYS
                     else [int(k) for k in e.keys]]
                    for e in log]
                for t, log in self._events.items()}

    def restore_events(self, events: Dict[str, List]) -> None:
        """Rebuild the event log from a checkpoint image (crash-restart).
        Only called when the checkpointed ref fingerprints matched the
        current tables — otherwise recovery resets lineage and repair
        re-scans everything.  Call before ``start()``."""
        now = time.monotonic()
        with self._events_lock:
            for t, log in events.items():
                if t not in self._events:
                    continue
                self._events[t] = [
                    _RefEvent(int(v), now - float(age),
                              None if keys is None
                              else np.asarray(keys, np.int64))
                    for v, age, keys in log]
            pending = [e.t for log in self._events.values() for e in log]
            if pending:
                self._oldest_pending = min(pending)
        self._maybe_stale = True
        self._wake.set()

    def _dirty_keys(self, table: str,
                    have_version: int) -> Optional[np.ndarray]:
        """Union of keys changed since ``have_version``; None = unknown
        (coalesced history or too many keys: fall back to coarse)."""
        with self._events_lock:
            evs = [e for e in self._events[table]
                   if e.version > have_version]
        if not evs or any(e.keys is None for e in evs):
            return None
        keys = np.unique(np.concatenate([e.keys for e in evs]))
        if keys.size > self.REFINE_MAX_KEYS:
            return None
        return keys

    def _stale_since(self, table: str, have_version: int,
                     now: float) -> float:
        with self._events_lock:
            ts = [e.t for e in self._events[table]
                  if e.version > have_version]
        # no recorded event (recovered store, trimmed log): the staleness
        # is older than anything we observed — use the oldest retained
        # event, else "now" (lag 0; conservative-low but unavoidable)
        return min(ts) if ts else now

    # ----------------------------------------------------------- scheduling
    def run(self) -> None:
        while not self._stop_evt.is_set():
            self._wake.wait(self.spec.interval_s)
            self._wake.clear()
            if self._stop_evt.is_set():
                return
            try:
                self.step()
            except BaseException as e:   # surfaced by FeedHandle.join()
                self.error = e
                return

    def stop(self) -> None:
        self._stop_evt.set()
        self._wake.set()
        self.refstore.unsubscribe(self._tables, self._on_change)

    def _should_yield(self) -> bool:
        """Repair is the background job: defer while the feed is busy
        (``feed_busy`` — the contract shared with core/elasticity.py and
        the compaction job), unless the staleness SLO is breached."""
        h = self.handle
        if h is None:
            return False
        oldest = self._oldest_pending
        if oldest is not None and \
                time.monotonic() - oldest > self.spec.max_lag_s:
            # staleness SLO breached: stop deferring to ingestion (the
            # row budget still bounds how hard repair competes)
            return False
        return feed_busy(
            h, self.spec.yield_backlog_batches * self.plan.batch_size)

    def _refill(self, now: float) -> None:  # requires-lock: _step_lock
        cap = self.spec.budget_rows_s * self.spec.burst_s
        self._tokens = min(cap, self._tokens + (now - self._last_refill)
                           * self.spec.budget_rows_s)
        self._last_refill = now

    def _stale_units(self, versions: Lineage, now: float):
        """Priority queue of stale units: (priority, stale_since, partition,
        start, rows, lineage), oldest staleness first within a priority."""
        out = []
        for p in self.storage.partitions:
            for start, n, lin in p.lineage_units():
                since = None
                for t in self._tables:
                    if lin.get(t, -1) < versions[t]:
                        s = self._stale_since(t, lin.get(t, -1), now)
                        since = s if since is None else min(since, s)
                if since is not None:
                    out.append((self.spec.priority, since, p, start, n,
                                lin))
        out.sort(key=lambda u: (u[0], u[1], u[3]))
        return out

    def step(self, force: bool = False) -> int:
        """One scan/repair pass; returns rows repaired.  Synchronous and
        internally serialized, so tests and ``drain()`` call it directly.
        ``force`` ignores the budget and backlog yield (post-feed drain)."""
        with self._step_lock:
            t0 = time.perf_counter()
            now = time.monotonic()
            self.stats.steps += 1
            self._refill(now)
            if not force:
                if self._should_yield():
                    self.stats.yields += 1
                    return 0
                if self._tokens <= 0:
                    return 0
            # fast path: nothing can be stale — no ref write since the
            # last clean pass AND no new rows landed (a batch enriched
            # under pre-write versions may be written after a clean pass,
            # so row growth re-arms the scan)
            rows_now = sum(p.rows_total for p in self.storage.partitions)
            if not force and not self._maybe_stale and \
                    rows_now == self._clean_rows:
                return 0
            # clear BEFORE reading versions: a write racing this pass
            # re-arms the flag via its listener, so a clean verdict below
            # can never swallow a concurrent upsert (lost wake-up)
            self._maybe_stale = False
            versions = {t: self.refstore[t].version for t in self._tables}
            units = self._stale_units(versions, now)
            if not units:
                self._clean_rows = rows_now
                self._oldest_pending = None      # every change serviced
                return 0
            # stale work found (some may stay unprocessed under the
            # budget): keep scanning on subsequent steps
            self._maybe_stale = True
            repaired = 0
            for i, (_, since, p, start, n, lin) in enumerate(units):
                if not force and self._tokens <= 0:
                    break
                if not force and i and self._should_yield():
                    # backlog built mid-step: stop after the current unit
                    # so a step begun in an idle gap can't ride through a
                    # fresh burst of ingestion work
                    self.stats.yields += 1
                    break
                self._tokens -= n        # scanned rows consume budget
                repaired += self._repair_unit(p, start, n, lin, versions,
                                              since)
            self.stats.repair_s += time.perf_counter() - t0
            return repaired

    # ------------------------------------------------------------- repair
    def _repair_unit(self, part, start: int, n: int, lin: Lineage,
                     versions: Lineage, since: float) -> int:
        # layout-epoch capture: every conditional write below carries this
        # epoch, so a compaction or leveled merge that renumbers the
        # position space between the scan and the write rejects the batch
        # (position numbers freed by a shrink are reused by later appends
        # — without the epoch a stale positional check could spuriously
        # match; a merge additionally re-sorts rows ACROSS old unit
        # boundaries, so even a count-preserving merge moves them).  The
        # rejected unit keeps its old lineage, stays stale, and is
        # re-scanned.
        epoch = part.epoch
        t_unit = time.perf_counter()
        try:
            batch = part.read_rows(start, n)
        except IndexError:
            return 0          # compaction/merge shrank the partition
        if int(batch["id"].shape[0]) != n:
            # the unit list predates a compaction or merge: the span now
            # covers fewer rows (a merge also dissolves the boundary
            # itself).  Skip — the next step re-lists current units.
            return 0
        self.stats.units_scanned += 1
        stale_tables = [t for t in self._tables
                        if lin.get(t, -1) < versions[t]]
        # dirty-key refinement: only when EVERY stale table declares probe
        # columns ALL present in the stored rows AND has known dirty keys;
        # a row is affected when ANY of a table's probe columns hits
        mask = None
        for t in stale_tables:
            cols = self._probe_cols.get(t, ())
            keys = (self._dirty_keys(t, lin.get(t, -1))
                    if cols and all(c in batch for c in cols) else None)
            if keys is None:
                mask = None
                break
            for col in cols:
                hit = np.isin(np.asarray(batch[col], np.int64), keys)
                mask = hit if mask is None else (mask | hit)
        if mask is None:
            mask = np.ones(n, bool)
        elif not mask.any():
            self.stats.units_refined += 1
            self.stats.refined_rows += n
            part.update_lineage(start, n, versions, expect_epoch=epoch)
            return 0
        self.stats.stale_rows += int(mask.sum())
        self.stats.refined_rows += int(n - mask.sum())
        rows = np.arange(start, start + n)[mask]
        # the runner must see exactly the feed-time operand signature
        # (schema columns + valid) so the predeployed apply is a cache HIT
        sub_all = {k: np.asarray(batch[k])[mask]
                   for k in (*records.TWEET_SCHEMA, "valid")}
        repaired = 0
        bs = self.plan.batch_size
        for lo in range(0, int(mask.sum()), bs):
            m = min(bs, int(mask.sum()) - lo)
            sub = {k: v[lo:lo + m] for k, v in sub_all.items()}
            out = self._runner.run(sub)
            self.stats.repair_invocations += 1
            out = {k: v[:m] for k, v in out.items()}
            keep = np.asarray(out["valid"], bool)
            if not keep.all():
                # filter-delete: re-enrichment made these stored rows fail
                # the plan's re-evaluated filter — delete them, with the
                # same conditional-index exactly-once contract as repair
                # (a racing ingest upsert wins and the row survives as its
                # newer version, to be re-scanned)
                self.stats.invalidated_rows += int(m - keep.sum())
                self.stats.deleted_rows += part.delete_rows(
                    np.asarray(sub["id"])[~keep], rows[lo:lo + m][~keep],
                    expect_epoch=epoch)
            if not keep.any():
                continue
            fixed = self.plan.restrict({k: v[keep]
                                        for k, v in out.items()})
            fixed["valid"] = np.ones(int(keep.sum()), bool)
            got = part.repair_rows(fixed, rows[lo:lo + m][keep], versions,
                                   expect_epoch=epoch)
            self.stats.superseded_rows += int(keep.sum()) - got
            repaired += got
        part.update_lineage(start, n, versions, expect_epoch=epoch)
        self.stats.repaired_rows += repaired
        if repaired:
            self.stats.add_lag(max(0.0, time.monotonic() - since))
        if self._obs is not None and self._obs.tracing:
            # under the repair-step lock only (blocking-ok: R6-exempt,
            # ordering edge declared in analysis/annotations.py)
            self._obs.emit("repair.unit", (), t0=time.monotonic(),
                           dur=time.perf_counter() - t_unit, rows=n,
                           repaired=repaired, partition=part.pid)
        return repaired

    # -------------------------------------------------------------- drain
    def converged(self) -> bool:
        versions = {t: self.refstore[t].version for t in self._tables}
        return not self._stale_units(versions, time.monotonic())

    def drain(self, timeout: Optional[float] = 60.0) -> bool:
        """Repair to convergence (no stale units against current versions),
        unbudgeted — called by ``FeedHandle.join`` after the last computing
        worker, so a joined feed hands back a current store.  Returns
        whether it converged within ``timeout``.  Convergence is checked
        against the *current* versions each pass: if reference tables keep
        changing while draining, the target moves and ``timeout`` is the
        only bound — quiesce writers before join() for a guaranteed-final
        store (benchmarks/fig_repair.py's ``join_quiesced``)."""
        t0 = time.monotonic()
        deadline = None if timeout is None else t0 + timeout
        try:
            while not self._stop_evt.is_set():
                if self.converged():
                    return True
                if deadline is not None and time.monotonic() > deadline:
                    return False
                self.step(force=True)
            return self.converged()
        finally:
            self.stats.drain_s += time.monotonic() - t0

    def finish(self, timeout: Optional[float] = 60.0) -> bool:
        """Drain, stop, and join the scheduler thread (feed shutdown)."""
        converged = self.drain(timeout)
        self.stop()
        if self.is_alive():
            self.join(timeout)
        return converged
