"""Per-stage elasticity (ROADMAP: scale the partitions of a *stage*, not
the feed): a closed loop from observed load to partition count.

The paper's framework *adapts* — to reference-data changes (Model 2) and,
here, to load.  ``ElasticityController`` is one monitor thread per feed
that, at a configurable cadence, samples every stage group's

  * holder backlog (rows + bytes queued, ``PartitionHolder.backlog``), and
  * per-stage ``ComputingStats`` (apply_s / invocations / records of the
    group's runners — the per-stage split makes apply time attributable),

and drives ``FeedHandle.scale_up`` / ``FeedHandle.scale_down`` between the
``min_partitions``/``max_partitions`` bounds of the group's ``ElasticSpec``
(declared on the plan via ``pipeline(...).options(elastic=...)`` feed-wide,
or per stage via ``.enrich(udf, partitions=..., elastic=...)``).

Control law (deliberately simple and hysteretic):

  scale UP   when backlog rows exceed ``high_watermark`` batches *per
             partition* for ``up_after`` consecutive samples;
  scale DOWN when backlog rows stay under ``low_watermark`` batches total
             for ``down_after`` consecutive samples;

both gated by a shared per-group ``cooldown_s`` so the loop cannot flap,
and both stepping at most ``max_step`` partitions per decision.  Why
backlog and not utilization: enrichment-operator parallelism is what
bounds sustainable throughput (arXiv:2307.14287) and queued rows are the
direct, cheap observable of that bound being exceeded — a stage whose
workers keep up has an empty queue regardless of how hot they run.

``step()`` is synchronous and side-effect-complete so the control law is
unit-testable without threads; ``run()`` is just step + sleep until the
feed's workers are gone.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, NamedTuple, Optional


@dataclasses.dataclass(frozen=True)
class ElasticSpec:
    """Elastic bounds + control-law knobs for one stage group (or, when set
    via ``options(elastic=...)``, the default for every group of the plan).
    ``min_partitions == max_partitions`` pins the group static while still
    enabling backlog sampling (the benchmarks use this for fair A/Bs)."""
    min_partitions: int = 1
    max_partitions: int = 4
    interval_s: float = 0.05       # controller sampling cadence
    high_watermark: float = 1.5    # backlog batches per partition -> up
    low_watermark: float = 0.25    # backlog batches total -> down
    up_after: int = 2              # consecutive high samples before up
    down_after: int = 8            # consecutive low samples before down
    cooldown_s: float = 0.25       # min seconds between actions per group
    max_step: int = 1              # partitions added/retired per decision

    def __post_init__(self):
        if not (1 <= self.min_partitions <= self.max_partitions):
            raise ValueError(
                "elastic bounds must satisfy 1 <= min <= max, got "
                f"min={self.min_partitions} max={self.max_partitions}")
        if self.interval_s <= 0 or self.cooldown_s < 0:
            raise ValueError("interval_s must be > 0, cooldown_s >= 0")
        if self.up_after < 1 or self.down_after < 1 or self.max_step < 1:
            raise ValueError("up_after, down_after, max_step must be >= 1")


class GroupSample(NamedTuple):
    """One controller observation of one stage group."""
    t: float
    gid: int
    partitions: int
    backlog_rows: int
    backlog_bytes: int
    apply_s: float          # cumulative, summed over the group's runners
    invocations: int
    records: int


class Decision(NamedTuple):
    t: float
    gid: int
    action: str             # "up" | "down"
    partitions: int         # partition count AFTER the action


class ElasticityController(threading.Thread):
    """Per-feed monitor thread closing the load -> partitions loop.

    Operates on the feed handle's stage-group runtimes through a narrow
    protocol — each group exposes ``gid``, ``name``, ``elastic``,
    ``holders`` (list of objects with ``backlog()``) and ``slots`` (worker
    records with a ``runner.stats``), and the handle exposes
    ``stage_groups``, ``scale_up(n, stage=)``, ``scale_down(n, stage=)`` —
    so the control law is testable against fakes (tests/test_elasticity.py)
    and reusable by future per-stage *placement* monitors."""

    MAX_SAMPLES = 4096      # ring buffer bound: newest observations win

    def __init__(self, handle, batch_size: int,
                 name: str = "elasticity"):
        super().__init__(name=f"{name}-controller", daemon=True)
        self.handle = handle
        self.batch_size = max(1, batch_size)
        self.samples: List[GroupSample] = []    # guarded-by: _lock
        self.decisions: List[Decision] = []     # guarded-by: _lock
        self._stop_evt = threading.Event()
        self._up_ticks: Dict[int, int] = {}
        self._down_ticks: Dict[int, int] = {}
        self._last_action: Dict[int, float] = {}
        self._lock = threading.Lock()           # lock-name: controller

    # ----------------------------------------------------------- the loop
    def run(self) -> None:
        interval = min((g.elastic.interval_s
                        for g in self.handle.stage_groups
                        if g.elastic is not None), default=0.05)
        while not self._stop_evt.wait(interval):
            try:
                self.step()
            except Exception:
                # the controller must never take the feed down; a failed
                # sample just skips one control period
                continue
            if not any(s.thread is not None and s.thread.is_alive()
                       for g in self.handle.stage_groups
                       for s in list(g.slots)):
                return

    def stop(self) -> None:
        self._stop_evt.set()

    # ------------------------------------------------------- control law
    def step(self, now: Optional[float] = None) -> None:
        """One sample + decide pass over every stage group.  ``now`` is
        injectable so the hysteresis/cooldown clock is test-controllable."""
        t = time.monotonic() if now is None else now
        for group in list(self.handle.stage_groups):
            rows = nbytes = 0
            for h in list(group.holders):
                r, b = h.backlog()
                rows += r
                nbytes += b
            apply_s, inv, rec = 0.0, 0, 0
            for slot in list(group.slots):
                st = slot.runner.stats
                apply_s += st.apply_s
                inv += st.invocations
                rec += st.records
            parts = len(group.holders)
            with self._lock:
                self.samples.append(GroupSample(
                    t, group.gid, parts, rows, nbytes, apply_s, inv, rec))
                if len(self.samples) > self.MAX_SAMPLES:
                    del self.samples[:len(self.samples) // 2]
            spec = group.elastic
            if spec is None or parts == 0:
                continue
            self._decide(group, spec, parts, rows, t)

    def _decide(self, group, spec: ElasticSpec, parts: int, rows: int,
                t: float) -> None:
        gid = group.gid
        high = spec.high_watermark * self.batch_size * parts
        low = spec.low_watermark * self.batch_size
        if rows > high and parts < spec.max_partitions:
            self._up_ticks[gid] = self._up_ticks.get(gid, 0) + 1
        else:
            self._up_ticks[gid] = 0
        if rows < low and parts > spec.min_partitions:
            self._down_ticks[gid] = self._down_ticks.get(gid, 0) + 1
        else:
            self._down_ticks[gid] = 0

        cool = t - self._last_action.get(gid, -1e9) >= spec.cooldown_s
        if self._up_ticks[gid] >= spec.up_after and cool:
            step = min(spec.max_step, spec.max_partitions - parts)
            added = self.handle.scale_up(step, stage=gid)
            if added:
                self._last_action[gid] = t
                self._up_ticks[gid] = 0
                with self._lock:
                    self.decisions.append(
                        Decision(t, gid, "up", parts + added))
        elif self._down_ticks[gid] >= spec.down_after and cool:
            step = min(spec.max_step, parts - spec.min_partitions)
            dropped = self.handle.scale_down(step, stage=gid)
            if dropped:
                self._last_action[gid] = t
                self._down_ticks[gid] = 0
                with self._lock:
                    self.decisions.append(
                        Decision(t, gid, "down", parts - dropped))

    # ---------------------------------------------------- observability
    def backlog_p95(self, gid: int = 0) -> float:
        """p95 of sampled backlog rows for one group (benchmark metric)."""
        with self._lock:
            rows = sorted(s.backlog_rows for s in self.samples
                          if s.gid == gid)
        if not rows:
            return 0.0
        return float(rows[min(len(rows) - 1, int(0.95 * len(rows)))])

    def partition_timeline(self, gid: int = 0) -> List[int]:
        with self._lock:
            return [s.partitions for s in self.samples if s.gid == gid]
