"""Versioned reference-data store (the paper's "SensitiveWords"-style
datasets that enrichment UDFs reference, and that may be UPSERTed *during*
ingestion).

The paper's Model-2 semantics: records in batch *i* must be enriched against
the reference data as of batch *i*'s pickup — intermediate UDF state (hash
tables, aggregates, top-k lists) is rebuilt at batch boundaries so upserts
are visible during ingestion.  Model 3 (stream datasource) cannot do this;
Model 1 (per record) refreshes per record but is too slow.  See §5.3.

TPU adaptation (DESIGN.md §2): a reference table is a **fixed-capacity
struct-of-arrays** with a validity count.  Upserts mutate rows in place /
append, bump a version counter, and never change array shapes — so the
AOT-compiled ("predeployed") enrichment executable keeps accepting the table
as a *parameter* across updates with zero recompilation.  This is the JAX
realization of the paper's parameterized predeployed jobs: the query is
compiled once; the batch AND the current reference snapshot are the
invocation parameters.

Tables are keyed by an int64 primary key and maintain a sorted-key index
(rebuilt lazily per snapshot) so device-side joins are `searchsorted`
probes — the sorted-reference binary-search join that replaces pointer-chase
hash tables on TPU.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import nputil

# change listener: (table name, version after the write, changed keys).
# The repair scheduler (core/repair.py) subscribes these to build its
# staleness index: dirty ref versions -> stale stored segments.
ChangeListener = Callable[[str, int, np.ndarray], None]

KEY_SENTINEL = np.iinfo(np.int64).max  # empty slot marker (sorts last)


@dataclasses.dataclass(frozen=True)
class RefSnapshot:
    """Immutable view of one table at a version.  ``arrays`` always contains
    ``key`` (int64, padded with KEY_SENTINEL) plus the value columns, each of
    the full static ``capacity`` — shape-stable across versions."""
    name: str
    version: int
    size: int                      # valid rows (<= capacity)
    arrays: Dict[str, np.ndarray]  # includes "key", sorted ascending by key

    @property
    def capacity(self) -> int:
        return int(self.arrays["key"].shape[0])


class RefTable:
    """Fixed-capacity upsertable table. Thread-safe; snapshot() is O(1) when
    unchanged and O(n log n) (re-sort) after writes.

    Snapshot builds are **double-buffered**: after a write invalidates the
    cached snapshot, the next snapshot() copies the raw columns under the
    lock (O(n) memcpy) and sorts OUTSIDE it into a fresh buffer, so an
    UPSERT/DELETE arriving mid-build never waits behind the O(n log n) sort
    and computing workers never stall a writer — the paper's adaptiveness
    requirement (reference changes visible *during* ingestion, §5.3).  A
    build raced by a write simply isn't cached: it still returns a
    consistent view as of its copy point (exactly Model-2 "state as of
    batch pickup"), and the next call rebuilds against the newer version."""

    def __init__(self, name: str, capacity: int,
                 schema: Dict[str, np.dtype]):
        self.name = name
        self.capacity = int(capacity)
        self.schema = {k: np.dtype(v) for k, v in schema.items()}
        self._lock = threading.Lock()         # lock-name: ref-table
        # readers only; never writers          # lock-name: ref-build
        self._build_lock = threading.Lock()
        self._version = 0                      # guarded-by: _lock
        self._size = 0                         # guarded-by: _lock
        self._key = np.full((capacity,), KEY_SENTINEL, np.int64)
        self._cols = {k: np.zeros((capacity,) if np.dtype(v).shape == ()
                                  else (capacity,), v)
                      for k, v in self.schema.items()}
        # column arrays may be 2-D (e.g. fixed-width token lists)
        for k, v in self.schema.items():
            if v.subdtype is not None:
                base, shape = v.subdtype
                self._cols[k] = np.zeros((capacity,) + shape, base)
        self._snapshot: Optional[RefSnapshot] = None   # guarded-by: _lock
        self._listeners: List[ChangeListener] = []  # guarded-by: _lock — listener-registry

    # -------------------------------------------------------- change events
    def add_listener(self, fn: ChangeListener) -> None:
        """Subscribe to writes: ``fn(name, version, changed_keys)`` fires
        after every upsert/delete, OUTSIDE the write lock (a listener may
        read ``version``/``snapshot`` without deadlocking).  Listeners
        must be fast and never raise — they run on the writer's thread."""
        with self._lock:
            self._listeners.append(fn)

    def remove_listener(self, fn: ChangeListener) -> None:
        with self._lock:
            if fn in self._listeners:
                self._listeners.remove(fn)

    def _notify(self, version: int, keys: np.ndarray,  # fires-listeners
                listeners: List[ChangeListener]) -> None:
        for fn in listeners:
            fn(self.name, version, keys)

    # ------------------------------------------------------------------ DML
    def upsert(self, keys: np.ndarray, **cols: np.ndarray) -> None:
        """UPSERT semantics per the paper's footnote 1: replace the row when
        the key exists, insert otherwise.  Vectorized (this is the repair
        workload's hot write path — frequent small upserts against large
        tables): membership is one argsort + searchsorted probe instead of
        an O(table) Python dict rebuild per call; within a call the LAST
        occurrence of a duplicated key wins, as sequential replace did."""
        keys = np.asarray(keys, np.int64).reshape(-1)
        if (keys == KEY_SENTINEL).any():
            raise ValueError("KEY_SENTINEL is reserved")
        if keys.size == 0:
            return
        with self._lock:
            uniq, last = nputil.keep_last(keys)
            cur = self._key[:self._size]
            order = np.argsort(cur, kind="stable")
            found, loc, _ = nputil.sorted_find(cur, uniq, sorter=order)
            n_new = int((~found).sum())
            if self._size + n_new > self.capacity:
                raise RuntimeError(
                    f"table {self.name} over capacity {self.capacity}")
            slots = np.empty(uniq.size, np.int64)
            slots[found] = loc[found]
            slots[~found] = np.arange(self._size, self._size + n_new)
            self._size += n_new
            self._key[slots] = uniq
            for c, arr in cols.items():
                self._cols[c][slots] = np.asarray(arr)[last]
            self._version += 1
            self._snapshot = None
            version, listeners = self._version, list(self._listeners)
        self._notify(version, keys.copy(), listeners)

    def delete(self, keys: np.ndarray) -> int:
        keys = np.unique(np.asarray(keys, np.int64).reshape(-1))
        version = removed_keys = None
        with self._lock:
            cur = self._key[:self._size]
            rm = np.isin(cur, keys)
            removed = int(rm.sum())
            if removed:
                removed_keys = cur[rm].copy()
                keep = np.where(~rm)[0]
                for c in self._cols:
                    self._cols[c][:len(keep)] = self._cols[c][keep]
                self._key[:len(keep)] = self._key[keep]
                self._key[len(keep):self._size] = KEY_SENTINEL
                self._size = len(keep)
                self._version += 1
                self._snapshot = None
                version, listeners = self._version, list(self._listeners)
        if removed:
            self._notify(version, removed_keys, listeners)
        return removed

    # ------------------------------------------------------------- snapshot
    def snapshot(self) -> RefSnapshot:
        """Sorted-by-key immutable view; cached until the next write."""
        # feedlint: allow[guarded-field] double-checked fast path: a
        # torn read is impossible (GIL-atomic ref), a stale one only
        # costs the slow path below
        snap = self._snapshot
        if snap is not None:
            return snap
        # one builder at a time: concurrent readers wait for the winner's
        # result instead of each paying the O(n log n) sort.  Writers never
        # take this lock, so upserts proceed while the build runs.
        with self._build_lock:
            # buffer 1: consistent raw copy under the write lock (memcpy)
            with self._lock:
                if self._snapshot is not None:
                    return self._snapshot
                version, size = self._version, self._size
                key = self._key.copy()
                cols = {c: arr.copy() for c, arr in self._cols.items()}
            # buffer 2: sort outside the write lock — writers proceed
            order = np.argsort(key, kind="stable")
            arrays = {"key": np.ascontiguousarray(key[order])}
            for c, arr in cols.items():
                arrays[c] = np.ascontiguousarray(arr[order])
            snap = RefSnapshot(self.name, version, size, arrays)
            with self._lock:
                # publish only if no write raced the build
                if self._version == version and self._snapshot is None:
                    self._snapshot = snap
        return snap

    @property
    def version(self) -> int:
        with self._lock:
            return self._version

    def __len__(self) -> int:
        with self._lock:
            return self._size


class RefStore:
    """Named tables + a store-wide version (max of table versions) used for
    version-gated enrichment-state rebuild (beyond-paper optimization — the
    paper rebuilds every batch unconditionally)."""

    def __init__(self):
        # write-guarded: create() mutates under the lock; lookups are
        # lock-free dict reads (GIL-atomic) on the hot enrichment path
        self._tables: Dict[str, RefTable] = {}  # write-guarded-by: _lock
        self._lock = threading.Lock()           # lock-name: ref-store

    def create(self, name: str, capacity: int,
               schema: Dict[str, np.dtype]) -> RefTable:
        with self._lock:
            if name in self._tables:
                raise KeyError(f"table {name} exists")
            t = RefTable(name, capacity, schema)
            self._tables[name] = t
            return t

    def __getitem__(self, name: str) -> RefTable:
        return self._tables[name]

    def __contains__(self, name: str) -> bool:
        return name in self._tables

    def snapshot(self, names: Tuple[str, ...]) -> Dict[str, RefSnapshot]:
        return {n: self._tables[n].snapshot() for n in names}

    def version(self, names: Tuple[str, ...]) -> Tuple[int, ...]:
        return tuple(self._tables[n].version for n in names)

    def subscribe(self, names: Tuple[str, ...],
                  fn: ChangeListener) -> None:
        for n in names:
            self._tables[n].add_listener(fn)

    def unsubscribe(self, names: Tuple[str, ...],
                    fn: ChangeListener) -> None:
        for n in names:
            if n in self._tables:
                self._tables[n].remove_listener(fn)
