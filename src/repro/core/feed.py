"""The Active Feed Manager (§7.1): executes declarative ingestion plans.

The primary entry point is the **plan API** (core/plan.py):

    plan = (pipeline(adapter, "tweets").parse(batch_size=420)
            .enrich(Q.Q1).enrich(Q.Q2)          # fused: ONE apply per batch
            .filter(pred).project("safety_level", ...)
            .tee(lm_sink).store(spill_dir=...))  # multi-sink fan-out
    handle = manager.submit(plan)                # -> FeedHandle

``submit`` wires the compiled plan onto the three-job pipeline of Fig 23:

    intake job  ->  [passive intake holders]  ->  computing workers
                ->  [one active sink holder PER SINK]  ->  storage job
                                                        / tee consumers

and keeps invoking computing jobs while data flows (a worker loop per
partition — each ``ComputingRunner.run`` call is one computing-job
invocation, counted and timed, with per-stage ``ComputingStats`` for fused
chains).  Every enriched batch is pushed to every sink holder exactly once;
each sink drains its own bounded queue, so one slow sink backpressures the
feed without corrupting another sink's delivery.  Stop protocol per §7.1:
the adapter ends, the intake job enqueues StopRecords, computing workers
drain and finish partial batches, the sink holders close after the last
worker.  Completed feeds deregister from the manager (name + holder IDs
become reusable).

**Baselines:** ``FeedManager.start(FeedConfig(...), adapter)`` is now the
entry point for the paper-baseline measurement rigs ONLY; the deprecated
framework="new" shim lowering was removed once every driver migrated to
plans (``FeedConfig`` survives as the internal runtime config a compiled
plan lowers onto).  The baseline frameworks stay cfg-only (they are
measurement rigs, not plans):

  framework="current"   coupled single job, single parsing node, Model-3
                        state (AsterixDB data feeds with a Java UDF)
  framework="balanced"  coupled, parsing divided over all nodes
  framework="insert"    Approach 1: repeated INSERT statements — every
                        batch pays query compilation (no predeploy cache)
  framework="new"       this paper: decoupled + predeployed + Model 2 —
                        plan-only; ``start`` rejects it

Fault tolerance: per-invocation retry with exponential backoff; failed
frames are re-enqueued (at-least-once) and the idempotent storage job makes
delivery effectively exactly-once.  Idle workers steal from the deepest
holder (straggler mitigation).

**Per-stage elasticity** (core/elasticity.py): a compiled plan is >= 1
linked **stage groups** — chain segments split at declared boundaries
(``.enrich(q6, partitions=..., elastic=...)``), each with its own holder
list + worker pool + elastic bounds, connected by intermediate
``PartitionHolder``s so a heavy-state stage (Q6) scales independently of
cheap probe stages.  ``FeedHandle.scale_up(n, stage=g)`` adds partitions
mid-feed (the upstream round-robin re-targets); ``scale_down`` retires
them — the holder leaves the round-robin under the handle lock, a
StopRecord drains its queue exactly-once, and the worker merges its
``ComputingStats`` into the feed totals as it exits.  With
``options(elastic=...)`` an ``ElasticityController`` thread closes the
loop from observed backlog (rows + bytes queued per group) to partition
count between ``min_partitions``/``max_partitions``.

Cross-partition micro-batching (``coalesce_rows``): when a worker finds
a backlog in its holder it coalesces queued frames — up to a row AND byte
budget — into ONE kernel dispatch.  Per-invocation overhead (snapshot
lookup, H2D, executable dispatch) is paid once per coalesced batch instead
of once per frame, which is the paper's batch-size lever (Fig 25/26)
applied adaptively: an idle feed keeps per-frame latency, a backlogged feed
converges to throughput-optimal batches.  Coalesced batches are padded to
power-of-two row buckets (enrich/dispatch.py) so they never trigger
per-size recompiles.  Default (``coalesce_rows=None``): ON at 4x the batch
size for the decoupled framework, OFF for the baselines (whose per-batch
cost model the coalescer would distort).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import records
from repro.core.compaction import CompactionJob, CompactionStats
from repro.core.computing import ComputingRunner, ComputingSpec, \
    ComputingStats
from repro.core.durability import DurabilityRuntime
from repro.core.elasticity import ElasticityController, ElasticSpec
from repro.core.enrich import dispatch
from repro.core.enrich.queries import EnrichUDF
from repro.core.intake import Adapter, IntakeJob, TrackedBatch, TrackedFrame
from repro.core.obs import (FeedHealthModel, FeedObs, HealthReport,
                            JourneyProfiler, MetricValue, ObsServer,
                            ProfileReport, ROWS_BOUNDS, TraceSpec, mangle,
                            write_jsonl)
from repro.core.partition_holder import (ActivePartitionHolder,
                                         PartitionHolder,
                                         PartitionHolderManager,
                                         StopRecord, frame_bytes,
                                         frame_rows)
from repro.core.plan import IngestPlan, Pipeline, StageGroup
from repro.core.predeploy import PredeployCache
from repro.core.refdata import RefStore
from repro.core.repair import RepairJob, RepairStats
from repro.core.storage import StorageJob

# coalesce_rows=None resolves to this many batches' worth of rows for the
# decoupled framework (ROADMAP item: benchmarked under sustained backlog —
# numbers in CHANGES.md PR 2)
COALESCE_DEFAULT_BATCHES = 4


def _store_consumer(storage: StorageJob, ledger=None, obs=None) -> Callable:
    """Storage-sink consumer: unwrap lineage-tagged batches (plan path);
    bare dicts (pure-ingestion / legacy call sites) store unversioned.
    On durable feeds the consumer marks the batch's WAL sequence numbers
    done in the ledger AFTER the (idempotent) store write returns — that
    ordering is the exactly-once contract: a checkpoint can only cite a
    watermark whose records are already in the column store.

    Currency accounting (core/obs): once the write returns the rows are
    snapshot-queryable, so this is where store-visible latency — the
    paper's lag metric, intake stamp to queryable — lands in the
    ``ingest_visible_latency_s`` histogram, and where the ``store.append``
    span closes a traced batch's journey.  Both happen with no lock held
    (this thread is the sink holder's drain loop)."""
    lat_hist = (obs.registry.histogram("ingest_visible_latency_s")
                if obs is not None else None)

    def consume(frame) -> None:
        if isinstance(frame, _StoreBatch):
            t0 = time.perf_counter()
            storage.write(frame.batch, lineage=frame.lineage,
                          span_ids=frame.span_ids)
            if ledger is not None and frame.wal_seqs:
                ledger.mark_done(frame.wal_seqs)
            if obs is not None:
                dur = time.perf_counter() - t0
                now = time.monotonic()
                if frame.t_intake:
                    lat_hist.observe(max(0.0, now - frame.t_intake))
                if frame.span_ids:
                    obs.emit("store.append", frame.span_ids, t0=now - dur,
                             dur=dur, rows=_frame_rows(frame.batch))
        else:
            storage.write(frame)
            if ledger is not None:
                seqs = getattr(frame, "wal_seqs", None)
                if seqs:
                    ledger.mark_done(seqs)
    return consume

_frame_rows = frame_rows      # shared with the holders' backlog accounting
_frame_bytes = frame_bytes


class _StoreBatch:
    """An enriched batch plus the ref-version lineage it was computed
    under, en route to the STORE sink holder (tee sinks receive the bare
    dict).  The storage job records the lineage per stored chunk so the
    repair subsystem (core/repair.py) can find stale rows later.  On
    durable feeds ``wal_seqs`` carries the intake-log sequence numbers of
    the raw frames this batch was parsed from (core/durability.py);
    ``span_ids``/``t_intake`` are the observability stamps lifted off the
    raw ``TrackedFrame`` the same way (core/obs — span ids close the
    trace at the store, the intake timestamp prices store-visible
    latency)."""
    __slots__ = ("batch", "lineage", "wal_seqs", "span_ids", "t_intake")

    def __init__(self, batch: Dict, lineage: Optional[Dict[str, int]],
                 wal_seqs: Optional[Tuple[int, ...]] = None,
                 span_ids: Tuple[int, ...] = (), t_intake: float = 0.0):
        self.batch = batch
        self.lineage = lineage
        self.wal_seqs = wal_seqs
        self.span_ids = span_ids
        self.t_intake = t_intake


@dataclasses.dataclass
class FeedConfig:
    """Runtime feed configuration.

    Historically the whole public surface (one ``udf`` slot, one sink) and
    once a ``start``-time shim over the plan API; the shim lowering is
    gone.  Today it serves two roles: the internal config a compiled
    ``IngestPlan`` lowers onto in ``FeedManager.submit``, and the driver
    config of the paper-baseline measurement rigs
    (framework="current"/"balanced"/"insert" via ``FeedManager.start``).
    Decoupled feeds are built with ``pipeline(...)``/``submit``."""
    name: str = "feed"
    udf: Optional[EnrichUDF] = None
    batch_size: int = 420                 # the paper's 1X
    num_partitions: int = 1
    model: str = "per_batch"              # per_record | per_batch | stream
    refresh: str = "always"               # always | version
    framework: str = "new"                # new | current | balanced | insert
    storage_partitions: int = 0           # 0 -> num_partitions
    spill_dir: Optional[str] = None
    upsert: bool = False
    work_stealing: bool = True
    max_retries: int = 3
    retry_backoff_s: float = 0.05
    holder_capacity: int = 8
    # cross-partition micro-batching: coalesce queued frames into one
    # computing-job invocation up to this many rows (0 disables) and
    # coalesce_bytes raw bytes.  None = auto: COALESCE_DEFAULT_BATCHES x
    # batch_size for framework="new", 0 for the baselines.  Ignored for
    # model="per_record", whose semantics are inherently per-row.
    coalesce_rows: Optional[int] = None
    coalesce_bytes: int = 8 << 20
    # test hook: raises inside the computing job when it returns True
    fault_hook: Optional[Callable[[int], bool]] = None
    # alternate sink: enriched batches go to this callable instead of the
    # storage job (the LM data plane consumes batches directly — see
    # train/data_feed.py)
    sink: Optional[Callable[[Dict], None]] = None
    # feed-wide elastic bounds (shim lowering of options(elastic=...));
    # per-stage bounds are plan-only
    elastic: Optional[ElasticSpec] = None

    @property
    def resolved_coalesce_rows(self) -> int:
        if self.coalesce_rows is not None:
            return self.coalesce_rows
        if self.framework == "new":
            return COALESCE_DEFAULT_BATCHES * self.batch_size
        return 0


# FeedStats scalar fields backed by the metrics registry once bound:
# integer event counts become counters, float durations/levels gauges.
# Mutation sites keep their existing synchronization (the handle lock) —
# counter/gauge updates are plain attribute writes, explicitly legal under
# core locks (feedlint R6 flags only histogram observe / span emit there).
_FEED_COUNTER_FIELDS = ("records_in", "frames_in", "stored", "retries",
                        "steals", "coalesced_frames", "scale_ups",
                        "scale_downs", "stale_rows", "repaired_rows",
                        "compacted_rows")
_FEED_GAUGE_FIELDS = ("wall_s", "storage_write_s", "worker_seconds",
                      "backlog_p95_rows", "repair_lag_p50_s",
                      "repair_lag_p95_s", "repair_drain_s",
                      "durable_finish_s")
_FEED_SCALAR_FIELDS = frozenset(_FEED_COUNTER_FIELDS + _FEED_GAUGE_FIELDS)


@dataclasses.dataclass
class FeedStats:
    """Feed-level stats.  The attribute API below is the stable public
    surface; once ``bind()`` attaches a ``MetricsRegistry`` (every
    ``FeedHandle`` does this at construction) the scalar fields are
    *views over registry instruments* — reads and writes go through the
    feed's ``feed_<field>`` counter/gauge, so ``handle.metrics()`` and
    the Prometheus exposition see the same live numbers benchmarks read
    off this dataclass.  Unbound instances (direct construction in
    tests) behave exactly like the plain dataclass they look like."""
    wall_s: float = 0.0
    records_in: int = 0
    frames_in: int = 0
    stored: int = 0
    retries: int = 0
    steals: int = 0
    coalesced_frames: int = 0     # frames merged into a neighbor's batch
    computing: ComputingStats = dataclasses.field(
        default_factory=ComputingStats)
    predeploy: Dict = dataclasses.field(default_factory=dict)
    storage_write_s: float = 0.0
    # multi-sink fan-out: sink name -> batches delivered (exactly-once per
    # sink per enriched batch)
    sink_batches: Dict[str, int] = dataclasses.field(default_factory=dict)
    # elasticity: partition add/retire events (manual + controller), the
    # integral of live computing workers over time (the cost side of the
    # elastic-vs-static A/B), and per-group peak partition counts
    scale_ups: int = 0
    scale_downs: int = 0
    worker_seconds: float = 0.0
    backlog_p95_rows: float = 0.0
    peak_partitions: Dict[str, int] = dataclasses.field(default_factory=dict)
    # progressive re-enrichment (core/repair.py): currency of stored rows
    # under mid-/post-ingestion reference updates.  repair_drain_s is the
    # post-feed convergence time join() spent, so benchmarks can separate
    # ingest-side throughput from the repair catch-up.
    stale_rows: int = 0
    repaired_rows: int = 0
    repair_lag_p50_s: float = 0.0
    repair_lag_p95_s: float = 0.0
    repair_drain_s: float = 0.0
    repair: Optional[RepairStats] = None
    # durable feeds (core/durability.py): time join() spent in the final
    # coordinated checkpoint (WAL sync + storage flush + snapshot +
    # truncate) — shutdown drain, not steady-state ingest, so benchmarks
    # can exclude it the way they exclude repair_drain_s
    durable_finish_s: float = 0.0
    # background segment compaction (core/compaction.py): space reclaimed
    # from superseded/deleted row versions while the feed ran
    compacted_rows: int = 0
    compaction: Optional[CompactionStats] = None

    @property
    def records_per_s(self) -> float:
        return self.records_in / self.wall_s if self.wall_s else 0.0

    # ------------------------------------------------- registry backing
    def bind(self, registry) -> None:
        """Back every scalar field with a ``feed_<name>`` instrument in
        ``registry``; current values carry over.  Nested stats objects
        (``computing``, ``repair``, ...) stay plain — the handle publishes
        them into the registry at ``metrics()`` collect time instead."""
        inst: Dict[str, object] = {}
        for f in _FEED_COUNTER_FIELDS:
            c = registry.counter("feed_" + f)
            c.set(getattr(self, f))
            inst[f] = c
        for f in _FEED_GAUGE_FIELDS:
            g = registry.gauge("feed_" + f)
            g.set(getattr(self, f))
            inst[f] = g
        # installed LAST: its presence is what flips the access paths
        self.__dict__["_inst"] = inst

    def __getattribute__(self, name: str):
        if name in _FEED_SCALAR_FIELDS:
            inst = object.__getattribute__(self, "__dict__").get("_inst")
            if inst is not None:
                return inst[name].value
        return object.__getattribute__(self, name)

    def __setattr__(self, name: str, value) -> None:
        if name in _FEED_SCALAR_FIELDS:
            inst = self.__dict__.get("_inst")
            if inst is not None:
                inst[name].set(value)
                return
        object.__setattr__(self, name, value)


class _WorkerSlot:
    """One computing worker: its holder, thread-confined runner, thread,
    and retirement flag (scale_down sets it; the worker then drains its
    queue, merges its stats, and exits without stealing)."""
    __slots__ = ("pid", "holder", "runner", "thread", "retire", "t_start")

    def __init__(self, pid: int, holder: PartitionHolder,
                 runner: ComputingRunner):
        self.pid = pid
        self.holder = holder
        self.runner = runner
        self.thread: Optional[threading.Thread] = None
        self.retire = threading.Event()
        self.t_start = time.perf_counter()


class _StageGroupRuntime:
    """Runtime state of one compiled ``StageGroup``: its own holder list
    (round-robin target of the upstream job), worker pool, computing spec
    derived from the plan, and elastic bounds.  All mutation happens under
    the feed handle's lock."""

    def __init__(self, gid: int, name: str, job: str, spec: ComputingSpec,
                 elastic: Optional[ElasticSpec]):
        self.gid = gid
        self.name = name
        self.job = job              # holder-manager job name (stealing)
        self.spec = spec
        self.elastic = elastic
        self.holders: List[PartitionHolder] = []   # live, lock-guarded
        self.slots: List[_WorkerSlot] = []
        self.next: Optional["_StageGroupRuntime"] = None
        self.next_pid = 0           # monotonic: retired pids never reused
        self.live = 0
        self.rr = 0                 # round-robin cursor into next.holders
        self.closing = False        # upstream drained: no more scale-ups
        self.peak_partitions = 0


class FeedHandle:
    def __init__(self, cfg: FeedConfig, manager: "FeedManager",
                 adapter: Adapter, plan: Optional[IngestPlan] = None):
        self.cfg = cfg
        self.plan = plan            # None for the cfg-only baseline paths
        self.manager = manager
        self.adapter = adapter
        self.storage: Optional[StorageJob] = None
        self.intake: Optional[IntakeJob] = None
        self.holders: List[PartitionHolder] = []
        self.workers: List[threading.Thread] = []
        self.runners: List[ComputingRunner] = []
        # decoupled path: >= 1 linked stage groups (per-stage parallelism);
        # empty for the coupled/insert baselines
        self.stage_groups: List[_StageGroupRuntime] = []
        self.controller: Optional[ElasticityController] = None
        # one active holder per sink (plan fan-out); storage_holder aliases
        # the first for pre-plan call sites
        self.sink_holders: List[ActivePartitionHolder] = []
        self._sink_names: List[str] = []
        self._store_sink_idx: Optional[int] = None
        self.storage_holder: Optional[ActivePartitionHolder] = None
        self.repair: Optional[RepairJob] = None
        self.compaction: Optional[CompactionJob] = None
        self.durability: Optional[DurabilityRuntime] = None
        # observability (core/obs): metrics are ALWAYS on — counters and
        # gauges are plain attribute writes, histograms a tiny per-
        # instrument lock — while span tracing is opt-in (plan trace=...).
        # FeedStats scalars read/write through this registry from birth.
        self.obs = FeedObs()
        self.stats = FeedStats()
        self.stats.bind(self.obs.registry)
        # currency + backlog histograms exist from birth so metrics()
        # always carries the keys, observed or not
        self._lat_hist = self.obs.registry.histogram(
            "ingest_visible_latency_s")
        self._repair_hist = self.obs.registry.histogram("repair_currency_s")
        self._backlog_hist = self.obs.registry.histogram(
            "backlog_rows", ROWS_BOUNDS)
        self._backlog_age_hist = self.obs.registry.histogram(
            "holder_backlog_age_s")
        # feedscope (core/obs): journey profiler (opt-in via
        # options(profile=...)), SLO health model (lazy — see health()),
        # and their always-present instruments: the worker_errors counter
        # feeds the health rule of the same name, feed_health publishes
        # the verdict (0 ok / 1 degraded / 2 stalled)
        self.profiler: Optional[JourneyProfiler] = None
        self._health_model: Optional[FeedHealthModel] = None
        self._health_gauge = self.obs.registry.gauge("feed_health")
        self._worker_err_counter = self.obs.registry.counter("worker_errors")
        self._t0 = 0.0
        self._lock = threading.Lock()               # lock-name: handle
        # appended by worker threads under the lock; read lock-free from
        # join() only after every worker thread has exited
        self._worker_errs: List[BaseException] = []  # write-guarded-by: _lock
        self._invocation_counter = 0                 # guarded-by: _lock
        self._live_workers = 0                       # guarded-by: _lock
        self._finalized = False
        self._deregistered = False
        self._sinks_dead = False    # all sink consumers failed: discard
        # ComputingStats of workers retired by scale_down, merged here the
        # moment the worker exits so no invocation/record count can vanish
        # merged under the lock at worker exit; read lock-free by
        # _finalize() after join() proved all workers are gone
        self._retired_computing = ComputingStats()  # write-guarded-by: _lock

    # ------------------------------------------------------------- lifecycle
    def stop(self) -> None:
        """Graceful stop: stop the adapter; the drain protocol finishes the
        in-flight batches (§7.1)."""
        self.adapter.stop()

    def join(self, timeout: Optional[float] = None) -> FeedStats:
        if self.intake is not None:
            self.intake.join(timeout)
        for w in self.workers:     # the list may grow while we iterate
            w.join(timeout)        # (scale_up); appended threads are seen
        if self.controller is not None:
            self.controller.stop()
            self.controller.join(timeout)
        try:
            if not self._finalized:
                for sh in self.sink_holders:
                    # last computing job done -> sinks stop
                    sh.close()
                sink_err: Optional[BaseException] = None
                for sh in self.sink_holders:
                    try:
                        # join EVERY sink before raising: healthy sinks
                        # must finish draining even when another failed
                        sh.join(timeout)
                    except BaseException as e:
                        sink_err = sink_err or e
                if sink_err is not None:
                    raise sink_err
            if self._worker_errs:
                raise self._worker_errs[0]
            if self.intake is not None and self.intake.error is not None:
                raise self.intake.error
            if self.repair is not None and not self._finalized:
                # the feed's own work is done: repair the remaining stale
                # segments to convergence so join() hands back a store
                # that is current against the final reference versions
                self.repair.finish(timeout)
                if self.repair.error is not None:
                    raise self.repair.error
            if self.compaction is not None and not self._finalized:
                # stop (no forced drain: compaction is an optimization —
                # callers wanting a fully-reclaimed store call
                # handle.compaction.drain() / storage.compact() first)
                self.compaction.finish(timeout)
                if self.compaction.error is not None:
                    raise self.compaction.error
            if self.durability is not None and not self._finalized:
                # final coordinated checkpoint: flush, snapshot the
                # watermark (== last seq once every sink drained), and
                # truncate the intake log so a clean restart replays
                # nothing
                t_fin = time.perf_counter()
                self.durability.finish(timeout)
                self.stats.durable_finish_s = (time.perf_counter()
                                               - t_fin)
            self._finalize()
        finally:
            if self.repair is not None:
                self.repair.stop()      # idempotent; error paths too
            if self.compaction is not None:
                self.compaction.stop()
            if self.durability is not None:
                self.durability.stop()  # idempotent; error paths too
            self._deregister()
        return self.stats

    def _finalize(self) -> None:
        if self._finalized:
            return
        self._finalized = True
        self.stats.wall_s = time.perf_counter() - self._t0
        if self.intake is not None:
            self.stats.records_in = self.intake.records_in
            self.stats.frames_in = self.intake.frames_in
        if self.storage is not None:
            self.stats.stored = self.storage.stored
            self.stats.storage_write_s = self.storage.write_s
        # retired workers merged their runners at exit (scale_down); the
        # runners list holds only never-retired workers at this point
        self.stats.computing.merge(self._retired_computing)
        for r in self.runners:
            self.stats.computing.merge(r.stats)
        for g in self.stage_groups:
            self.stats.peak_partitions[g.name] = g.peak_partitions
        # every worker pull samples queue depth into the registry, so the
        # p95 reports for STATIC feeds too (it used to exist only while
        # an elasticity controller was sampling); an elastic feed's
        # controller ring still refines it — worst across all stage
        # groups, since group 0's backlog can describe the wrong pool
        p95 = self._backlog_hist.percentile(0.95)
        # empty-histogram percentiles are nan by design (core/obs): an
        # idle feed's summary stat stays the neutral 0.0
        self.stats.backlog_p95_rows = p95 if p95 == p95 else 0.0
        if self.controller is not None:
            self.stats.backlog_p95_rows = max(
                self.stats.backlog_p95_rows,
                max((self.controller.backlog_p95(g.gid)
                     for g in self.stage_groups), default=0.0))
        spec = self.obs.trace_spec
        if spec is not None and spec.path:
            with open(spec.path, "a", encoding="utf-8") as fp:
                write_jsonl(self.obs.drain_trace(), fp)
        for name, sh in zip(self._sink_names, self.sink_holders):
            self.stats.sink_batches[name] = sh.pulled
        if self.repair is not None:
            r = self.repair.stats
            self.stats.repair = r
            self.stats.stale_rows = r.stale_rows
            self.stats.repaired_rows = r.repaired_rows
            self.stats.repair_lag_p50_s = r.repair_lag_p50_s
            self.stats.repair_lag_p95_s = r.repair_lag_p95_s
            self.stats.repair_drain_s = r.drain_s
        if self.compaction is not None:
            self.stats.compaction = self.compaction.stats
            self.stats.compacted_rows = self.compaction.stats.rows_dropped
        self.stats.predeploy = self.manager.predeploy.stats()

    def _deregister(self) -> None:
        """Release the feed's name and holder IDs once every thread is done
        so the same feed name can be started again (restart-after-stop)."""
        if self._deregistered:
            return
        if any(w.is_alive() for w in self.workers):
            return
        if self.intake is not None and self.intake.is_alive():
            return
        if any(sh._thread.is_alive() for sh in self.sink_holders):
            return
        self._deregistered = True
        hm = self.manager.holder_manager
        all_holders: List[PartitionHolder] = list(self.sink_holders)
        if self.stage_groups:
            for g in self.stage_groups:   # retired holders already
                all_holders.extend(g.holders)  # unregistered at retire time
        else:
            all_holders.extend(self.holders)
        for h in all_holders:
            hm.unregister(h.holder_id)
        with self.manager._lock:
            if self.manager.feeds.get(self.cfg.name) is self:
                del self.manager.feeds[self.cfg.name]

    # --------------------------------------------------------------- queries
    def query(self):
        """Analytical queries over the feed's column store (core/query.py):
        ``handle.query().where(col(...) >= v).group_by(k).agg(...)
        .execute()``.  Snapshot-consistent, so it is safe — and the point —
        to call while the feed is still ingesting and repair/compaction
        are churning rows."""
        if self.storage is None:
            raise RuntimeError(
                "feed has no store sink: end the plan with .store(...) to "
                "get a queryable column store")
        return self.storage.query()

    def _note_worker_err(self, e: BaseException) -> None:
        """Record a worker-loop failure: the exception for join() to
        re-raise, plus the ``worker_errors`` counter the health model's
        rule of the same name watches."""
        with self._lock:
            self._worker_errs.append(e)
        self._worker_err_counter.inc()

    # ---------------------------------------------------------- observability
    def metrics(self) -> Dict[str, MetricValue]:
        """Live, isolated snapshot of every feed metric: counters (int),
        gauges (float), histograms (``HistogramSnapshot`` with
        ``count``/``sum``/``percentile(q)``).  The paper's currency
        numbers are native histograms here —
        ``metrics()["ingest_visible_latency_s"]`` (intake stamp →
        store-queryable) and ``["repair_currency_s"]`` (ref write → row
        repaired) — live during ingestion, not just after join()."""
        self._collect_metrics()
        return self.obs.registry.snapshot()

    def metrics_text(self) -> str:
        """Prometheus-style text exposition of ``metrics()``."""
        self._collect_metrics()
        return self.obs.registry.exposition()

    def drain_trace(self):
        """Drain and return the batch trace spans collected so far (empty
        unless the plan enabled ``options(trace=...)``); see
        docs/OBSERVABILITY.md for the span taxonomy."""
        return self.obs.drain_trace()

    def profile(self) -> Optional[ProfileReport]:
        """feedscope: drain the tracer into the journey profiler and
        return the rolling critical-path report — per-hop service/queue
        percentiles, critical-path fractions, and the ranked bottleneck
        verdict (core/obs/profile.py).  ``None`` unless the plan enabled
        ``options(profile=...)``.  As a side effect the verdict lands in
        the registry as ``bottleneck_<hop>_frac`` gauges, so ``/metrics``
        scrapes carry the attribution without a JSON round trip."""
        prof = self.profiler
        if prof is None:
            return None
        prof.ingest(self.obs.drain_trace())
        report = prof.report()
        reg = self.obs.registry
        for hop, frac in report.ranked:
            reg.gauge(mangle(f"bottleneck_{hop}_frac")).set(frac)
        return report

    def health(self) -> HealthReport:
        """feedscope: evaluate the feed's SLO rules (core/obs/health.py)
        against the current metrics snapshot and return the report; the
        verdict also lands in the ``feed_health`` gauge (0 ok / 1
        degraded / 2 stalled).  The model is created lazily from the
        plan's ``options(health=...)`` spec (defaults when absent) and
        inherits the repair SLO (``RepairSpec.max_lag_s``) when the
        store declared one."""
        with self._lock:
            model = self._health_model
            if model is None:
                max_lag = None
                if (self.plan is not None and
                        self.plan.store_spec is not None and
                        self.plan.store_spec.refresh is not None):
                    max_lag = self.plan.store_spec.refresh.max_lag_s
                spec = self.plan.health if self.plan is not None else None
                model = self._health_model = FeedHealthModel(
                    spec, max_lag_s=max_lag)
        # evaluate OUTSIDE the handle lock: metrics() touches holder and
        # instrument locks and must never nest under `handle`
        report = model.evaluate(self.metrics())
        self._health_gauge.set(float(report.code))
        return report

    def _collect_metrics(self) -> None:
        """Refresh the published-on-read surfaces: nested stats objects
        and module-level telemetry are folded into registry instruments
        here, so each metrics()/exposition read is current.  Reads are
        lock-free by design (the counters are single-writer or advisory;
        see docs/CONCURRENCY.md 'racy by design')."""
        reg = self.obs.registry
        comp = ComputingStats()
        comp.merge(self._retired_computing)
        for r in list(self.runners):
            comp.merge(r.stats)
        reg.set_counters({
            "computing_invocations": comp.invocations,
            "computing_records": comp.records,
            "computing_state_builds": comp.state_builds,
            "computing_state_reuses": comp.state_reuses,
            "computing_calibrations": comp.calibrations})
        reg.set_gauges({
            "computing_parse_s": comp.parse_s,
            "computing_upload_s": comp.upload_s,
            "computing_convert_s": comp.convert_s,
            "computing_state_s": comp.state_s,
            "computing_apply_s": comp.apply_s})
        for sname, ss in comp.per_stage.items():
            reg.set_gauges({mangle(f"stage_{sname}_apply_s"): ss.apply_s})
            reg.set_counters(
                {mangle(f"stage_{sname}_invocations"): ss.invocations})
        # kernel-dispatch routing (process-wide tape, core/enrich/dispatch)
        for (op, path), n in dispatch.path_stats().items():
            reg.counter(mangle(f"dispatch_path_{op}_{path}")).set(n)
        for g in self.stage_groups:
            reg.gauge(mangle(f"elastic_partitions_{g.name}")).set(
                len(g.holders))
        # instantaneous queued rows across every live holder (stage
        # groups + sink queues) — the health model's stall/growth signal;
        # each backlog() read takes only that holder's own leaf lock
        backlog_now = 0
        with self._lock:
            live = [h for g in self.stage_groups for h in g.holders]
        for h in live:
            rows_q, _ = h.backlog()
            backlog_now += rows_q
        for sh in self.sink_holders:
            rows_q, _ = sh.backlog()
            backlog_now += rows_q
        reg.gauge("backlog_rows_now").set(float(backlog_now))
        # per-sink delivery counters (live view of stats.sink_batches,
        # which is only folded at _finalize): progress signal for the
        # health model's stall rule on tee-only feeds
        for sname, sh in zip(self._sink_names, self.sink_holders):
            reg.counter(mangle(f"sink_{sname}_batches")).set(sh.pulled)
        if self.storage is not None:
            reg.set_counters({"store_rows": self.storage.stored,
                              "store_dead_rows": self.storage.dead_rows,
                              "store_segments": self.storage.segment_count})
            reg.set_gauges({"store_write_s": self.storage.write_s})
            # compaction/merge level occupancy (PR 8's leveled layout)
            for lvl, n in sorted(self.storage.level_histogram().items()):
                reg.gauge(f"store_level_{lvl}_segments").set(n)
            # per-segment read telemetry feeds the PIQUE roadmap item;
            # the total makes scan traffic visible at a glance
            reads = self.storage.segment_read_counts()
            reg.counter("store_segment_reads").set(sum(reads.values()))
        if self.repair is not None:
            r = self.repair.stats
            reg.set_counters({"repair_stale_rows": r.stale_rows,
                              "repair_repaired_rows": r.repaired_rows})
        if self.compaction is not None:
            c = self.compaction.stats
            reg.set_counters({"compaction_merges": c.merges,
                              "compaction_rows_dropped": c.rows_dropped,
                              "compaction_rows_rewritten": c.rows_rewritten})
        if self.durability is not None:
            led = self.durability.ledger
            reg.set_counters(
                {"wal_backlog_records": led.backlog()
                 if hasattr(led, "backlog") else 0})

    # ------------------------------------------------------------ elasticity
    def scale_up(self, extra_partitions: int, stage: int = 0) -> int:
        """Add computing partitions to one stage group mid-feed; the
        upstream round-robin (the intake for group 0, the previous group's
        workers otherwise) picks them up on the next frame.  The new
        workers run the SAME compiled spec the group's original workers
        got — derived from the plan's stage group, never re-derived from
        the FeedConfig shim (a shim-era ``cfg.udf`` spec would enrich with
        the wrong pipeline on plan-submitted feeds).  Returns the number
        actually added (0 once the upstream has drained — a late worker
        would miss its StopRecord and never exit)."""
        group = self._group(stage)
        added = 0
        for _ in range(extra_partitions):
            with self._lock:
                if group.closing or (group.gid == 0 and
                                     self.intake is not None and
                                     self.intake.closing):
                    break
                self._add_partition_locked(group)
                self.stats.scale_ups += 1
                added += 1
        return added

    def scale_down(self, partitions: int = 1, stage: int = 0) -> int:
        """Retire computing partitions from one stage group: remove the
        holder from the upstream round-robin (under the lock, so no frame
        can target it afterwards), push a StopRecord so its worker drains
        the queued frames exactly-once into the sinks, and let the worker
        merge its ComputingStats into the feed totals as it exits.  Never
        drops below one partition (the elasticity controller additionally
        enforces its spec's ``min_partitions``).  Returns the number
        actually retired."""
        group = self._group(stage)
        dropped = 0
        for _ in range(partitions):
            with self._lock:
                if group.closing or len(group.holders) <= 1:
                    break
                holder = group.holders.pop()
                slot = next(s for s in group.slots if s.holder is holder)
                slot.retire.set()
                self.stats.scale_downs += 1
                dropped += 1
            # outside the lock: close() pushes the StopRecord (it may block
            # briefly on a full queue while the worker drains), and the
            # registry drops the holder so work stealing stops seeing it
            holder.close()
            self.manager.holder_manager.unregister(holder.holder_id)
        return dropped

    def _group(self, stage: int) -> _StageGroupRuntime:
        if not self.stage_groups:
            raise RuntimeError(
                "elasticity requires the decoupled plan path; the "
                "coupled/insert baselines are fixed-parallelism "
                "measurement rigs")
        return self.stage_groups[stage]

    def _add_partition_locked(self,  # requires-lock: _lock
                              group: _StageGroupRuntime) -> None:
        """Create holder + runner + worker for one new partition of
        ``group``.  Caller holds ``self._lock``."""
        pid = group.next_pid          # monotonic: retired ids never reused
        group.next_pid += 1
        holder = PartitionHolder((group.job, pid), self.cfg.holder_capacity)
        self.manager.holder_manager.register(holder)
        runner = ComputingRunner(group.spec, self.manager.refstore,
                                 self.manager.predeploy)
        slot = _WorkerSlot(pid, holder, runner)
        w = threading.Thread(target=self._worker_loop, args=(group, slot),
                             name=f"{self.cfg.name}-{group.name}-{pid}",
                             daemon=True)
        slot.thread = w               # set BEFORE the slot becomes visible:
        group.holders.append(holder)  # the controller reads slots lock-free
        group.slots.append(slot)
        group.peak_partitions = max(group.peak_partitions,
                                    len(group.holders))
        self.runners.append(runner)
        group.live += 1
        self._live_workers += 1
        self.workers.append(w)
        w.start()

    # --------------------------------------------------------------- workers
    def _coalesce(self, holder: PartitionHolder, frame):
        """Merge backlogged frames (same representation only) into one
        computing batch, bounded by the row/byte budgets."""
        cfg = self.cfg
        budget = cfg.resolved_coalesce_rows
        if budget <= 0 or cfg.model == "per_record":
            return frame
        kind = dict if isinstance(frame, dict) else list
        group = [frame]
        rows = _frame_rows(frame)
        nbytes = _frame_bytes(frame)
        while rows < budget and nbytes < cfg.coalesce_bytes:
            extra = holder.pull_nowait(lambda f: isinstance(f, kind))
            if extra is None:
                break
            group.append(extra)
            rows += _frame_rows(extra)
            nbytes += _frame_bytes(extra)
        if len(group) == 1:
            return frame
        with self._lock:
            self.stats.coalesced_frames += len(group) - 1
        seqs: List[int] = []
        sids: List[int] = []
        t_old = 0.0
        for g in group:
            seqs.extend(getattr(g, "wal_seqs", None) or ())
            sids.extend(getattr(g, "span_ids", ()))
            ti = getattr(g, "t_intake", 0.0)
            if ti and (not t_old or ti < t_old):
                t_old = ti       # oldest stamp: latency covers the whole
        if sids:
            # the coalesced batch covers every merged frame's WAL records
            # AND trace spans — the stamp unions ride to the sink; the
            # span emission is what merges the journeys in the profiler
            self.obs.emit("coalesce", tuple(sids), t0=time.monotonic(),
                          rows=rows, frames=len(group))
        if kind is dict:
            # downstream stage groups carry dict batches: union the
            # stamps onto a TrackedBatch so multi-group journeys stay
            # whole end to end (the pre-feedscope code dropped them here)
            merged_b = records.concat_batches(group)
            if seqs or sids or t_old:
                return TrackedBatch(merged_b, tuple(seqs), tuple(sids),
                                    t_old)
            return merged_b
        merged: List = []
        for g in group:
            merged.extend(g)
        if seqs or sids or t_old:
            return TrackedFrame(merged, tuple(seqs), tuple(sids), t_old)
        return merged

    def _run_with_retry(self, runner: ComputingRunner, frame) -> Dict:
        attempt = 0
        while True:
            with self._lock:
                inv = self._invocation_counter
                self._invocation_counter += 1
            try:
                if self.cfg.fault_hook is not None and \
                        self.cfg.fault_hook(inv):
                    raise RuntimeError(f"injected fault @ invocation {inv}")
                return runner.run(frame)
            except Exception:
                attempt += 1
                if attempt > self.cfg.max_retries:
                    raise
                with self._lock:
                    self.stats.retries += 1
                time.sleep(self.cfg.retry_backoff_s * (2 ** (attempt - 1)))

    def _worker_loop(self, group: _StageGroupRuntime,
                     slot: _WorkerSlot) -> None:
        pid, holder, runner = slot.pid, slot.holder, slot.runner
        try:
            while True:
                frame = holder.pull(timeout=0.05)
                if frame is None or isinstance(frame, StopRecord):
                    # idle or our queue drained: try stealing a backlog —
                    # never while retiring (the point is to shed capacity)
                    stolen = None
                    if self.cfg.work_stealing and not slot.retire.is_set():
                        deep = self.manager.holder_manager.deepest(
                            group.job, exclude=pid)
                        if deep is not None and deep.depth > 1:
                            stolen = deep.steal()
                    if stolen is None:
                        if isinstance(frame, StopRecord):
                            return
                        continue
                    frame = stolen
                    with self._lock:
                        self.stats.steals += 1
                if self._sinks_dead:
                    # no live sink: computing would silently discard the
                    # output anyway — drain frames without enriching so
                    # the intake never blocks and join() can surface the
                    # sink error promptly
                    continue
                frame = self._coalesce(holder, frame)
                # durable feed: lift the WAL stamp off the raw frame BEFORE
                # the runner consumes it (parsing returns a plain dict);
                # the obs stamps (core/obs) ride the same vehicle
                wal_seqs = getattr(frame, "wal_seqs", None)
                span_ids = getattr(frame, "span_ids", ())
                t_intake = getattr(frame, "t_intake", 0.0)
                # backlog sampling happens on EVERY pull, controller or
                # not — this is what makes backlog_p95_rows report for
                # static feeds (it used to be elasticity-only)
                rows_q, _ = holder.backlog()
                self._backlog_hist.observe(float(rows_q))
                if t_intake:
                    self._backlog_age_hist.observe(
                        max(0.0, time.monotonic() - t_intake))
                t0 = time.perf_counter()
                out = self._run_with_retry(runner, frame)
                apply_dt = time.perf_counter() - t0
                holder.record_service(apply_dt)
                if span_ids:
                    self.obs.emit(f"apply.{group.name}", span_ids,
                                  t0=time.monotonic() - apply_dt,
                                  dur=apply_dt, partition=pid)
                if group.next is not None:
                    # intermediate stage group: hand the enriched batch to
                    # the next group's holders, not the sinks — re-wrapped
                    # so the obs/WAL stamps survive the hop and the next
                    # group's apply span joins the same journey
                    if wal_seqs or span_ids or t_intake:
                        out = TrackedBatch(out, wal_seqs, span_ids,
                                           t_intake)
                    self._push_downstream(group, out)
                    continue
                out = self._project(out)
                # fan-out: every sink holder gets every batch exactly once;
                # the store sink's copy is tagged with the ref-version
                # lineage the batch was enriched under (repair subsystem)
                lineage = runner.last_versions
                delivered = 0
                for si, sh in enumerate(self.sink_holders):
                    if sh.error is not None:
                        # sink consumer raised: its holder closed itself
                        # (fail-fast drain); keep feeding the healthy
                        # sinks — the error is re-raised by join()
                        continue
                    try:
                        if si == self._store_sink_idx and \
                                (lineage is not None or wal_seqs or
                                 span_ids or t_intake):
                            sh.push(_StoreBatch(out, lineage, wal_seqs,
                                                span_ids, t_intake))
                        elif span_ids or t_intake:
                            # tee sinks get the same dict payload wrapped
                            # with the obs stamps so their sink.append
                            # spans carry ids — a slow tee then shows up
                            # in the critical-path profile by name
                            sh.push(TrackedBatch(out, None, span_ids,
                                                 t_intake))
                        else:
                            sh.push(out)
                        delivered += 1
                    except RuntimeError:
                        if sh.error is None:     # not a sink failure
                            raise
                if delivered == 0 and self.sink_holders:
                    # every sink is dead: stop the adapter and switch to
                    # discard-drain (below) so the stop protocol still
                    # completes; the sink error surfaces from join()
                    self._sinks_dead = True
                    self.adapter.stop()
        except BaseException as e:
            # feedlint R1 fix: error collection races join()'s liveness
            # checks without the lock (inside _note_worker_err)
            self._note_worker_err(e)
        finally:
            self._on_worker_exit(group, slot)

    def _push_downstream(self, group: _StageGroupRuntime, out: Dict) -> None:
        """Round-robin an enriched batch into the next stage group's live
        holder list, re-targeting when the chosen holder was retired
        between snapshot and push (the same exactly-once rule the intake
        follows)."""
        nxt = group.next
        while True:
            with self._lock:
                hs = list(nxt.holders)
                i = group.rr
                group.rr += 1
            target = hs[i % len(hs)]
            try:
                target.push(out)
                return
            except RuntimeError:
                if not target.closed:
                    raise

    def _on_worker_exit(self, group: _StageGroupRuntime,
                        slot: _WorkerSlot) -> None:
        now = time.perf_counter()
        downstream: List[PartitionHolder] = []
        with self._lock:
            group.live -= 1
            self._live_workers -= 1
            self.stats.worker_seconds += now - slot.t_start
            if slot.retire.is_set():
                # scale_down fix: the retired runner's counts land in the
                # feed totals the moment its worker exits, BEFORE the
                # runner is dropped from the live lists — invocations and
                # records can never vanish from FeedStats
                self._retired_computing.merge(slot.runner.stats)
                if slot.runner in self.runners:
                    self.runners.remove(slot.runner)
                if slot in group.slots:
                    group.slots.remove(slot)
            if group.live == 0 and group.next is not None:
                # last worker of this group: drain protocol hops one group
                # downstream (§7.1 — the storage job closes after the last
                # computing job; intermediate groups close the same way)
                group.next.closing = True
                downstream = list(group.next.holders)
        for h in downstream:          # outside the lock: close() can block
            if not h.closed:
                h.close()

    def _project(self, out: Dict) -> Dict:
        """Plan-level projection: restrict the columns sinks receive (id +
        valid always flow).  Cheap dict subset — the arrays are shared, not
        copied; sinks must treat batches as read-only (they already do).
        Shared with the repair job via ``IngestPlan.restrict`` so repaired
        rows carry exactly the stored column set."""
        if self.plan is None:
            return out
        return self.plan.restrict(out)


class FeedManager:
    """The AFM: tracks active feeds, owns the predeploy cache and the
    partition-holder registry, and starts/stops the per-feed job trios."""

    def __init__(self, refstore: Optional[RefStore] = None):
        self.refstore = refstore or RefStore()
        self.predeploy = PredeployCache()
        self.holder_manager = PartitionHolderManager()
        self._lock = threading.Lock()           # lock-name: manager
        self.feeds: Dict[str, FeedHandle] = {}  # guarded-by: _lock
        # feedscope live ops endpoint (core/obs/server.py), opt-in via
        # serve_obs(); started/stopped from the caller's thread only
        self._obs_server: Optional[ObsServer] = None

    # --------------------------------------------------------------- submit
    def submit(self, plan, _resume=None) -> FeedHandle:
        """Execute a declarative ingestion plan (core/plan.py).  Accepts an
        ``IngestPlan`` or an uncompiled ``Pipeline`` (compiled here against
        this manager's refstore — all validation happens before any job
        thread starts).  ``_resume`` is the private crash-restart path:
        ``FeedManager.resume`` builds a ``recovery.RecoveryState`` and
        re-submits the plan through here so both paths share the exact
        same wiring."""
        if isinstance(plan, Pipeline):
            plan = plan.compile(self.refstore)
        if not isinstance(plan, IngestPlan):
            raise TypeError("submit() takes an IngestPlan or Pipeline, "
                            f"got {type(plan).__name__}")
        cfg = FeedConfig(
            name=plan.name, udf=plan.udf, batch_size=plan.batch_size,
            num_partitions=plan.num_partitions, model=plan.model,
            refresh=plan.refresh, framework="new",
            work_stealing=plan.work_stealing, max_retries=plan.max_retries,
            retry_backoff_s=plan.retry_backoff_s,
            holder_capacity=plan.holder_capacity,
            coalesce_rows=plan.coalesce_rows,
            coalesce_bytes=plan.coalesce_bytes,
            fault_hook=plan.fault_hook, elastic=plan.elastic)
        adapter = _resume.adapter if _resume is not None else plan.adapter
        handle = FeedHandle(cfg, self, adapter, plan=plan)
        # feedlint R1 fix: check-then-insert is one critical section, so
        # two racing submits of the same name cannot both win
        with self._lock:
            if plan.name in self.feeds:
                raise KeyError(f"feed {plan.name} already active")
            self.feeds[plan.name] = handle
        handle._t0 = time.perf_counter()
        self._start_new(cfg, handle, plan, resume=_resume)
        return handle

    def resume(self, plan, durable_dir: Optional[str] = None) -> FeedHandle:
        """Crash-restart a durable feed (core/recovery.py): recover every
        storage partition from its manifest, load the last checkpoint,
        replay the intake log's tail through the normal pipeline (the
        idempotent pk-index insert de-duplicates rows the crashed run
        already stored), fast-forward the adapter to the last durable
        offset, and hand back a live FeedHandle.  ``durable_dir``
        overrides the plan's ``DurableSpec.dir`` (resume a directory the
        plan object didn't originally point at)."""
        from repro.core import recovery
        return recovery.resume_feed(self, plan, durable_dir)

    # ------------------------------------------------- baseline entry point
    def start(self, cfg: FeedConfig, adapter: Adapter) -> FeedHandle:
        """Entry point for the paper-baseline measurement rigs ONLY
        (framework "current"/"balanced"/"insert" — fixed cfg-driven
        pipelines the figures compare against).  The deprecated
        framework="new" lowering is gone: decoupled feeds are built with
        ``pipeline(adapter).parse(...)....store()/.tee(...)`` and
        ``submit`` (FeedConfig survives as the internal runtime config a
        compiled plan lowers onto)."""
        if cfg.framework == "new":
            raise ValueError(
                "FeedManager.start no longer lowers framework='new' "
                "FeedConfigs (the deprecated shim was removed): build the "
                "feed with pipeline(adapter).parse(...)....store()/"
                ".tee(...) and FeedManager.submit instead")

        handle = FeedHandle(cfg, self, adapter)
        with self._lock:
            if cfg.name in self.feeds:
                raise KeyError(f"feed {cfg.name} already active")
            self.feeds[cfg.name] = handle
        handle._t0 = time.perf_counter()
        nstore = cfg.storage_partitions or cfg.num_partitions
        handle.storage = StorageJob(nstore, cfg.spill_dir, cfg.upsert)

        if cfg.framework in ("current", "balanced"):
            self._start_coupled(cfg, handle,
                                balanced=cfg.framework == "balanced")
        elif cfg.framework == "insert":
            self._start_insert(cfg, handle)
        else:
            raise ValueError(cfg.framework)
        return handle

    def _start_new(self, cfg: FeedConfig, handle: FeedHandle,
                   plan: IngestPlan, resume=None) -> None:
        # durable plans: attach the WAL + ledger runtime — fresh feeds
        # create/refuse-dirty the log directory, crash-restarts arrive
        # with the already-recovered runtime in the RecoveryState
        dspec = (plan.store_spec.durable
                 if plan.store_spec is not None else None)
        if plan.trace is not None:
            # span tracing is plan-opt-in; metrics are always on
            handle.obs.enable_trace(plan.trace)
        if plan.profile is not None:
            # the profiler consumes spans, so profile=... implies a
            # default tracer when the plan didn't configure one itself
            if handle.obs.tracer is None:
                handle.obs.enable_trace(TraceSpec())
            handle.profiler = JourneyProfiler(plan.profile)
        if resume is not None:
            handle.durability = resume.runtime
        elif dspec is not None:
            handle.durability = DurabilityRuntime.create(dspec)
        ledger = (handle.durability.ledger
                  if handle.durability is not None else None)
        # one active holder per sink: the plan's multi-sink fan-out
        for i, spec in enumerate(plan.sinks):
            if spec.is_store:
                nstore = spec.store.partitions or cfg.num_partitions
                handle.storage = StorageJob(nstore, spec.store.spill_dir,
                                            spec.store.upsert,
                                            spec.store.segment_rows,
                                            spec.store.zone_map_cols,
                                            spec.store.sort_key,
                                            obs=handle.obs)
                handle._store_sink_idx = i
                consumer = _store_consumer(handle.storage, ledger,
                                           obs=handle.obs)
            else:
                consumer = spec.consumer
            sh = ActivePartitionHolder(
                (f"{cfg.name}:storage", i), consumer,
                capacity=cfg.holder_capacity, obs=handle.obs)
            self.holder_manager.register(sh)
            handle.sink_holders.append(sh)
            handle._sink_names.append(spec.name)
        handle.storage_holder = handle.sink_holders[0]
        if resume is not None and handle.storage is not None:
            # crash-restart: rebuild every partition from its manifest
            # BEFORE any worker can write — the recovered pk index is
            # what de-duplicates the replayed WAL tail
            handle.storage.recover()
            if resume.reset_lineage:
                # checkpointed ref fingerprints did not match the current
                # reference tables: drop lineage so repair re-scans
                # EVERYTHING rather than trusting stale versions
                handle.storage.reset_lineage()

        # stage groups: the plan's independently-scalable chain segments
        # (pre-stage-group IngestPlans lower to one group over plan.udf)
        groups = plan.stage_groups or (StageGroup(
            plan.udf.name if plan.udf is not None else "parse",
            plan.udf, 0, plan.elastic),)
        prev: Optional[_StageGroupRuntime] = None
        for gid, g in enumerate(groups):
            job = (f"{cfg.name}:intake" if gid == 0
                   else f"{cfg.name}:stage{gid}")
            rt = _StageGroupRuntime(
                gid, g.name, job,
                ComputingSpec(g.udf, cfg.batch_size, cfg.model,
                              cfg.refresh), g.elastic)
            handle.stage_groups.append(rt)
            if prev is not None:
                prev.next = rt
            prev = rt
        # the intake's live round-robin list IS group 0's holder list
        handle.holders = handle.stage_groups[0].holders
        for g, rt in zip(groups, handle.stage_groups):
            n = g.partitions or cfg.num_partitions
            if resume is not None:
                # resume at the learned scale: the checkpoint persisted
                # per-group partition counts (ElasticityController state)
                n = resume.partitions.get(rt.name, n)
            if rt.elastic is not None:
                # elastic groups start inside their declared bounds
                n = min(max(n, rt.elastic.min_partitions),
                        rt.elastic.max_partitions)
            else:
                n = max(1, n)
            with handle._lock:
                for _ in range(n):
                    handle._add_partition_locked(rt)
        wal = (handle.durability.wal
               if handle.durability is not None else None)
        if wal is not None:
            wal.set_fsync_histogram(
                handle.obs.registry.histogram("wal_fsync_s"))
        handle.intake = IntakeJob(handle.adapter, handle.holders,
                                  lock=handle._lock, wal=wal,
                                  ledger=ledger, obs=handle.obs)
        handle.intake.start()
        if any(rt.elastic is not None for rt in handle.stage_groups):
            handle.controller = ElasticityController(
                handle, cfg.batch_size, name=cfg.name)
            handle.controller.start()
        store_spec = plan.store_spec
        if store_spec is not None and store_spec.refresh is not None:
            # progressive re-enrichment: the background repair scheduler
            # (compile() guaranteed an enrich stage and a single group)
            handle.repair = RepairJob(plan, handle.storage, self.refstore,
                                      self.predeploy, handle=handle)
            if resume is not None and resume.repair_events:
                # checkpointed ref-event log (fingerprints matched):
                # restore BEFORE start so the first scheduler pass sees it
                handle.repair.restore_events(resume.repair_events)
            handle.repair.start()
        if store_spec is not None and store_spec.compact is not None:
            # background space reclaim: budgeted, yields to ingestion the
            # same way repair does (core/compaction.py)
            handle.compaction = CompactionJob(
                handle.storage, store_spec.compact, cfg.batch_size,
                handle=handle, name=cfg.name)
            handle.compaction.start()
        if handle.durability is not None:
            # coordinated checkpoints: start LAST so every job the
            # checkpoint snapshots (storage, repair, stage groups) exists
            ref_tables = (plan.udf.ref_tables
                          if handle.repair is not None and
                          plan.udf is not None else ())
            handle.durability.start(handle, self.refstore, ref_tables)

    # ------------------------------------------------- coupled baselines
    def _start_coupled(self, cfg: FeedConfig, handle: FeedHandle,
                       balanced: bool) -> None:
        """'Current feeds': one chained job — parse -> UDF (Model 3, state
        never refreshed) -> store.  'Balanced': parsing (and the chained
        work) divided over num_partitions threads."""
        nthreads = cfg.num_partitions if balanced else 1
        spec = ComputingSpec(cfg.udf, cfg.batch_size, model="stream")
        handle.holders = [PartitionHolder((f"{cfg.name}:intake", i),
                                          cfg.holder_capacity)
                          for i in range(nthreads)]
        for h in handle.holders:
            self.holder_manager.register(h)

        def loop(pid: int, holder: PartitionHolder,
                 runner: ComputingRunner):
            try:
                while True:
                    frame = holder.pull(timeout=0.05)
                    if isinstance(frame, StopRecord):
                        return
                    if frame is None:
                        continue
                    out = runner.run(frame)       # parse+enrich chained
                    handle.storage.write(out)     # ... with storage
            except BaseException as e:
                handle._note_worker_err(e)

        for i, h in enumerate(handle.holders):
            runner = ComputingRunner(spec, self.refstore, self.predeploy)
            handle.runners.append(runner)
            w = threading.Thread(target=loop, args=(i, h, runner),
                                 name=f"{cfg.name}-coupled-{i}", daemon=True)
            handle.workers.append(w)
            w.start()
        handle.intake = IntakeJob(handle.adapter, handle.holders)
        handle.intake.start()

    def _start_insert(self, cfg: FeedConfig, handle: FeedHandle) -> None:
        """Approach 1 (§5.2.1): an external program issuing repeated INSERT
        statements — every statement re-pays query compilation and job
        distribution, i.e. NO predeploy cache: fresh jit per batch."""
        spec = ComputingSpec(cfg.udf, cfg.batch_size, model="per_batch")

        def loop():
            try:
                runner = ComputingRunner(spec, self.refstore,
                                         PredeployCache())
                handle.runners.append(runner)
                for frame in handle.adapter.frames():
                    runner.cache = PredeployCache()   # recompilation cost
                    out = runner.run(frame)
                    handle.storage.write(out)
                    # _frame_rows, not len(): a dict frame's len() is its
                    # COLUMN count; take the handle lock — stats are also
                    # read/merged from the joining thread
                    with handle._lock:
                        handle.stats.frames_in += 1
                        handle.stats.records_in += _frame_rows(frame)
            except BaseException as e:
                handle._note_worker_err(e)

        w = threading.Thread(target=loop, name=f"{cfg.name}-insert",
                             daemon=True)
        handle.workers.append(w)
        w.start()

    # ----------------------------------------------------------- feedscope
    def active_feeds(self) -> Dict[str, FeedHandle]:
        """Snapshot of the active feed table (name -> handle).  The live
        ops endpoint renders from this copy, so no HTTP handler ever
        holds the manager lock while reading feed state."""
        with self._lock:
            return dict(self.feeds)

    def serve_obs(self, port: int = 0,
                  host: str = "127.0.0.1") -> ObsServer:
        """Start (idempotently) the zero-dependency live ops endpoint:
        ``/metrics`` (Prometheus text across all active feeds),
        ``/health`` (SLO verdicts; 503 when any feed stalls),
        ``/profile`` (critical-path attribution JSON) and ``/trace``
        (recent raw spans).  ``port=0`` binds a free port — read the
        result's ``.url``.  The server is a daemon thread reading only
        snapshots; stop it with ``stop_obs()``."""
        if self._obs_server is None:
            self._obs_server = ObsServer(self, host, port).start()
        return self._obs_server

    def stop_obs(self) -> None:
        """Shut the live ops endpoint down (no-op when never started)."""
        srv = self._obs_server
        if srv is not None:
            self._obs_server = None
            srv.stop()

    def stop_all(self) -> None:
        with self._lock:
            handles = list(self.feeds.values())
        for h in handles:
            h.stop()
