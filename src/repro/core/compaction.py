"""Background segment compaction: reclaim superseded and deleted row
versions so analytical scans don't degrade as upserts and repair churn
rows (closing the PR 4 known limit: 'superseded row versions accumulate
append-only — no segment compaction yet').

Declared on the plan's store sink next to the repair policy:

    .store(refresh=RepairSpec(...),
           compact=CompactionSpec(budget_rows_s=..., min_dead_frac=...))

The job shares the repair scheduler's citizenship model — it is a
*background* maintenance task:

  * **token bucket** on scanned rows/s (a compaction rewrites every row of
    the segment it touches, so segment rows are the honest cost unit) with
    a deliberately shallow burst;
  * **yields to ingestion**: while the feed has computing backlog, or an
    elastic group is scaled above its floor, the job skips its cycle
    (``repair.feed_busy`` — the same test the repair scheduler uses);
  * **trigger** per unit: dead fraction (exactly tracked by the storage
    layer's per-segment counters — no scan needed to decide) at or above
    ``min_dead_frac``.

Beyond dead-row reclaim the job also owns the **leveled merge policy**
(``level_target_rows``/``merge_fanin``): contiguous runs of small
segments are merged into one next-level segment, re-sorted on the
store's ``sort_key`` and with zone maps rebuilt, so per-unit scan
overhead shrinks as data ages (see docs/STORAGE.md).

Correctness is owned by the storage layer's primitives
(``compact_segment``/``compact_chunks``/``merge_segments``): the
decide+rewrite+swap runs atomically under the partition lock, the layout
epoch bump fences in-flight conditional repairs, and pinned query
snapshots keep replaced segment files readable until released.  This
module only *schedules*.  ``drain()`` compacts everything regardless of
budget (benchmarks and tests use it to assert 100% reclaim);
``merge_now()`` is the synchronous analogue for merging."""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional, Tuple

from repro.core.repair import feed_busy
from repro.core.storage import StorageJob


@dataclasses.dataclass(frozen=True)
class CompactionSpec:
    """Compaction policy for one plan's store sink (``.store(compact=...)``).

    ``budget_rows_s`` caps rewritten rows/s (the knob trading space reclaim
    against ingestion interference); ``min_dead_frac`` is the per-unit
    trigger — rewriting a segment that is 2% garbage wastes IO, one that is
    half garbage halves the scan cost of every future query over it.

    ``level_target_rows`` > 0 additionally enables **leveled merging**
    (size-tiered): a contiguous run of at least ``merge_fanin`` flushed
    segments, each smaller than the target, is merged into ONE segment at
    the next level — re-sorted on the store's ``sort_key``, zone maps
    rebuilt — so per-unit scan overhead shrinks as data ages instead of
    staying flat at flush-size segments.  A segment at or above the
    target **graduates**: it is never merged again, which bounds write
    amplification to O(log_fanin(target/flush)) copies per row."""
    budget_rows_s: float = 50_000.0
    min_dead_frac: float = 0.25
    interval_s: float = 0.25       # scheduler cadence
    yield_backlog_batches: float = 0.0   # same semantics as RepairSpec's
    burst_s: float = 0.1
    merge_fanin: int = 8           # max (and trigger) segments per merge
    level_target_rows: int = 0     # 0 disables merging

    def __post_init__(self):
        if self.budget_rows_s <= 0:
            raise ValueError("budget_rows_s must be > 0")
        if not 0.0 <= self.min_dead_frac <= 1.0:
            raise ValueError("min_dead_frac must be in [0, 1]")
        if self.interval_s <= 0 or self.burst_s <= 0:
            raise ValueError("interval_s and burst_s must be > 0")
        if self.yield_backlog_batches < 0:
            raise ValueError("yield_backlog_batches must be >= 0")
        if self.merge_fanin < 2:
            raise ValueError("merge_fanin must be >= 2")
        if self.level_target_rows < 0:
            raise ValueError("level_target_rows must be >= 0")


@dataclasses.dataclass
class CompactionStats:
    segments_compacted: int = 0
    chunk_compactions: int = 0
    rows_dropped: int = 0        # superseded/deleted versions reclaimed
    rows_rewritten: int = 0      # live rows copied into new segments
    steps: int = 0
    yields: int = 0
    compact_s: float = 0.0
    merges: int = 0              # merge operations (K segments -> 1)
    segments_merged: int = 0     # input segments consumed by merges
    rows_merged: int = 0         # rows read (live + dead) by merges


def find_merge_run(stats, fanin: int, target_rows: int,
                   min_run: Optional[int] = None
                   ) -> Optional[Tuple[int, int, int]]:
    """First mergeable run in one partition's ``segment_stats()`` output:
    ``(start_index, count, total_rows)`` over a contiguous run of
    below-target segments at least ``min_run`` (default: ``fanin``) long,
    capped at ``fanin`` inputs per merge — or None.  Pure policy; the
    caller re-validates against the live layout via ``merge_segments``'s
    own bounds check."""
    if target_rows <= 0:
        return None
    need = fanin if min_run is None else min_run
    i, nseg = 0, len(stats)
    while i < nseg:
        if stats[i][0] >= target_rows:
            i += 1
            continue
        j = i                     # extend the run, up to fanin inputs
        while j < nseg and stats[j][0] < target_rows and j - i < fanin:
            j += 1
        if j - i >= max(need, 2):
            return (i, j - i,
                    int(sum(rows for rows, _d, _l in stats[i:j])))
        while j < nseg and stats[j][0] < target_rows:
            j += 1                # run too short: skip past all of it
        i = j
    return None


class CompactionJob(threading.Thread):
    """Budgeted background compactor for one feed's store (one thread;
    ``step()`` is synchronous and internally serialized so tests and
    ``drain()`` call it directly)."""

    def __init__(self, storage: StorageJob, spec: CompactionSpec,
                 batch_size: int = 420, handle=None, name: str = "store"):
        super().__init__(name=f"{name}-compact", daemon=True)
        self.storage = storage
        self.spec = spec
        self.batch_size = batch_size
        self.handle = handle      # duck-typed FeedHandle (None in tests)
        self._obs = getattr(handle, "obs", None)
        self.stats = CompactionStats()
        self.error: Optional[BaseException] = None
        # serializes step(); dedicated background lock — the segment
        # rewrites it triggers block under the partition lock by design
        self._step_lock = threading.Lock()  # lock-name: compaction-step blocking-ok
        self._stop_evt = threading.Event()
        self._tokens = spec.budget_rows_s * spec.burst_s  # guarded-by: _step_lock
        self._last_refill = time.monotonic()              # guarded-by: _step_lock

    # ----------------------------------------------------------- scheduling
    def run(self) -> None:
        while not self._stop_evt.wait(self.spec.interval_s):
            try:
                self.step()
            except BaseException as e:   # surfaced by FeedHandle.join()
                self.error = e
                return

    def stop(self) -> None:
        self._stop_evt.set()

    def _refill(self, now: float) -> None:  # requires-lock: _step_lock
        cap = self.spec.budget_rows_s * self.spec.burst_s
        self._tokens = min(cap, self._tokens + (now - self._last_refill)
                           * self.spec.budget_rows_s)
        self._last_refill = now

    def step(self, force: bool = False) -> int:
        """One pass over the store's garbage units; returns rows dropped.
        ``force`` ignores the budget, the backlog yield, and the dead-
        fraction trigger (the drain path)."""
        with self._step_lock:
            t0 = time.perf_counter()
            self.stats.steps += 1
            self._refill(time.monotonic())
            if not force:
                if feed_busy(self.handle,
                             self.spec.yield_backlog_batches
                             * self.batch_size):
                    self.stats.yields += 1
                    return 0
                if self._tokens <= 0:
                    return 0
            frac = 0.0 if force else self.spec.min_dead_frac
            dropped = 0
            for part in self.storage.partitions:
                # reversed: an all-dead segment is deleted outright,
                # shifting later indices — walking high-to-low keeps
                # the rest of this stale snapshot valid
                for si, rows, dead in reversed(part.garbage_units()):
                    if rows == 0 or dead == 0 or \
                            (rows and dead / rows < frac):
                        continue
                    if not force and self._tokens <= 0:
                        break
                    self._tokens -= rows     # rewritten rows cost budget
                    if si is None:
                        got = part.compact_chunks()
                        self.stats.chunk_compactions += int(got > 0)
                    else:
                        got = part.compact_segment(si)
                        self.stats.segments_compacted += int(got > 0)
                    self.stats.rows_dropped += got
                    self.stats.rows_rewritten += rows - got
                    dropped += got
            if self.spec.level_target_rows > 0:
                dropped += self._merge_pass(force)
            self.stats.compact_s += time.perf_counter() - t0
            return dropped

    def _merge_pass(self, force: bool,  # requires-lock: _step_lock
                    min_run: Optional[int] = None) -> int:
        """Leveled-merge scheduling pass over every partition; returns
        rows dropped (dead versions that vanish inside merges).  Policy
        is ``find_merge_run``; correctness (epoch fence, pinned-snapshot
        GC, manifest ordering) is ``StoragePartition.merge_segments``."""
        spec = self.spec
        dropped = 0
        for part in self.storage.partitions:
            while force or self._tokens > 0:
                run = find_merge_run(part.segment_stats(),
                                     spec.merge_fanin,
                                     spec.level_target_rows, min_run)
                if run is None:
                    break
                si, count, run_rows = run
                if not force:
                    self._tokens -= run_rows   # merges rewrite every row
                t_m = time.perf_counter()
                try:
                    n, got = part.merge_segments(si, count)
                except IndexError:
                    break    # layout moved since segment_stats(); retry
                if self._obs is not None and self._obs.tracing:
                    # under the compaction-step lock only (blocking-ok:
                    # R6-exempt, edge declared in analysis/annotations.py)
                    self._obs.emit("compact.merge", (),
                                   t0=time.monotonic(),
                                   dur=time.perf_counter() - t_m,
                                   rows=n, dropped=got, inputs=count,
                                   partition=part.pid)
                self.stats.merges += 1
                self.stats.segments_merged += count
                self.stats.rows_merged += n
                self.stats.rows_dropped += got
                self.stats.rows_rewritten += n - got
                dropped += got
        return dropped

    def merge_now(self, min_run: int = 2) -> int:
        """Synchronously merge every eligible run, ignoring the budget
        and relaxing the fanin trigger to runs of ``min_run`` segments;
        returns rows dropped.  Benchmarks, tests, and the quickstart use
        it to age a store on demand (the background scheduler does the
        same work incrementally via ``step``)."""
        with self._step_lock:
            if self.spec.level_target_rows <= 0:
                return 0
            return self._merge_pass(True, min_run=min_run)

    # -------------------------------------------------------------- drain
    def drain(self, timeout: Optional[float] = 60.0) -> bool:
        """Compact until no dead rows remain (unbudgeted); returns whether
        it got there within ``timeout``.  Under concurrent writers the
        target moves — quiesce them first for a guaranteed-zero store."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while self.storage.dead_rows > 0:
            if deadline is not None and time.monotonic() > deadline:
                return False
            if self.step(force=True) == 0 and self.storage.dead_rows > 0:
                # raced a writer between decide and recheck; keep going
                time.sleep(0.001)
        return True

    def finish(self, timeout: Optional[float] = 60.0) -> bool:
        """Stop the scheduler thread (feed shutdown).  No forced drain:
        compaction is an optimization, not a correctness requirement —
        callers wanting a fully-reclaimed store use ``drain()`` first."""
        self.stop()
        if self.is_alive():
            self.join(timeout)
        return self.storage.dead_rows == 0
