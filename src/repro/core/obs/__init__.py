"""Feed observability: trace spans, unified metrics, currency accounting.

``FeedObs`` is the per-feed bundle every pipeline component shares: a
``MetricsRegistry`` (always on — counters/gauges are lock-free attribute
updates, histograms a tiny per-instrument lock) and an optional
``Tracer`` (opt-in via ``.options(trace=...)``; ``obs.emit`` is a no-op
when tracing is off, so instrumentation sites never branch on policy).

Lock discipline (feedlint R6, docs/CONCURRENCY.md): histogram
``observe`` and span ``emit`` must run with no core lock held
(``blocking-ok`` step locks exempt, with declared lock-order edges);
counter/gauge updates are allowed anywhere.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from repro.core.obs.health import (FeedHealthModel, HealthReport,
                                   HealthSpec, STATE_CODE)
from repro.core.obs.metrics import (Counter, Gauge, Histogram,
                                    HistogramSnapshot, MetricsRegistry,
                                    MetricValue, ROWS_BOUNDS,
                                    SECONDS_BOUNDS, mangle, percentile_of)
from repro.core.obs.profile import (HOP_ORDER, HopStats, JourneyProfiler,
                                    ProfileReport, ProfileSpec)
from repro.core.obs.server import ObsServer, http_get
from repro.core.obs.trace import Tracer, TraceSpec, write_jsonl


class FeedObs:
    """One feed's observability bundle: registry (always) + tracer
    (when a ``TraceSpec`` is enabled)."""

    def __init__(self, trace: Optional[TraceSpec] = None):
        self.registry = MetricsRegistry()
        self.trace_spec: Optional[TraceSpec] = trace
        self.tracer: Optional[Tracer] = \
            Tracer(trace.capacity) if trace is not None else None

    def enable_trace(self, spec: TraceSpec) -> None:
        self.trace_spec = spec
        self.tracer = Tracer(spec.capacity)

    @property
    def tracing(self) -> bool:
        return self.tracer is not None

    def new_span(self) -> int:
        """Fresh span id, or 0 when tracing is off (0 never collides —
        real ids start at 1)."""
        tr = self.tracer
        return tr.new_id() if tr is not None else 0

    def emit(self, name: str, spans: Tuple[int, ...] = (), t0: float = 0.0,
             dur: float = 0.0, **extra: Any) -> None:
        """Emit one span; no-op when tracing is off.  Subject to
        feedlint R6: never call while holding a core lock."""
        tr = self.tracer
        if tr is not None:
            tr.emit(name, spans, t0, dur, **extra)

    def drain_trace(self) -> List[Dict[str, Any]]:
        tr = self.tracer
        return tr.drain() if tr is not None else []


__all__ = ["FeedObs", "MetricsRegistry", "MetricValue", "Counter", "Gauge",
           "Histogram", "HistogramSnapshot", "Tracer", "TraceSpec",
           "SECONDS_BOUNDS", "ROWS_BOUNDS", "mangle", "percentile_of",
           "write_jsonl",
           "FeedHealthModel", "HealthReport", "HealthSpec", "STATE_CODE",
           "HOP_ORDER", "HopStats", "JourneyProfiler", "ProfileReport",
           "ProfileSpec", "ObsServer", "http_get"]
