"""feedscope: per-feed health states from declarative SLO rules.

A ``FeedHealthModel`` evaluates a metrics snapshot (the mapping
``FeedHandle.metrics()`` returns) against a small, declarative rule set
(``HealthSpec``) and yields one of three states:

  ``ok``        every rule passes
  ``degraded``  an SLO rule tripped but the feed is still moving
  ``stalled``   outstanding work exists and *no progress* has been made
                for longer than ``stall_after_s``

Rules (each one line of the ``/health`` report; thresholds in
``HealthSpec``):

| rule              | signal (registry instrument)                  |
|-------------------|-----------------------------------------------|
| visible_latency   | ``ingest_visible_latency_s`` p95 over budget  |
| wal_fsync         | ``wal_fsync_s`` p95 over budget               |
| repair_currency   | ``repair_currency_s`` p95 vs the repair SLO   |
|                   | (``max_lag_s`` x ``repair_lag_slack``)        |
| worker_errors     | ``worker_errors`` counter over the allowance  |
| backlog_growth    | ``backlog_rows_now`` strictly increasing over |
|                   | ``backlog_growth_evals`` evaluations          |
| stalled           | ``backlog_rows_now`` > 0 while the progress   |
|                   | counters (``feed_stored`` + ``sink_*_batches``|
|                   | pulls) sit still for > ``stall_after_s``      |

Empty histograms are skipped, not judged: their percentiles are ``nan``
by design (core/obs/metrics.py), and ``nan > x`` is False anyway — a
never-observed latency is "no data", never "instant".

The model is **clock-injectable** (pass ``clock=`` a fake monotonic
callable) so stall and growth transitions unit-test without sleeping.
Evaluations serialize on a private lock (``health``) held only around
pure in-memory bookkeeping — no other lock, no blocking call, and no
``observe``/``emit`` ever runs under it, so feedlint's lock hierarchy
gains no edges.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable, Deque, Dict, List, Mapping, Optional

from repro.core.obs.metrics import HistogramSnapshot

OK = "ok"
DEGRADED = "degraded"
STALLED = "stalled"

#: state -> ``feed_health`` gauge encoding (worst wins)
STATE_CODE: Dict[str, int] = {OK: 0, DEGRADED: 1, STALLED: 2}


@dataclasses.dataclass(frozen=True)
class HealthSpec:
    """Declarative SLO thresholds (``.options(health=...)``).  A rule
    whose signal is absent from the snapshot — no WAL, no repair, never
    observed — passes by definition."""
    visible_p95_s: float = 5.0       # store-visible latency budget
    wal_fsync_p95_s: float = 1.0     # durable-feed fsync budget
    repair_lag_slack: float = 2.0    # degraded past slack * max_lag_s
    max_worker_errors: int = 0       # tolerated worker-loop errors
    backlog_growth_evals: int = 3    # monotone growth across N evals
    stall_after_s: float = 5.0       # no progress w/ backlog -> stalled

    def __post_init__(self):
        if self.backlog_growth_evals < 2:
            raise ValueError("backlog_growth_evals must be >= 2")
        if self.stall_after_s <= 0:
            raise ValueError("stall_after_s must be > 0")


@dataclasses.dataclass
class HealthReport:
    """One evaluation's outcome.  ``state`` is the worst rule outcome,
    ``code`` its ``feed_health`` gauge encoding, ``rules`` every rule's
    own state, and ``reasons`` one human line per non-ok rule."""
    state: str = OK
    code: int = 0
    rules: Dict[str, str] = dataclasses.field(default_factory=dict)
    reasons: List[str] = dataclasses.field(default_factory=list)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def _p95(snap: Mapping[str, Any], name: str) -> Optional[float]:
    """p95 of a histogram snapshot, or None when absent/never observed
    (empty percentiles are nan by design — treat as no data)."""
    h = snap.get(name)
    if not isinstance(h, HistogramSnapshot) or not h.count:
        return None
    return h.percentile(0.95)


class FeedHealthModel:
    """Stateful rule evaluator for ONE feed.  Keep one instance per feed
    (the growth/stall rules compare consecutive evaluations); hand every
    ``evaluate`` call the feed's current ``metrics()`` snapshot."""

    def __init__(self, spec: Optional[HealthSpec] = None,
                 max_lag_s: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.spec = spec or HealthSpec()
        self.max_lag_s = max_lag_s   # repair SLO (None = rule disabled)
        self._clock = clock
        # pure in-memory bookkeeping only — nothing blocking, no other
        # lock, no observe/emit ever runs under it
        self._lock = threading.Lock()         # lock-name: health
        self._backlogs: Deque[float] = collections.deque(
            maxlen=self.spec.backlog_growth_evals)  # guarded-by: _lock
        self._progress: Optional[float] = None      # guarded-by: _lock
        self._progress_t = 0.0                      # guarded-by: _lock

    # ------------------------------------------------------------- evaluate
    def evaluate(self, snap: Mapping[str, Any]) -> HealthReport:
        spec = self.spec
        report = HealthReport()

        def rule(name: str, state: str, reason: str = "") -> None:
            report.rules[name] = state
            if state != OK:
                report.reasons.append(f"{name}: {reason}")
            if STATE_CODE[state] > report.code:
                report.state = state
                report.code = STATE_CODE[state]

        p = _p95(snap, "ingest_visible_latency_s")
        rule("visible_latency",
             DEGRADED if p is not None and p > spec.visible_p95_s else OK,
             f"p95 {p:.3f}s > {spec.visible_p95_s:.3f}s budget"
             if p is not None else "")

        p = _p95(snap, "wal_fsync_s")
        rule("wal_fsync",
             DEGRADED if p is not None and p > spec.wal_fsync_p95_s else OK,
             f"p95 {p:.3f}s > {spec.wal_fsync_p95_s:.3f}s budget"
             if p is not None else "")

        p = _p95(snap, "repair_currency_s")
        lag_budget = (self.max_lag_s * spec.repair_lag_slack
                      if self.max_lag_s is not None else None)
        rule("repair_currency",
             DEGRADED if (p is not None and lag_budget is not None
                          and p > lag_budget) else OK,
             f"p95 {p:.3f}s > {lag_budget:.3f}s "
             f"(max_lag_s x {spec.repair_lag_slack:g})"
             if p is not None and lag_budget is not None else "")

        errs = int(snap.get("worker_errors", 0) or 0)
        rule("worker_errors",
             DEGRADED if errs > spec.max_worker_errors else OK,
             f"{errs} worker error(s) (allowed {spec.max_worker_errors})")

        backlog = float(snap.get("backlog_rows_now", 0.0) or 0.0)
        progress = float(snap.get("feed_stored", 0) or 0)
        progress += sum(float(v) for k, v in snap.items()
                        if k.startswith("sink_") and k.endswith("_batches")
                        and isinstance(v, (int, float)))
        now = self._clock()
        with self._lock:
            self._backlogs.append(backlog)
            growing = (len(self._backlogs) ==
                       self._backlogs.maxlen and
                       all(a < b for a, b in zip(list(self._backlogs),
                                                 list(self._backlogs)[1:])))
            if self._progress is None or progress != self._progress \
                    or backlog <= 0.0:
                # progress moved (or nothing is outstanding): re-anchor
                self._progress = progress
                self._progress_t = now
            stalled_for = now - self._progress_t
        rule("backlog_growth", DEGRADED if growing else OK,
             f"backlog grew monotonically over the last "
             f"{spec.backlog_growth_evals} evaluations "
             f"(now {backlog:.0f} rows)")
        rule("stalled",
             STALLED if (backlog > 0.0
                         and stalled_for > spec.stall_after_s) else OK,
             f"{backlog:.0f} rows outstanding with no progress for "
             f"{stalled_for:.1f}s (> {spec.stall_after_s:.1f}s)")
        return report


__all__ = ["DEGRADED", "FeedHealthModel", "HealthReport", "HealthSpec",
           "OK", "STALLED", "STATE_CODE"]
