"""feedscope: journey reconstruction and critical-path attribution.

Turns the tracer's flat span stream (core/obs/trace.py) into *batch
journeys* — one per tracked batch, grouped by span id across the hop
taxonomy ``intake.draw -> wal.append -> coalesce -> apply.<group> ->
sink.append -> store.append -> store.flush`` — and decomposes each
journey's end-to-end visible latency into per-hop **service** time (the
span's own ``dur``) and **queue** time (the gap between one hop's end
and the next hop's start, attributed to the hop that was waited *for*).

Span ids merge at coalesce points (several intake draws become one
apply) and at segment flushes (many store-appends become one flush);
the profiler unions them, so a journey is the connected component of
span ids, found with a tiny union-find.

``JourneyProfiler.report()`` rolls the retained window up into a
``ProfileReport``: per-hop p50/p95 for service and queue, each hop's
**critical-path fraction** (its share of all attributed wall time),
and a ranked bottleneck verdict.  ``FeedHandle.profile()`` feeds it
from ``drain_trace()`` and publishes ``bottleneck_<hop>_frac`` gauges;
the live ops endpoint (core/obs/server.py) serves the JSON form at
``/profile``.

Thread safety: ingest/report/recent_spans serialize on a private lock
(``profiler``) that is never held around any other lock, any blocking
call, or any ``observe``/``emit`` — feedlint sees no new ordering
edges.  Span draining happens *outside* the profiler (the caller hands
in already-drained copies), so the ``trace-rings`` lock never nests
under it either.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any, Deque, Dict, List, Optional, Tuple

from repro.core.obs.metrics import percentile_of

#: canonical hop display order; unknown hops (repair.unit, custom) sort
#: after these, alphabetically
HOP_ORDER: Tuple[str, ...] = ("intake.draw", "wal.append", "coalesce",
                              "apply.", "sink.append", "store.append",
                              "store.flush")


def _hop_rank(name: str) -> Tuple[int, str]:
    for i, prefix in enumerate(HOP_ORDER):
        if name == prefix or (prefix.endswith(".") and
                              name.startswith(prefix)):
            return (i, name)
    return (len(HOP_ORDER), name)


@dataclasses.dataclass(frozen=True)
class ProfileSpec:
    """Profiler policy (``.options(profile=...)``).  ``window`` bounds
    the number of retained journeys (oldest evicted); ``trace_keep``
    bounds the raw spans kept for the ``/trace`` endpoint."""
    window: int = 512
    trace_keep: int = 512

    def __post_init__(self):
        if self.window <= 0:
            raise ValueError("profile window must be > 0")
        if self.trace_keep <= 0:
            raise ValueError("profile trace_keep must be > 0")


@dataclasses.dataclass
class HopStats:
    """One hop's aggregate over the journey window.  ``service_s`` sums
    span durations, ``queue_s`` sums the waits attributed to this hop
    (time between the previous hop's end and this hop's start), and
    ``frac`` is the hop's critical-path fraction: (service + queue) /
    total attributed time across all hops."""
    hop: str
    count: int = 0
    service_s: float = 0.0
    queue_s: float = 0.0
    service_p50: float = 0.0
    service_p95: float = 0.0
    queue_p50: float = 0.0
    queue_p95: float = 0.0
    frac: float = 0.0

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ProfileReport:
    """Rolling critical-path profile over the retained journey window.

    ``bottleneck`` is the verdict: the hop with the largest critical-path
    fraction (``None`` until at least one journey reconstructs);
    ``ranked`` is every hop sorted by fraction, descending.  ``visible``
    percentiles cover journeys anchored at ``intake.draw``; a journey is
    ``complete`` when it runs intake.draw -> ... -> store.flush."""
    journeys: int = 0
    complete: int = 0
    hops: Dict[str, HopStats] = dataclasses.field(default_factory=dict)
    ranked: List[Tuple[str, float]] = dataclasses.field(default_factory=list)
    bottleneck: Optional[str] = None
    visible_p50_s: float = 0.0
    visible_p95_s: float = 0.0

    def to_dict(self) -> Dict[str, Any]:
        return {"journeys": self.journeys,
                "complete": self.complete,
                "bottleneck": self.bottleneck,
                "ranked": [list(r) for r in self.ranked],
                "visible_p50_s": self.visible_p50_s,
                "visible_p95_s": self.visible_p95_s,
                "hops": {h: s.to_dict() for h, s in self.hops.items()}}


class _Journey:
    __slots__ = ("hops", "born")

    def __init__(self, born: int):
        # (t0, dur, name) per observed hop span, unsorted until report
        self.hops: List[Tuple[float, float, str]] = []
        self.born = born


class JourneyProfiler:
    """Reconstructs batch journeys from drained spans and rolls them up
    into ``ProfileReport``s.  Feed it with ``ingest(spans)`` (the spans
    must already be drained — the profiler never touches the tracer),
    then ask for ``report()``."""

    def __init__(self, spec: Optional[ProfileSpec] = None):
        self.spec = spec or ProfileSpec()
        # serializes ingest/report/recent_spans; pure in-memory work
        # only — never held around observe/emit or any other lock
        self._lock = threading.Lock()          # lock-name: profiler
        self._parent: Dict[int, int] = {}      # guarded-by: _lock
        self._journeys: Dict[int, _Journey] = {}   # guarded-by: _lock
        self._born = 0                         # guarded-by: _lock
        self._recent: Deque[Dict[str, Any]] = collections.deque(
            maxlen=self.spec.trace_keep)       # guarded-by: _lock

    # ------------------------------------------------------------ union-find
    def _find(self, x: int) -> int:  # requires-lock: _lock
        parent = self._parent
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:     # path compression
            parent[x], x = root, parent[x]
        return root

    def _union(self, a: int, b: int) -> int:  # requires-lock: _lock
        ra, rb = self._find(a), self._find(b)
        if ra == rb:
            return ra
        # an evicted journey can resurface via a late span: treat its
        # root as empty rather than KeyError-ing the ingest loop
        ja = self._journeys.get(ra)
        jb = self._journeys.get(rb)
        if ja is None and jb is None:
            self._parent[rb] = ra
            return ra
        if ja is None or (jb is not None and jb.born < ja.born):
            ra, rb, ja, jb = rb, ra, jb, ja
        self._parent[rb] = ra
        if jb is not None:
            assert ja is not None    # the swap above guarantees it
            ja.hops.extend(jb.hops)
            del self._journeys[rb]
        return ra

    # --------------------------------------------------------------- ingest
    def ingest(self, spans: List[Dict[str, Any]]) -> int:
        """Fold drained spans into the journey table; returns the number
        of spans that joined a journey (spans with no ids — repair,
        compaction, checkpoint — only land in the ``/trace`` ring)."""
        joined = 0
        with self._lock:
            for span in spans:
                self._recent.append(span)
                ids = span.get("spans") or ()
                if not ids:
                    continue
                root = None
                for sid in ids:
                    if sid not in self._parent:
                        self._parent[sid] = sid
                        self._born += 1
                        self._journeys[sid] = _Journey(self._born)
                    root = (self._find(sid) if root is None
                            else self._union(root, sid))
                j = self._journeys.get(root)
                if j is None:        # root survived eviction in _parent
                    self._born += 1
                    j = self._journeys[root] = _Journey(self._born)
                j.hops.append(
                    (float(span.get("t0", 0.0)),
                     float(span.get("dur", 0.0)),
                     str(span.get("name", "?"))))
                joined += 1
            self._evict_locked()
        return joined

    def _evict_locked(self) -> None:  # requires-lock: _lock
        limit = self.spec.window
        excess = len(self._journeys) - limit
        if excess <= 0:
            return
        for root, _ in sorted(self._journeys.items(),
                              key=lambda kv: kv[1].born)[:excess]:
            del self._journeys[root]
            # leave the union-find entries: a late span for an evicted
            # journey re-creates it rather than corrupting another; the
            # parent table is pruned wholesale when it outgrows the
            # window by a wide margin
        if len(self._parent) > 64 * limit:
            live = set(self._journeys)
            self._parent = {r: r for r in live}

    # --------------------------------------------------------------- report
    def recent_spans(self) -> List[Dict[str, Any]]:
        """The newest raw spans (bounded by ``trace_keep``) — the
        ``/trace`` endpoint's backing store."""
        with self._lock:
            return list(self._recent)

    def report(self) -> ProfileReport:
        """Roll the retained journeys up into a ``ProfileReport``."""
        with self._lock:
            journeys = [list(j.hops) for j in self._journeys.values()]
        service: Dict[str, List[float]] = {}
        queue: Dict[str, List[float]] = {}
        visible: List[float] = []
        complete = 0
        for hops in journeys:
            hops.sort(key=lambda h: h[0])
            names = [h[2] for h in hops]
            if "intake.draw" in names:
                end = max(t0 + dur for t0, dur, _ in hops)
                start = min(t0 for t0, dur, name in hops
                            if name == "intake.draw")
                visible.append(max(0.0, end - start))
                if "store.flush" in names:
                    complete += 1
            prev_end: Optional[float] = None
            for t0, dur, name in hops:
                service.setdefault(name, []).append(dur)
                if prev_end is not None:
                    queue.setdefault(name, []).append(
                        max(0.0, t0 - prev_end))
                prev_end = max(prev_end or t0, t0 + dur)
        report = ProfileReport(journeys=len(journeys), complete=complete)
        total = 0.0
        for name in sorted(set(service) | set(queue), key=_hop_rank):
            sv = service.get(name, [])
            qv = queue.get(name, [])
            hs = HopStats(hop=name, count=len(sv),
                          service_s=sum(sv), queue_s=sum(qv))
            if sv:
                hs.service_p50 = percentile_of(sv, 0.5)
                hs.service_p95 = percentile_of(sv, 0.95)
            if qv:
                hs.queue_p50 = percentile_of(qv, 0.5)
                hs.queue_p95 = percentile_of(qv, 0.95)
            report.hops[name] = hs
            total += hs.service_s + hs.queue_s
        if total > 0.0:
            for hs in report.hops.values():
                hs.frac = (hs.service_s + hs.queue_s) / total
        report.ranked = sorted(
            ((h, s.frac) for h, s in report.hops.items()),
            key=lambda kv: -kv[1])
        if report.ranked:
            report.bottleneck = report.ranked[0][0]
        if visible:
            report.visible_p50_s = percentile_of(visible, 0.5)
            report.visible_p95_s = percentile_of(visible, 0.95)
        return report


__all__ = ["HOP_ORDER", "HopStats", "JourneyProfiler", "ProfileReport",
           "ProfileSpec"]
