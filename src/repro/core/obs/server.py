"""feedscope: zero-dependency live ops endpoint.

A tiny stdlib ``http.server`` surface for poking a running
``FeedManager`` from a browser, ``curl``, or a Prometheus scraper — no
third-party dependency, opt-in via ``FeedManager.serve_obs(port=...)``:

  ``GET /metrics``   Prometheus text exposition, merged across every
                     active feed (plus per-feed ``feed_health`` gauges)
  ``GET /health``    JSON health per feed (core/obs/health.py); status
                     200 when every feed is ok/degraded, 503 when any
                     feed is stalled
  ``GET /profile``   JSON ``ProfileReport`` per profiled feed
                     (core/obs/profile.py)
  ``GET /trace``     the newest raw spans per profiled feed (bounded
                     by ``ProfileSpec.trace_keep``); never drains the
                     tracer — ``/trace`` is a window, not a consumer

Read-path discipline: every handler works from ``snapshot()``s,
``exposition()`` strings, and the profiler's *already-drained* span
copies.  Handlers take no feed, holder, or storage lock — the only
locks touched are the registry's own instrument locks (inside
``exposition``/``merge``) and the profiler/health private locks, each
leaf locks with no ordering edges — so serving traffic cannot contend
with, deadlock against, or reorder the ingest hot path, and feedlint's
LOCK_ORDER needs no new entries (see docs/CONCURRENCY.md).
"""

from __future__ import annotations

import http.server
import json
import threading
import urllib.error
import urllib.request
from typing import Any, Dict, Optional, Tuple

from repro.core.obs.metrics import MetricsRegistry


class _ObsHandler(http.server.BaseHTTPRequestHandler):
    """Request handler; the owning ``ObsServer`` hangs off the server
    object (``self.server.obs``)."""

    server_version = "feedscope/1"

    # silence per-request stderr chatter from BaseHTTPRequestHandler
    def log_message(self, format: str, *args: Any) -> None:
        pass

    def _send(self, code: int, body: str, ctype: str) -> None:
        data = body.encode("utf-8")
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def do_GET(self) -> None:  # noqa: N802 (http.server naming)
        obs: "ObsServer" = self.server.obs  # type: ignore[attr-defined]
        path = self.path.split("?", 1)[0].rstrip("/") or "/"
        try:
            if path == "/metrics":
                self._send(200, obs.render_metrics(),
                           "text/plain; version=0.0.4; charset=utf-8")
            elif path == "/health":
                code, body = obs.render_health()
                self._send(code, body, "application/json")
            elif path == "/profile":
                self._send(200, obs.render_profile(), "application/json")
            elif path == "/trace":
                self._send(200, obs.render_trace(), "application/json")
            elif path == "/":
                self._send(200, json.dumps(
                    {"endpoints": ["/metrics", "/health", "/profile",
                                   "/trace"]}), "application/json")
            else:
                self._send(404, json.dumps({"error": "not found",
                                            "path": path}),
                           "application/json")
        except Exception as exc:  # surface, don't kill the thread
            try:
                self._send(500, json.dumps({"error": repr(exc)}),
                           "application/json")
            except OSError:
                pass  # client went away mid-error


class ObsServer:
    """Background HTTP surface over one ``FeedManager``.  Construction
    binds the socket (``port=0`` picks a free port); ``start()`` spawns
    the daemon serving thread; ``stop()`` shuts it down.  All state the
    handlers read is reached through ``manager.active_feeds()`` — a
    snapshot method, so no manager lock is held while rendering."""

    def __init__(self, manager: Any, host: str = "127.0.0.1",
                 port: int = 0):
        self._manager = manager
        self._httpd = http.server.ThreadingHTTPServer(
            (host, port), _ObsHandler)
        self._httpd.daemon_threads = True
        self._httpd.obs = self  # type: ignore[attr-defined]
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------ lifecycle
    @property
    def address(self) -> Tuple[str, int]:
        """(host, port) actually bound — useful with ``port=0``."""
        host, port = self._httpd.server_address[:2]
        return (str(host), int(port))

    @property
    def url(self) -> str:
        host, port = self.address
        return f"http://{host}:{port}"

    def start(self) -> "ObsServer":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._httpd.serve_forever,
                name="feedscope-obs", daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    # ------------------------------------------------------------- renderers
    def _feeds(self) -> Dict[str, Any]:
        return dict(self._manager.active_feeds())

    def render_metrics(self) -> str:
        """Prometheus text across every active feed.  One feed renders
        its registry directly; several merge into a scratch registry
        (counters add, gauges last-write, histograms bucket-wise).
        ``health()`` runs ``_collect_metrics`` internally, so one call
        per feed refreshes both the published-on-read instruments and
        the ``feed_health`` gauge."""
        feeds = self._feeds()
        for handle in feeds.values():
            health = getattr(handle, "health", None)
            if health is not None:
                health()
            else:
                refresh = getattr(handle, "_collect_metrics", None)
                if refresh is not None:
                    refresh()
        registries = [h.obs.registry for h in feeds.values()
                      if getattr(h, "obs", None) is not None]
        if not registries:
            return "# no active feeds\n"
        if len(registries) == 1:
            return registries[0].exposition()
        scratch = MetricsRegistry()
        for reg in registries:
            scratch.merge(reg)
        return scratch.exposition()

    def render_health(self) -> Tuple[int, str]:
        """(status_code, JSON body): 503 iff any feed is stalled."""
        out: Dict[str, Any] = {}
        worst = 0
        for name, handle in self._feeds().items():
            health = getattr(handle, "health", None)
            if health is None:
                continue
            report = health()
            worst = max(worst, report.code)
            out[name] = report.to_dict()
        body = json.dumps({"feeds": out,
                           "stalled": worst >= 2}, indent=2)
        return (503 if worst >= 2 else 200), body

    def render_profile(self) -> str:
        out: Dict[str, Any] = {}
        for name, handle in self._feeds().items():
            profile = getattr(handle, "profile", None)
            report = profile() if profile is not None else None
            if report is not None:
                out[name] = report.to_dict()
        return json.dumps({"feeds": out}, indent=2)

    def render_trace(self) -> str:
        out: Dict[str, Any] = {}
        for name, handle in self._feeds().items():
            profiler = getattr(handle, "profiler", None)
            if profiler is not None:
                out[name] = profiler.recent_spans()
        return json.dumps({"feeds": out}, indent=2)


def http_get(url: str, timeout: float = 5.0) -> Tuple[int, str]:
    """Tiny stdlib GET helper for tests and benchmarks (no requests
    dependency): returns (status, body)."""
    try:
        with urllib.request.urlopen(url, timeout=timeout) as resp:
            return resp.status, resp.read().decode("utf-8")
    except urllib.error.HTTPError as err:
        return err.code, err.read().decode("utf-8")


__all__ = ["ObsServer", "http_get"]
