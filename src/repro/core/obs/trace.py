"""Batch trace spans: a low-overhead, lock-free-per-thread ring tracer.

Every pipeline hop that touches a tracked batch emits one *span* — a
small dict with a name from the taxonomy in docs/OBSERVABILITY.md
(``intake.draw``, ``wal.append``, ``coalesce``, ``apply.<group>``,
``sink.append``, ``store.append``, ``store.flush``, ``repair.unit``,
``compact.merge``, ``checkpoint``), the frame's span ids, a monotonic
start time, and a duration.  Span ids ride the frame intake→worker→store
on ``TrackedFrame``/``_StoreBatch`` exactly like ``wal_seqs`` do (PR 7),
so one batch's whole journey reconstructs from the drained spans.

Design for the hot path (the bench-smoke overhead gate holds the traced
feed to >= 0.97x untraced throughput):

* each emitting thread appends to its **own** ``collections.deque`` with
  ``maxlen`` — appends never take a lock, and a full ring drops its
  oldest span instead of blocking (deque semantics);
* the only lock (``trace-rings``) guards the ring *registry* and is
  taken once per thread's first emit plus once per ``drain()``;
* span ids come from ``itertools.count`` — ``next()`` is atomic under
  the GIL.

``drain()`` (via ``FeedHandle.drain_trace()``) empties every ring and
returns spans sorted by start time; ``TraceSpec(path=...)`` makes
``join()`` write them as JSON-lines for offline waterfall analysis.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import json
import threading
from typing import Any, Deque, Dict, IO, Iterable, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Per-plan tracing policy (``.options(trace=...)``).

    ``capacity`` bounds each thread's ring (oldest spans drop when the
    consumer falls behind — tracing never applies backpressure);
    ``path`` if set makes ``FeedHandle.join()`` dump the remaining spans
    as JSON-lines there."""
    capacity: int = 4096
    path: Optional[str] = None

    def __post_init__(self):
        if self.capacity <= 0:
            raise ValueError("trace capacity must be > 0")


class Tracer:
    """Per-thread ring-buffer span collector.  ``emit`` is lock-free on
    the hot path; ``drain`` is the single consumer."""

    def __init__(self, capacity: int = 4096):
        if capacity <= 0:
            raise ValueError("trace capacity must be > 0")
        self.capacity = capacity
        # registration-only lock: taken once per thread's first emit and
        # once per drain — never on the per-span hot path
        self._reg_lock = threading.Lock()  # lock-name: trace-rings
        self._rings: List[Deque[Dict[str, Any]]] = []  # guarded-by: _reg_lock
        self._tls = threading.local()
        self._ids = itertools.count(1)

    def new_id(self) -> int:
        """Fresh span id (``next`` on a count is GIL-atomic)."""
        return next(self._ids)

    def emit(self, name: str, spans: Tuple[int, ...] = (), t0: float = 0.0,
             dur: float = 0.0, **extra: Any) -> None:
        ring = getattr(self._tls, "ring", None)
        if ring is None:
            ring = collections.deque(maxlen=self.capacity)
            self._tls.ring = ring
            with self._reg_lock:
                self._rings.append(ring)
        span: Dict[str, Any] = {"name": name, "spans": list(spans),
                                "t0": t0, "dur": dur,
                                "thread": threading.current_thread().name}
        if extra:
            span.update(extra)
        ring.append(span)   # deque(maxlen=...) drops-oldest, never blocks

    def drain(self) -> List[Dict[str, Any]]:
        """Empty every thread's ring; spans come back sorted by start
        time.  Safe against concurrent emitters: ``popleft`` and
        ``append`` on a deque are independently thread-safe, so a race
        only means a just-emitted span waits for the next drain."""
        with self._reg_lock:
            rings = list(self._rings)
        out: List[Dict[str, Any]] = []
        for ring in rings:
            while True:
                try:
                    out.append(ring.popleft())
                except IndexError:
                    break
        out.sort(key=lambda s: s.get("t0", 0.0))
        return out


def write_jsonl(spans: Iterable[Dict[str, Any]], fp: IO[str]) -> int:
    """Serialize spans as JSON-lines; returns the number written."""
    n = 0
    for span in spans:
        fp.write(json.dumps(span, sort_keys=True) + "\n")
        n += 1
    return n


__all__ = ["TraceSpec", "Tracer", "write_jsonl"]
