"""Unified metrics registry: counters, gauges, fixed-bucket histograms.

One ``MetricsRegistry`` per feed (``FeedHandle.obs.registry``) replaces
the scattered ad-hoc stats surfaces as the *storage* for runtime
telemetry: the public stats dataclasses (``FeedStats`` first) keep their
attribute API but read/write *through* the registry, so every number a
benchmark or operator wants is also a live, uniformly-named metric
(``handle.metrics()``) and a Prometheus-style text dump
(``handle.metrics_text()``).

Concurrency contract (docs/CONCURRENCY.md, enforced by feedlint R6):

* ``Counter.inc``/``set`` and ``Gauge.set`` are **lock-free** single
  attribute updates.  They are safe under any core lock (that is what
  makes registry-backed ``FeedStats`` possible — its mutations happen
  under the handle lock exactly as before) and their writers are either
  single-threaded or already externally serialized, the same
  racy-by-design discipline as the holder wait counters.
* ``Histogram.observe`` serializes on a small per-instrument lock
  (global name ``metrics``) because histograms have genuinely concurrent
  writers (worker backlog samples).  Rule R6 therefore requires
  ``observe`` to run with **no core lock held** (``blocking-ok``
  step locks exempt, with declared ``LOCK_ORDER`` edges).
* ``snapshot()``/``exposition()`` read instrument fields lock-free
  (GIL-atomic reference reads; a mid-observe read can skew sum vs count
  by one sample, which is harmless for telemetry) while holding only
  the registry map lock.
"""

from __future__ import annotations

import math
import re
import threading
from typing import Dict, Iterable, List, Mapping, Tuple, Union

#: default bucket bounds for latency histograms (seconds, log-spaced)
SECONDS_BOUNDS: Tuple[float, ...] = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0)

#: default bucket bounds for row-count histograms (powers of two)
ROWS_BOUNDS: Tuple[float, ...] = (
    0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0,
    1024.0, 4096.0, 16384.0, 65536.0, 262144.0)

#: raw-sample ring bound per histogram — exact percentiles over the
#: newest ~4K observations (same halving policy as RepairStats)
MAX_SAMPLES = 4096

_NAME_RE = re.compile(r"[^A-Za-z0-9_]")


def mangle(name: str) -> str:
    """Label-free exposition names: anything outside ``[A-Za-z0-9_]``
    becomes ``_`` (dispatch path keys like ``('segment_sum', 'kernel')``
    publish as ``dispatch_path_segment_sum_kernel``)."""
    return _NAME_RE.sub("_", name)


class Counter:
    """Monotonic-by-convention integer.  Lock-free: writers are single-
    threaded or externally serialized (see module docstring)."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0

    def inc(self, n: int = 1) -> None:
        self._value += int(n)

    def set(self, value: int) -> None:
        """Absolute set — what ``stats.field += n`` under the owner's
        lock compiles to through the registry-backed dataclasses."""
        self._value = int(value)

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    """Last-write-wins float.  Lock-free, same contract as Counter."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0

    def set(self, value: float) -> None:
        self._value = float(value)

    def add(self, delta: float) -> None:
        self._value += float(delta)

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Fixed-bucket histogram + bounded raw-sample ring.

    The buckets feed the Prometheus-style exposition; the ring gives
    exact percentiles over the newest ``MAX_SAMPLES`` observations
    (benchmarks compare these against independently driver-computed
    lags, so approximation error from bucket interpolation is not
    acceptable there).
    """

    __slots__ = ("name", "bounds", "_lock", "_counts", "_overflow",
                 "_sum", "_count", "_samples")

    def __init__(self, name: str, bounds: Tuple[float, ...] = SECONDS_BOUNDS):
        if not bounds or list(bounds) != sorted(bounds):
            raise ValueError(f"histogram bounds must be sorted: {bounds}")
        self.name = name
        self.bounds = tuple(float(b) for b in bounds)
        # one small lock per instrument, never held across blocking work;
        # observe() has concurrent writers (e.g. worker backlog samples)
        self._lock = threading.Lock()      # lock-name: metrics
        self._counts = [0] * len(self.bounds)  # write-guarded-by: _lock
        self._overflow = 0                 # write-guarded-by: _lock
        self._sum = 0.0                    # write-guarded-by: _lock
        self._count = 0                    # write-guarded-by: _lock
        self._samples: List[float] = []    # write-guarded-by: _lock

    def observe(self, value: float) -> None:
        v = float(value)
        with self._lock:
            self._sum += v
            self._count += 1
            for i, b in enumerate(self.bounds):
                if v <= b:
                    self._counts[i] += 1
                    break
            else:
                self._overflow += 1
            self._samples.append(v)
            if len(self._samples) > MAX_SAMPLES:
                # keep the newest half: recent currency matters most
                del self._samples[:len(self._samples) // 2]

    @property
    def count(self) -> int:
        return self._count

    def percentile(self, q: float) -> float:
        """Exact percentile over the retained raw samples (lock-free
        copy of the bounded ring; ``nan`` when never observed — a
        percentile of an empty distribution is undefined, and 0.0 used
        to read as "instant", which is a lie health rules would act
        on)."""
        return percentile_of(tuple(self._samples), q)


class HistogramSnapshot:
    """Immutable point-in-time view of one histogram."""

    __slots__ = ("name", "bounds", "bucket_counts", "overflow", "sum",
                 "count", "samples")

    def __init__(self, name: str, bounds: Tuple[float, ...],
                 bucket_counts: Tuple[int, ...], overflow: int,
                 total: float, count: int, samples: Tuple[float, ...]):
        self.name = name
        self.bounds = bounds
        self.bucket_counts = bucket_counts
        self.overflow = overflow
        self.sum = total
        self.count = count
        self.samples = samples

    def percentile(self, q: float) -> float:
        """Exact percentile over the retained raw samples (``nan`` when
        the histogram has never been observed — undefined, not zero;
        callers wanting a default test ``count`` or ``math.isnan``)."""
        xs = sorted(self.samples)
        if not xs:
            return math.nan
        return float(xs[min(len(xs) - 1, int(q * len(xs)))])

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def cumulative_buckets(self) -> List[Tuple[float, int]]:
        """``(le, cumulative_count)`` per bound, exposition-style."""
        out, acc = [], 0
        for b, c in zip(self.bounds, self.bucket_counts):
            acc += c
            out.append((b, acc))
        return out

    def __repr__(self) -> str:
        return (f"HistogramSnapshot({self.name!r}, count={self.count}, "
                f"sum={self.sum:.6g}, p50={self.percentile(0.5):.6g}, "
                f"p95={self.percentile(0.95):.6g})")


MetricValue = Union[int, float, HistogramSnapshot]


def _fmt(v: float) -> str:
    """Exposition number formatting: integral floats print as ints."""
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return f"{f:.9g}"


class MetricsRegistry:
    """Name -> instrument map.  ``counter``/``gauge``/``histogram`` are
    get-or-create; ``snapshot()`` returns an isolated mapping (ints,
    floats, ``HistogramSnapshot``); ``exposition()`` is the Prometheus
    text format; ``merge()`` folds another registry in (counters add,
    gauges last-write-wins, histograms add bucket-wise)."""

    def __init__(self) -> None:
        # guards only the name->instrument map (instruments synchronize
        # themselves); never held across blocking work
        self._lock = threading.Lock()  # lock-name: metrics-registry
        self._counters: Dict[str, Counter] = {}    # guarded-by: _lock
        self._gauges: Dict[str, Gauge] = {}        # guarded-by: _lock
        self._hists: Dict[str, Histogram] = {}     # guarded-by: _lock

    # ------------------------------------------------------------- factories
    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                self._check_free_locked(name, self._counters)
                c = self._counters[name] = Counter(name)
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                self._check_free_locked(name, self._gauges)
                g = self._gauges[name] = Gauge(name)
            return g

    def histogram(self, name: str,
                  bounds: Tuple[float, ...] = SECONDS_BOUNDS) -> Histogram:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                self._check_free_locked(name, self._hists)
                h = self._hists[name] = Histogram(name, bounds)
            return h

    def _check_free_locked(self, name: str, own: Dict) -> None:  # requires-lock: _lock
        for kind, reg in (("counter", self._counters),
                          ("gauge", self._gauges),
                          ("histogram", self._hists)):
            if reg is not own and name in reg:
                raise ValueError(
                    f"metric {name!r} already registered as a {kind}")

    # --------------------------------------------------------------- reading
    def snapshot(self) -> Dict[str, MetricValue]:
        """Isolated point-in-time view: mutating the registry (or
        observing instruments) after this call never changes a returned
        snapshot."""
        with self._lock:
            counters = list(self._counters.values())
            gauges = list(self._gauges.values())
            hists = list(self._hists.values())
        out: Dict[str, MetricValue] = {}
        for c in counters:
            out[c.name] = c.value
        for g in gauges:
            out[g.name] = g.value
        for h in hists:
            out[h.name] = HistogramSnapshot(
                h.name, h.bounds, tuple(h._counts), h._overflow,
                h._sum, h._count, tuple(h._samples))
        return out

    def exposition(self) -> str:
        """Prometheus text exposition (label-free names; histogram
        buckets use the standard ``_bucket{le=...}`` convention)."""
        snap = self.snapshot()
        lines: List[str] = []
        for name in sorted(snap):
            v = snap[name]
            m = mangle(name)
            if isinstance(v, HistogramSnapshot):
                lines.append(f"# TYPE {m} histogram")
                for le, acc in v.cumulative_buckets():
                    lines.append(f'{m}_bucket{{le="{_fmt(le)}"}} {acc}')
                lines.append(f'{m}_bucket{{le="+Inf"}} {v.count}')
                lines.append(f"{m}_sum {_fmt(v.sum)}")
                lines.append(f"{m}_count {v.count}")
            elif isinstance(v, int):
                lines.append(f"# TYPE {m} counter")
                lines.append(f"{m} {v}")
            else:
                lines.append(f"# TYPE {m} gauge")
                lines.append(f"{m} {_fmt(v)}")
        return "\n".join(lines) + "\n"

    # --------------------------------------------------------------- merging
    def merge(self, other: "MetricsRegistry") -> None:
        """Fold ``other`` into this registry: counters add, gauges take
        the other's value, histograms add bucket-wise and concatenate
        sample rings (bounded).  ``other`` is snapshotted first, so the
        two registry locks are never nested."""
        data = other.snapshot()
        for name, v in data.items():
            if isinstance(v, HistogramSnapshot):
                h = self.histogram(name, v.bounds)
                with h._lock:
                    if h.bounds != v.bounds:
                        raise ValueError(
                            f"histogram {name!r} bucket bounds differ")
                    for i, c in enumerate(v.bucket_counts):
                        h._counts[i] += c
                    h._overflow += v.overflow
                    h._sum += v.sum
                    h._count += v.count
                    h._samples.extend(v.samples)
                    if len(h._samples) > MAX_SAMPLES:
                        del h._samples[:len(h._samples) - MAX_SAMPLES]
            elif isinstance(v, int):
                c2 = self.counter(name)
                c2.inc(v)
            else:
                self.gauge(name).set(v)

    # ------------------------------------------------------------- utilities
    def set_counters(self, values: Mapping[str, int]) -> None:
        for name, v in values.items():
            self.counter(name).set(int(v))

    def set_gauges(self, values: Mapping[str, float]) -> None:
        for name, v in values.items():
            self.gauge(name).set(float(v))

    def names(self) -> List[str]:
        with self._lock:
            return sorted(list(self._counters) + list(self._gauges)
                          + list(self._hists))


def percentile_of(values: Iterable[float], q: float) -> float:
    """Shared sorted-rank percentile (the RepairStats convention).
    Returns ``nan`` for an empty input: a percentile of no samples is
    undefined, and the old 0.0 masked "never observed" as "instant"."""
    xs = sorted(values)
    if not xs:
        return math.nan
    return float(xs[min(len(xs) - 1, int(q * len(xs)))])


__all__ = ["Counter", "Gauge", "Histogram", "HistogramSnapshot",
           "MetricsRegistry", "MetricValue", "SECONDS_BOUNDS",
           "ROWS_BOUNDS", "MAX_SAMPLES", "mangle", "percentile_of"]
