"""Relational enrichment operators, TPU-adapted (pure jnp, jit-able, static
shapes).  These are the building blocks of the paper's UDF workload:

  hash join      -> ``sorted_join``: binary-search probe of the snapshot's
                    sorted key column (no pointer-chase hash table; O(log R)
                    regular accesses, fully vectorized on the VPU)
  group-by       -> ``segment_sum`` / ``segment_count`` (optionally lowered
                    to the one-hot x matmul MXU kernel, kernels/segment_reduce)
  order-by/top-k -> ``segment_topk``: one composite-key sort, no S x R blowup
  spatial join   -> ``radius_count`` / ``radius_topk``: tiled pairwise
                    distances via the MXU identity |a-b|^2 = |a|^2+|b|^2-2ab
                    (kernels/spatial_join is the Pallas version)
  contains()     -> ``contains_any``: hashed-token membership (DESIGN.md §2)

Invalid reference rows are key-sentinel padded, so every operator is correct
on fixed-capacity snapshots regardless of fill level.

Routing: the hot-path operators (``sorted_join``, ``radius_count``,
``radius_topk``, ``segment_sum``, ``segment_count``, ``segment_topk``) are
thin wrappers over the kernel-dispatch layer (dispatch.py), which picks the
Pallas kernel or the ``_*_ref`` jnp bodies kept here.  The ``_*_ref``
functions ARE the former implementations — dispatch falls back to them for
tiny batches, CPU-only runs, or mode="reference".
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.refdata import KEY_SENTINEL

Array = jax.Array

_SPATIAL_CHUNK = 512   # probe-row block for distance tiles (see kernels/)


# ---------------------------------------------------------------------------
# joins
# ---------------------------------------------------------------------------

def sorted_join(probe: Array, ref_keys: Array) -> Tuple[Array, Array]:
    """Equi-join probe: for each probe key, the index of its match in the
    (ascending, sentinel-padded) reference key column and a found flag.
    probe: (B,) int64; ref_keys: (R,) int64 sorted.
    Returns (idx (B,) int32 [-1 when absent], found (B,) bool)."""
    from repro.core.enrich import dispatch
    return dispatch.sorted_join(probe, ref_keys)


def _sorted_join_ref(probe: Array, ref_keys: Array) -> Tuple[Array, Array]:
    idx = jnp.searchsorted(ref_keys, probe)
    idx = jnp.minimum(idx, ref_keys.shape[0] - 1)
    found = (ref_keys[idx] == probe) & (probe != KEY_SENTINEL)
    return jnp.where(found, idx, -1).astype(jnp.int32), found


def gather_col(col: Array, idx: Array, found: Array, fill=0) -> Array:
    """Payload gather for an (idx, found) join result."""
    out = jnp.take(col, idx, axis=0)
    fill_arr = jnp.asarray(fill, out.dtype)
    return jnp.where(
        found.reshape(found.shape + (1,) * (out.ndim - 1)), out, fill_arr)


# ---------------------------------------------------------------------------
# group-by aggregation
# ---------------------------------------------------------------------------

def segment_sum(values: Array, seg: Array, num_segments: int,
                valid: Optional[Array] = None) -> Array:
    from repro.core.enrich import dispatch
    return dispatch.segment_sum(values, seg, num_segments, valid)


def _segment_sum_ref(values: Array, seg: Array, num_segments: int,
                     valid: Optional[Array] = None) -> Array:
    if valid is not None:
        values = jnp.where(valid, values, 0)
    return jax.ops.segment_sum(values, seg, num_segments=num_segments)


def segment_count(seg: Array, num_segments: int,
                  valid: Optional[Array] = None) -> Array:
    from repro.core.enrich import dispatch
    return dispatch.segment_count(seg, num_segments, valid)


def segment_topk(values: Array, seg: Array, payload: Array,
                 num_segments: int, k: int,
                 valid: Optional[Array] = None) -> Tuple[Array, Array]:
    from repro.core.enrich import dispatch
    return dispatch.segment_topk(values, seg, payload, num_segments, k,
                                 valid)


def _segment_topk_ref(values: Array, seg: Array, payload: Array,
                      num_segments: int, k: int,
                      valid: Optional[Array] = None) -> Tuple[Array, Array]:
    """Per-segment top-k by ``values`` (descending), returning the payload.

    One composite-key argsort — O(R log R), never materializes (S, R).
    values: (R,) non-negative int32; seg: (R,) int32; payload: (R,) any.
    Returns (payload (S, k) with -1 fill, values (S, k) with 0 fill)."""
    r = values.shape[0]
    vmax = jnp.int64(1) << 31
    v = jnp.clip(values.astype(jnp.int64), 0, vmax - 1)
    segi = seg.astype(jnp.int64)
    if valid is not None:
        # invalid rows sort to a virtual overflow segment
        segi = jnp.where(valid, segi, num_segments)
    composite = segi * vmax + (vmax - 1 - v)   # asc seg, desc value
    order = jnp.argsort(composite)
    sseg = segi[order]
    sval = values[order]
    spay = payload[order]
    starts = jnp.searchsorted(sseg, jnp.arange(num_segments + 1,
                                               dtype=jnp.int64))
    pos = jnp.arange(r) - starts[jnp.clip(sseg, 0, num_segments)]
    keep = (pos < k) & (sseg < num_segments)
    slot = jnp.where(keep, sseg * k + pos, num_segments * k)
    pay_out = jnp.full((num_segments * k + 1,), -1, payload.dtype)
    val_out = jnp.zeros((num_segments * k + 1,), values.dtype)
    pay_out = pay_out.at[slot].set(jnp.where(keep, spay, -1), mode="drop")
    val_out = val_out.at[slot].set(jnp.where(keep, sval, 0), mode="drop")
    return (pay_out[:-1].reshape(num_segments, k),
            val_out[:-1].reshape(num_segments, k))


# ---------------------------------------------------------------------------
# text membership (the ``contains`` adaptation)
# ---------------------------------------------------------------------------

def contains_any(text_tokens: Array, keywords: Array,
                 kw_valid: Optional[Array] = None) -> Array:
    """(B, T) int64 token hashes vs (K,) keyword hashes -> (B,) bool."""
    eq = text_tokens[:, :, None] == keywords[None, None, :]
    if kw_valid is not None:
        eq &= kw_valid[None, None, :]
    eq &= text_tokens[:, :, None] != 0
    return jnp.any(eq, axis=(1, 2))


# ---------------------------------------------------------------------------
# spatial operators
# ---------------------------------------------------------------------------

def country_keyword_match(text_tokens: Array, country: Array,
                          ref_country: Array, ref_word: Array,
                          ref_valid: Optional[Array] = None,
                          chunk: int = 256) -> Array:
    """SQL++ UDF 2 (tweetSafetyCheck): EXISTS(SELECT s FROM SensitiveWords s
    WHERE t.country = s.country AND contains(t.text, s.word)).
    text_tokens: (B, T); country: (B,); ref_country/ref_word: (R,).
    Returns (B,) bool.  Chunked over probe rows like the spatial tiles."""
    def one(args):
        toks, ctry = args
        cmatch = ctry[:, None] == ref_country[None, :]           # (b, R)
        wmatch = jnp.any(
            (toks[:, :, None] == ref_word[None, None, :])
            & (toks[:, :, None] != 0), axis=1)                   # (b, R)
        hit = cmatch & wmatch
        if ref_valid is not None:
            hit &= ref_valid[None, :]
        return jnp.any(hit, axis=1)

    b = text_tokens.shape[0]
    if b <= chunk:
        return one((text_tokens, country))
    pad = (-b) % chunk
    toks = jnp.pad(text_tokens, ((0, pad), (0, 0)))
    ctry = jnp.pad(country, (0, pad))
    out = jax.lax.map(one, (toks.reshape(-1, chunk, text_tokens.shape[1]),
                            ctry.reshape(-1, chunk)))
    return out.reshape(-1)[:b]


def pairwise_dist2(points: Array, refs: Array) -> Array:
    """Squared euclidean distance matrix via the MXU-friendly identity.
    points: (B, 2); refs: (R, 2) -> (B, R) float32."""
    p = points.astype(jnp.float32)
    r = refs.astype(jnp.float32)
    d2 = (jnp.sum(p * p, axis=1)[:, None]
          + jnp.sum(r * r, axis=1)[None, :]
          - 2.0 * p @ r.T)
    return jnp.maximum(d2, 0.0)


def _chunk_map(fn, points: Array, chunk: int):
    """Apply ``fn`` over probe-row blocks so the (B, R) tile never exceeds
    (chunk, R) — mirrors the Pallas kernel's VMEM blocking."""
    b = points.shape[0]
    if b <= chunk:
        return fn(points)
    pad = (-b) % chunk
    pts = jnp.pad(points, ((0, pad), (0, 0)))
    blocks = pts.reshape(-1, chunk, 2)
    out = jax.lax.map(fn, blocks)
    return jax.tree.map(
        lambda x: x.reshape((-1,) + x.shape[2:])[:b], out)


def radius_count(points: Array, refs: Array, radius: float,
                 ref_valid: Optional[Array] = None,
                 chunk: int = _SPATIAL_CHUNK) -> Array:
    """#reference points within ``radius`` of each probe point. (B,) int32."""
    from repro.core.enrich import dispatch
    return dispatch.radius_count(points, refs, radius, ref_valid,
                                 chunk=chunk)


def _radius_count_ref(points: Array, refs: Array, radius: float,
                      ref_valid: Optional[Array] = None,
                      chunk: int = _SPATIAL_CHUNK) -> Array:
    r2 = jnp.float32(radius) ** 2

    def one(pts):
        d2 = pairwise_dist2(pts, refs)
        hit = d2 <= r2
        if ref_valid is not None:
            hit &= ref_valid[None, :]
        return jnp.sum(hit, axis=1).astype(jnp.int32)

    return _chunk_map(one, points, chunk)


def radius_topk(points: Array, refs: Array, radius: float, k: int,
                ref_valid: Optional[Array] = None,
                chunk: int = _SPATIAL_CHUNK
                ) -> Tuple[Array, Array, Array]:
    """k nearest reference points within ``radius``.
    Returns (idx (B,k) int32 [-1 when absent], dist2 (B,k), count (B,))."""
    from repro.core.enrich import dispatch
    return dispatch.radius_topk(points, refs, radius, k, ref_valid,
                                chunk=chunk)


def _radius_topk_ref(points: Array, refs: Array, radius: float, k: int,
                     ref_valid: Optional[Array] = None,
                     chunk: int = _SPATIAL_CHUNK
                     ) -> Tuple[Array, Array, Array]:
    r2 = jnp.float32(radius) ** 2
    kk = min(k, refs.shape[0])

    def one(pts):
        d2 = pairwise_dist2(pts, refs)
        if ref_valid is not None:
            d2 = jnp.where(ref_valid[None, :], d2, jnp.inf)
        neg, idx = jax.lax.top_k(-d2, kk)
        dd = -neg
        if kk < k:   # tiny reference table: pad result slots
            pad = [(0, 0), (0, k - kk)]
            idx = jnp.pad(idx, pad, constant_values=-1)
            dd = jnp.pad(dd, pad, constant_values=jnp.inf)
        ok = dd <= r2
        count = jnp.sum((d2 <= r2), axis=1).astype(jnp.int32)
        return (jnp.where(ok, idx, -1).astype(jnp.int32),
                jnp.where(ok, dd, jnp.inf), count)

    return _chunk_map(one, points, chunk)


def group_count_within_radius(points: Array, refs: Array, group: Array,
                              num_groups: int, radius: float,
                              ref_valid: Optional[Array] = None,
                              chunk: int = _SPATIAL_CHUNK) -> Array:
    """Per probe point: counts of in-radius reference points per group
    (Q5/Q6's 'facilities by type').  Returns (B, num_groups) int32.
    The hit x one-hot contraction is a dense GEMM — MXU-native."""
    r2 = jnp.float32(radius) ** 2
    onehot = jax.nn.one_hot(group, num_groups, dtype=jnp.float32)
    if ref_valid is not None:
        onehot *= ref_valid[:, None]

    def one(pts):
        d2 = pairwise_dist2(pts, refs)
        hit = (d2 <= r2).astype(jnp.float32)
        if ref_valid is not None:
            hit *= ref_valid[None, :]
        return (hit @ onehot).astype(jnp.int32)

    return _chunk_map(one, points, chunk)


def point_in_rect(points: Array, rects: Array,
                  rect_valid: Optional[Array] = None,
                  chunk: int = 8192) -> Tuple[Array, Array]:
    """First containing rectangle per point (the paper's district lookup).
    points: (B, 2); rects: (R, 4) [xmin, ymin, xmax, ymax].
    Returns (rect_idx (B,) int32 [-1 when none], found (B,) bool).
    Chunked over points: Q6 pushes 1M persons through this."""
    big = jnp.int32(2**31 - 1)

    def one(pts):
        x, y = pts[:, 0:1], pts[:, 1:2]
        inside = ((x >= rects[None, :, 0]) & (y >= rects[None, :, 1])
                  & (x <= rects[None, :, 2]) & (y <= rects[None, :, 3]))
        if rect_valid is not None:
            inside &= rect_valid[None, :]
        # single min-iota reduction instead of any + argmax (§Perf: one
        # pass over the (B, R) tile instead of two)
        iota = jax.lax.broadcasted_iota(jnp.int32, inside.shape, 1)
        idx = jnp.min(jnp.where(inside, iota, big), axis=1)
        found = idx != big
        return jnp.where(found, idx, -1), found

    return _chunk_map(one, points, chunk)


def time_window_count_by_group(t: Array, event_t: Array, event_group: Array,
                               group_of_interest: Array, window: int,
                               event_valid: Optional[Array] = None) -> Array:
    """Q7: for each (probe time t_i, group g_ij): #events with
    t_i - window < event_t < t_i and event_group == g_ij.
    t: (B,); event_*: (A,); group_of_interest: (B, K). Returns (B, K)."""
    in_window = ((event_t[None, :] < t[:, None])
                 & (event_t[None, :] > (t[:, None] - window)))   # (B, A)
    if event_valid is not None:
        in_window &= event_valid[None, :]
    match = (group_of_interest[:, :, None]
             == event_group[None, None, :])                      # (B, K, A)
    return jnp.sum(match & in_window[:, None, :], axis=-1).astype(jnp.int32)
