"""The paper's enrichment-UDF workload (§8 + appendix A-G), as composable
``EnrichUDF``s over the operators in ``ops.py``.

Each UDF splits into:
  * ``state_fn(refs) -> state`` — the *intermediate state* of §5.3 (the hash
    table / aggregate / top-k list a stateful SQL++ UDF builds from its
    reference datasets).  Model 2 re-evaluates this per batch, which is
    exactly how reference-data changes become visible during ingestion;
    Model 3 evaluates it once (fast but stale — "current w/o updates").
  * ``apply_fn(batch, state, refs) -> enriched columns`` — the per-record
    probe side.

Both are pure jnp and AOT-compile ("predeploy") once per (batch shape x
table capacities); reference snapshots are invocation *parameters*.

The seven UDFs and their operator mix match the paper:
  Q1 Safety Level          hash join
  Q2 Religious Population  group-by (sum)
  Q3 Largest Religions     order-by / top-3
  Q4 Nearby Monuments      spatial join (1.5 deg)
  Q5 Suspicious Names      hash join + 2 spatial joins + group-by + order-by
  Q6 Tweet Context         hash join + 5 spatial joins + 2 group-bys
  Q7 Worrisome Tweets      hash join + spatial join + group-by + time window
plus §4's UDF1 (stateless safety check) and UDF2 (SensitiveWords join).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import records
from repro.core.enrich import ops
from repro.core.refdata import KEY_SENTINEL, RefStore

Array = jnp.ndarray

# dictionary domains (DESIGN.md §2: dense-dictionary join for small domains)
COUNTRY_DOMAIN = 50_000     # country_code key space of the reference tables
NUM_RELIGIONS = 64
NUM_FACILITY_TYPES = 16
NUM_ETHNICITIES = 32
NUM_DISTRICTS = 512         # covers the paper's 500 districts
US_CODE = 0
BOMB_HASH = records.hash64("bomb")
TWO_MONTHS = 62 * 24 * 3600

# paper cardinalities (appendix)
PAPER_CARDINALITIES = {
    "safety_levels": 50_000,
    "religious_populations": 50_000,
    "monuments": 50_000,
    "sensitive_words": 10_000,
    "religious_buildings": 10_000,
    "facilities": 50_000,
    "suspicious_names": 1_000_000,
    "district_areas": 500,
    "average_incomes": 500,
    "persons": 1_000_000,
    "attack_events": 5_000,
}


@dataclasses.dataclass(frozen=True)
class EnrichUDF:
    name: str
    ref_tables: Tuple[str, ...]
    state_fn: Optional[Callable]   # refs -> state (None = stateless probe)
    apply_fn: Callable             # (batch, state, refs) -> enriched cols
    operators: str                 # paper's operator mix, for reports
    # non-empty for fused UDFs (built by ``chain``/``then``): the original
    # single-stage UDFs, in application order.  The computing runner uses
    # this to build/refresh intermediate state per stage (Model-2 semantics
    # per stage) and to attribute per-stage ComputingStats, while the apply
    # side stays ONE predeployed executable for the whole chain.
    stages: Tuple["EnrichUDF", ...] = ()
    # (ref table, batch column) pairs declaring that the UDF probes the
    # table's PRIMARY KEYS with that batch column (Q1: safety_levels keys
    # ARE country codes).  Lets the repair scheduler (core/repair.py)
    # refine coarse version-staleness with a dirty-key probe: a stored
    # segment none of whose rows touch an upserted key needs no repair.
    # Tables without a declared pair fall back to coarse version matching.
    repair_keys: Tuple[Tuple[str, str], ...] = ()

    @property
    def stateless(self) -> bool:
        return not self.ref_tables

    def build_state(self, refs: Dict[str, Dict[str, Array]]):
        if self.state_fn is None:
            return ()
        return self.state_fn(refs)

    def __call__(self, batch, state, refs):
        return self.apply_fn(batch, state, refs)

    def then(self, other: "EnrichUDF",
             name: Optional[str] = None) -> "EnrichUDF":
        """Left-to-right composition: ``a.then(b)`` applies ``a`` first and
        ``b`` second (``b`` sees ``a``'s output columns, SQL++ LET-style) —
        fused into ONE predeployed apply per batch with the union of both
        ref tables.  Flattens nested compositions so
        ``q1.then(q2).then(q3)`` is a flat three-stage chain."""
        mine = self.stages or (self,)
        theirs = other.stages or (other,)
        return chain(name or f"{self.name}>{other.name}", *mine, *theirs)


def _valid(table: Dict[str, Array]) -> Array:
    return table["key"] != KEY_SENTINEL


def _latlon(table: Dict[str, Array]) -> Array:
    return jnp.stack([table["lat"], table["lon"]], axis=1)


# ---------------------------------------------------------------------------
# §4 UDF 1 — stateless US safety check
# ---------------------------------------------------------------------------

def _udf1_apply(batch, state, refs):
    has_bomb = jnp.any(batch["text_tokens"] == BOMB_HASH, axis=1)
    red = (batch["country"] == US_CODE) & has_bomb
    return {"safety_check_flag": red.astype(jnp.int32)}   # 1=Red 0=Green


UDF1 = EnrichUDF("udf1_us_safety_check", (), None, _udf1_apply, "stateless")


# ---------------------------------------------------------------------------
# §4 UDF 2 — SensitiveWords join (the paper's running stateful example)
# ---------------------------------------------------------------------------

def _udf2_apply(batch, state, refs):
    sw = refs["sensitive_words"]
    red = ops.country_keyword_match(
        batch["text_tokens"], batch["country"].astype(jnp.int64),
        sw["country"].astype(jnp.int64), sw["word"], _valid(sw))
    return {"safety_check_flag": red.astype(jnp.int32)}


UDF2 = EnrichUDF("udf2_tweet_safety_check", ("sensitive_words",),
                 None, _udf2_apply, "hash join + contains")


# ---------------------------------------------------------------------------
# Q1 — Safety Level (hash join on country)
# ---------------------------------------------------------------------------

def _q1_apply(batch, state, refs):
    t = refs["safety_levels"]
    idx, found = ops.sorted_join(batch["country"].astype(jnp.int64),
                                 t["key"])
    lvl = ops.gather_col(t["safety_level"], idx, found, fill=-1)
    return {"safety_level": lvl}


Q1 = EnrichUDF("q1_safety_level", ("safety_levels",), None, _q1_apply,
               "hash join",
               repair_keys=(("safety_levels", "country"),))


# ---------------------------------------------------------------------------
# Q2 — Religious Population (group-by sum, then probe)
# ---------------------------------------------------------------------------

def _q2_state(refs):
    t = refs["religious_populations"]
    return ops.segment_sum(t["population"].astype(jnp.int64), t["country"],
                           COUNTRY_DOMAIN, _valid(t))


def _q2_apply(batch, state, refs):
    return {"religious_population":
            jnp.take(state, batch["country"], axis=0)}


Q2 = EnrichUDF("q2_religious_population", ("religious_populations",),
               _q2_state, _q2_apply, "group-by")


# ---------------------------------------------------------------------------
# Q3 — Largest Religions (per-country top-3)
# ---------------------------------------------------------------------------

def _q3_state(refs):
    t = refs["religious_populations"]
    top_rel, _ = ops.segment_topk(t["population"], t["country"],
                                  t["religion"], COUNTRY_DOMAIN, 3,
                                  _valid(t))
    return top_rel                                        # (C, 3) int32


def _q3_apply(batch, state, refs):
    return {"largest_religions":
            jnp.take(state, batch["country"], axis=0)}    # (B, 3)


Q3 = EnrichUDF("q3_largest_religions", ("religious_populations",),
               _q3_state, _q3_apply, "order-by/top-k")


# ---------------------------------------------------------------------------
# Q4 — Nearby Monuments (spatial join, radius 1.5 deg, up to 8 returned)
# ---------------------------------------------------------------------------

Q4_RADIUS, Q4_K = 1.5, 8


def _q4_apply(batch, state, refs):
    t = refs["monuments"]
    pts = jnp.stack([batch["lat"], batch["lon"]], axis=1)
    idx, _, count = ops.radius_topk(pts, _latlon(t), Q4_RADIUS, Q4_K,
                                    _valid(t))
    ids = jnp.where(idx >= 0,
                    jnp.take(t["key"], jnp.maximum(idx, 0), axis=0), -1)
    return {"nearby_monuments": ids, "nearby_monument_count": count}


Q4 = EnrichUDF("q4_nearby_monuments", ("monuments",), None, _q4_apply,
               "spatial join")


# ---------------------------------------------------------------------------
# Q5 — Suspicious Names (join + 2 spatial + group-by + order-by)
# ---------------------------------------------------------------------------

Q5_RADIUS, Q5_K = 3.0, 3


def _q5_apply(batch, state, refs):
    fac, rb, sn = (refs["facilities"], refs["religious_buildings"],
                   refs["suspicious_names"])
    pts = jnp.stack([batch["lat"], batch["lon"]], axis=1)
    fac_counts = ops.group_count_within_radius(
        pts, _latlon(fac), fac["ftype"], NUM_FACILITY_TYPES, Q5_RADIUS,
        _valid(fac))
    idx, _, _ = ops.radius_topk(pts, _latlon(rb), Q5_RADIUS, Q5_K,
                                _valid(rb))
    rb_ids = jnp.where(idx >= 0,
                       jnp.take(rb["key"], jnp.maximum(idx, 0), axis=0), -1)
    rb_rel = jnp.where(idx >= 0,
                       jnp.take(rb["religion"], jnp.maximum(idx, 0), axis=0),
                       -1)
    jidx, jfound = ops.sorted_join(batch["user_name_hash"], sn["key"])
    threat = ops.gather_col(sn["threat_level"], jidx, jfound, fill=-1)
    s_rel = ops.gather_col(sn["religion"], jidx, jfound, fill=-1)
    return {"nearby_facility_counts": fac_counts,
            "nearby_religious_buildings": rb_ids,
            "nearby_building_religions": rb_rel,
            "suspect_threat_level": threat,
            "suspect_religion": s_rel}


Q5 = EnrichUDF("q5_suspicious_names",
               ("facilities", "religious_buildings", "suspicious_names"),
               None, _q5_apply,
               "hash join + 2x spatial join + group-by + order-by",
               repair_keys=(("suspicious_names", "user_name_hash"),))


# ---------------------------------------------------------------------------
# Q6 — Tweet Context (the heavy one: ref-ref spatial joins in the state)
# ---------------------------------------------------------------------------

def _q6_state(refs):
    """All tweet-independent work: assign facilities and persons to
    districts (two big spatial joins), aggregate counts — the paper's
    'expensive spatial joins between referenced datasets before enriching'
    (§8.3, Tweet Context).  Model 2 pays this per batch, so larger batches
    amortize it — reproducing Fig 26's Tweet Context curve."""
    fac, dst, per, inc = (refs["facilities"], refs["district_areas"],
                          refs["persons"], refs["average_incomes"])
    rects = jnp.stack([dst["xmin"], dst["ymin"], dst["xmax"], dst["ymax"]],
                      axis=1)
    rvalid = _valid(dst)

    nd = rects.shape[0]          # static snapshot capacity, not NUM_DISTRICTS

    fidx, ffound = ops.point_in_rect(_latlon(fac), rects, rvalid)
    fac_seg = jnp.where(ffound & _valid(fac),
                        fidx * NUM_FACILITY_TYPES + fac["ftype"],
                        nd * NUM_FACILITY_TYPES)
    fac_counts = ops.segment_count(
        fac_seg, nd * NUM_FACILITY_TYPES + 1
    )[:-1].reshape(nd, NUM_FACILITY_TYPES)

    pidx, pfound = ops.point_in_rect(_latlon(per), rects, rvalid)
    eth_seg = jnp.where(pfound & _valid(per),
                        pidx * NUM_ETHNICITIES + per["ethnicity"],
                        nd * NUM_ETHNICITIES)
    eth_counts = ops.segment_count(
        eth_seg, nd * NUM_ETHNICITIES + 1
    )[:-1].reshape(nd, NUM_ETHNICITIES)

    # income by district position (align incomes to the district snapshot)
    iidx, ifound = ops.sorted_join(dst["key"], inc["key"])
    income = ops.gather_col(inc["income"], iidx, ifound, fill=0.0)

    return {"rects": rects, "rvalid": rvalid, "fac_counts": fac_counts,
            "eth_counts": eth_counts, "income": income}


def _q6_apply(batch, state, refs):
    pts = jnp.stack([batch["lat"], batch["lon"]], axis=1)
    didx, dfound = ops.point_in_rect(pts, state["rects"], state["rvalid"])
    safe = jnp.maximum(didx, 0)
    income = jnp.where(dfound, jnp.take(state["income"], safe, axis=0), 0.0)
    fac = jnp.where(dfound[:, None],
                    jnp.take(state["fac_counts"], safe, axis=0), 0)
    eth = jnp.where(dfound[:, None],
                    jnp.take(state["eth_counts"], safe, axis=0), 0)
    return {"district": didx, "area_avg_income": income,
            "area_facility_counts": fac, "area_ethnicity_dist": eth}


Q6 = EnrichUDF("q6_tweet_context",
               ("facilities", "district_areas", "persons",
                "average_incomes"),
               _q6_state, _q6_apply,
               "hash join + 5x spatial join + 2x group-by")


# ---------------------------------------------------------------------------
# Q7 — Worrisome Tweets (spatial + group-by + 2-month time window)
# ---------------------------------------------------------------------------

Q7_RADIUS, Q7_K = 3.0, 3


def _q7_apply(batch, state, refs):
    rb, ev = refs["religious_buildings"], refs["attack_events"]
    pts = jnp.stack([batch["lat"], batch["lon"]], axis=1)
    idx, _, _ = ops.radius_topk(pts, _latlon(rb), Q7_RADIUS, Q7_K,
                                _valid(rb))
    rels = jnp.where(idx >= 0,
                     jnp.take(rb["religion"], jnp.maximum(idx, 0), axis=0),
                     -1)                                   # (B, K)
    counts = ops.time_window_count_by_group(
        batch["created_at"], ev["time"], ev["religion"], rels, TWO_MONTHS,
        _valid(ev))
    counts = jnp.where(rels >= 0, counts, 0)
    return {"nearby_religions": rels, "religion_attack_counts": counts}


Q7 = EnrichUDF("q7_worrisome_tweets",
               ("religious_buildings", "attack_events"), None, _q7_apply,
               "hash join + spatial join + group-by + time window")


# ---------------------------------------------------------------------------
# UDF composition + the LM data-plane UDF
# ---------------------------------------------------------------------------

def chain(name: str, *udfs: EnrichUDF) -> EnrichUDF:
    """Compose UDFs left-to-right into ONE fused UDF: states are built
    independently (per stage, so the runner can refresh/reuse them at stage
    granularity), outputs merged; later UDFs see earlier outputs in the
    batch (SQL++ LET-style).  The fused ``apply_fn`` runs the whole chain in
    a single jit / predeployed executable — one kernel dispatch per batch
    instead of one per stage.  Nested chains flatten."""
    flat: Tuple[EnrichUDF, ...] = tuple(
        s for u in udfs for s in (u.stages or (u,)))
    tables = tuple(dict.fromkeys(t for u in flat for t in u.ref_tables))
    has_state = any(u.state_fn is not None for u in flat)

    def state_fn(refs):
        return tuple(u.state_fn(refs) if u.state_fn is not None else ()
                     for u in flat)

    def apply_fn(batch, state, refs):
        out = {}
        cur = dict(batch)
        for u, s in zip(flat, state):
            res = u.apply_fn(cur, s, refs)
            out.update(res)
            cur.update(res)
        return out

    ops_mix = " | ".join(u.operators for u in flat)
    rkeys = tuple(dict.fromkeys(
        pair for u in flat for pair in u.repair_keys))
    return EnrichUDF(name, tables, state_fn if has_state else None,
                     apply_fn if has_state else
                     (lambda b, s, r: apply_fn(b, ((),) * len(flat), r)),
                     ops_mix, stages=flat, repair_keys=rkeys)


def make_filter(name: str, pred: Callable[[Dict[str, Array]], Array]
                ) -> EnrichUDF:
    """A filter stage as a stateless UDF: rows where ``pred(batch)`` is
    False have their ``valid`` flag cleared, so every downstream sink (the
    storage job, tee'd consumers, the LM data plane) drops them.  Because
    it is an ``EnrichUDF`` it fuses into the chain's single predeployed
    apply — a declarative WHERE pushed into ingestion, not a host-side
    post-pass.  ``pred`` sees enriched columns of earlier stages."""
    def apply_fn(batch, state, refs):
        keep = pred(batch)
        return {"valid": batch["valid"] & keep.astype(bool)}

    return EnrichUDF(name, (), None, apply_fn, "filter")


LM_RESERVED = 16


def make_lm_tokenize(vocab_size: int) -> EnrichUDF:
    """Fold hashed text tokens into LM vocab ids (data/tokenizer.py shares
    this convention); emits (B, T) 'lm_tokens' with 0 = pad."""
    def apply_fn(batch, state, refs):
        toks = batch["text_tokens"]
        ids = toks % (vocab_size - LM_RESERVED) + LM_RESERVED
        ids = jnp.where(toks == 0, 0, ids)
        return {"lm_tokens": ids.astype(jnp.int32)}

    return EnrichUDF(f"lm_tokenize_{vocab_size}", (), None, apply_fn,
                     "stateless tokenize")


ALL_UDFS: Dict[str, EnrichUDF] = {
    u.name: u for u in (UDF1, UDF2, Q1, Q2, Q3, Q4, Q5, Q6, Q7)}
SHORT_NAMES = {"udf1": UDF1, "udf2": UDF2, "q1": Q1, "q2": Q2, "q3": Q3,
               "q4": Q4, "q5": Q5, "q6": Q6, "q7": Q7}


def get_udf(name: str) -> EnrichUDF:
    if name in SHORT_NAMES:
        return SHORT_NAMES[name]
    return ALL_UDFS[name]


# ---------------------------------------------------------------------------
# synthetic reference datasets at paper cardinalities (scalable)
# ---------------------------------------------------------------------------

def make_reference_tables(store: RefStore, scale: float = 1.0,
                          seed: int = 7,
                          scale_overrides: Optional[Dict[str, float]] = None,
                          headroom: int = 1024) -> None:
    """Create + populate every reference table the UDF workload needs.
    ``scale`` multiplies the paper cardinality (scale_overrides per table —
    §8.3 scales only the three simple-UDF tables by 100x).  ``headroom``
    leaves spare capacity for mid-ingestion UPSERTs."""
    rng = np.random.default_rng(seed)
    n = {}
    for name, card in PAPER_CARDINALITIES.items():
        s = (scale_overrides or {}).get(name, scale)
        n[name] = max(4, int(card * s))

    t = store.create("safety_levels", n["safety_levels"] + headroom,
                     {"safety_level": np.int32})
    keys = np.arange(n["safety_levels"], dtype=np.int64)
    t.upsert(keys, safety_level=rng.integers(
        0, 5, n["safety_levels"]).astype(np.int32))

    t = store.create("religious_populations",
                     n["religious_populations"] + headroom,
                     {"country": np.int32, "religion": np.int32,
                      "population": np.int32})
    m = n["religious_populations"]
    t.upsert(np.arange(m, dtype=np.int64),
             country=rng.integers(0, records.NUM_COUNTRIES, m
                                  ).astype(np.int32),
             religion=rng.integers(0, NUM_RELIGIONS, m).astype(np.int32),
             population=rng.integers(1_000, 10_000_000, m).astype(np.int32))

    t = store.create("monuments", n["monuments"] + headroom,
                     {"lat": np.float32, "lon": np.float32})
    m = n["monuments"]
    t.upsert(np.arange(m, dtype=np.int64),
             lat=rng.uniform(-60, 60, m).astype(np.float32),
             lon=rng.uniform(-180, 180, m).astype(np.float32))

    t = store.create("sensitive_words", n["sensitive_words"] + headroom,
                     {"country": np.int32, "word": np.int64})
    m = n["sensitive_words"]
    words = [records.hash64(w) for w in
             rng.choice(records._WORDS, m)]
    t.upsert(np.arange(m, dtype=np.int64),
             country=rng.integers(0, records.NUM_COUNTRIES, m
                                  ).astype(np.int32),
             word=np.asarray(words, np.int64))

    t = store.create("religious_buildings",
                     n["religious_buildings"] + headroom,
                     {"lat": np.float32, "lon": np.float32,
                      "religion": np.int32})
    m = n["religious_buildings"]
    t.upsert(np.arange(m, dtype=np.int64),
             lat=rng.uniform(-60, 60, m).astype(np.float32),
             lon=rng.uniform(-180, 180, m).astype(np.float32),
             religion=rng.integers(0, NUM_RELIGIONS, m).astype(np.int32))

    t = store.create("facilities", n["facilities"] + headroom,
                     {"lat": np.float32, "lon": np.float32,
                      "ftype": np.int32})
    m = n["facilities"]
    t.upsert(np.arange(m, dtype=np.int64),
             lat=rng.uniform(-60, 60, m).astype(np.float32),
             lon=rng.uniform(-180, 180, m).astype(np.float32),
             ftype=rng.integers(0, NUM_FACILITY_TYPES, m).astype(np.int32))

    t = store.create("suspicious_names", n["suspicious_names"] + headroom,
                     {"religion": np.int32, "threat_level": np.int32})
    m = n["suspicious_names"]
    name_keys = np.asarray(
        [records.hash64(f"user{i}") for i in
         rng.choice(1_000_000, m, replace=False)], np.int64)
    t.upsert(name_keys,
             religion=rng.integers(0, NUM_RELIGIONS, m).astype(np.int32),
             threat_level=rng.integers(1, 11, m).astype(np.int32))

    t = store.create("district_areas", n["district_areas"] + headroom,
                     {"xmin": np.float32, "ymin": np.float32,
                      "xmax": np.float32, "ymax": np.float32})
    m = n["district_areas"]
    cx = rng.uniform(-58, 58, m).astype(np.float32)
    cy = rng.uniform(-170, 170, m).astype(np.float32)
    w = rng.uniform(1.0, 8.0, m).astype(np.float32)
    h = rng.uniform(1.0, 8.0, m).astype(np.float32)
    t.upsert(np.arange(m, dtype=np.int64),
             xmin=cx - w, ymin=cy - h, xmax=cx + w, ymax=cy + h)

    t = store.create("average_incomes", n["average_incomes"] + headroom,
                     {"income": np.float32})
    m = n["average_incomes"]
    t.upsert(np.arange(m, dtype=np.int64),
             income=rng.uniform(20_000, 120_000, m).astype(np.float32))

    t = store.create("persons", n["persons"] + headroom,
                     {"lat": np.float32, "lon": np.float32,
                      "ethnicity": np.int32})
    m = n["persons"]
    t.upsert(np.arange(m, dtype=np.int64),
             lat=rng.uniform(-60, 60, m).astype(np.float32),
             lon=rng.uniform(-180, 180, m).astype(np.float32),
             ethnicity=rng.integers(0, NUM_ETHNICITIES, m).astype(np.int32))

    t = store.create("attack_events", n["attack_events"] + headroom,
                     {"time": np.int64, "religion": np.int32})
    m = n["attack_events"]
    t.upsert(np.arange(m, dtype=np.int64),
             time=rng.integers(1_500_000_000, 1_600_000_000, m
                               ).astype(np.int64),
             religion=rng.integers(0, NUM_RELIGIONS, m).astype(np.int32))
