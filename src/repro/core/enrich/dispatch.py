"""Enrichment kernel-dispatch layer: route the relational operators through
the Pallas kernels with shape-bucketed jit caching.

The paper's thesis (An IDEA §6-8) only pays off if the enrichment operators
themselves are fast at scale; the stream-enrichment survey finds operator
*dispatch* cost dominates once ingestion is decoupled.  This module is that
dispatch layer:

  * **Routing** — each operator picks the Pallas kernel or the pure-jnp
    reference path per call.  Policy (repro.kernels.get_dispatch_mode):
    "pallas" forces the kernel (interpret-mode emulation off-TPU — slow,
    for equivalence tests and --dispatch pallas benchmarks), "reference"
    forces the jnp path, and "auto" uses the kernel only on TPU and only
    above ``min_pallas_rows`` (tiny batches are dominated by launch
    overhead, not compute — the reference path wins there).

  * **Shape-bucketed jit caching** — probe batches arrive at every size
    (partial frames, coalesced micro-batches, drain-protocol tails).  A
    fresh XLA compile per size would re-introduce exactly the per-statement
    compile cost the paper's predeployed jobs eliminate (§5.2.1), so probe
    dimensions are padded up to power-of-two buckets (floor
    ``bucket_min``): at most log2(max_batch) compiled variants per
    operator, ever.  Padding rows are key-sentinel / dropped-segment rows,
    inert by the same convention that already pads reference snapshots.

Row counts, padding and routing are all static at trace time, so these
functions are safe both eagerly and inside predeployed (AOT-compiled) UDFs.
The reference-table operand is NOT bucketed here: snapshots are already
shape-stable (fixed capacity, trim-quantized in computing.py) and the
kernels pad the reference block internally.

``segment_topk`` routes to the tournament-selection kernel
(kernels/segment_topk) inside its segment-count/k envelope — the
query subsystem's group-by top-k lives there — and to the composite-key
XLA sort outside it (Q3's 50K-segment state build).

Fused UDF chains (core/plan.py) trace every stage's operators into ONE
predeployed executable, so a chained Q1->Q2->Q3 plan pays one dispatch per
batch total, not one per stage; routing/bucketing decisions here happen at
trace time and are baked into that single executable.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.refdata import KEY_SENTINEL
from repro.kernels import (dispatch_mode,  # noqa: F401  (re-export: scoped
                           # mode override — plan tests force "reference"
                           # to compare fused vs sequential bit-for-bit)
                           get_dispatch_mode, resolve_use_pallas)
from repro.kernels.hash_probe import ops as hp_ops
from repro.kernels.segment_reduce import ops as sr_ops
from repro.kernels.segment_topk import ops as st_ops
from repro.kernels.spatial_join import ops as sj_ops

Array = jax.Array


@dataclasses.dataclass
class DispatchConfig:
    min_pallas_rows: int = 1024   # "auto": below this the jnp path wins
    bucket_min: int = 512         # smallest probe bucket
    bucket_max: int = 1 << 22     # cap: beyond this, chunk upstream
    # segment_topk kernel envelope: its (k_pad, S_pad) winner tables and
    # the (block, S_pad) one-hot tile live in VMEM, and its work is
    # O(k*R) vs the reference sort's O(R log R) — route to the kernel
    # only inside these bounds (Q3's 50K-country top-3 stays on the
    # reference sort; query-layer group-bys land inside)
    topk_max_segments: int = 2048
    topk_max_k: int = 16


_config = DispatchConfig()
_stats_lock = threading.Lock()              # lock-name: dispatch-stats
_bucket_hits: Dict[Tuple[str, int], int] = {}   # guarded-by: _stats_lock
# (op, path) execution-path counters for the segment_* aggregation ops:
# "kernel" = Pallas kernel, "xla_64bit" = the EXPLICIT wide-dtype XLA
# fallback (the MXU kernel accumulates in 32 bits; a hi/lo split
# accumulator is TPU-future work — see ROADMAP), "reference" = jnp path
# via mode/size/envelope routing.  Callers that need a per-query view
# (QueryStats' kernel-vs-fallback report) use the thread-local tape.
_path_hits: Dict[Tuple[str, str], int] = {}     # guarded-by: _stats_lock
_tls = threading.local()                    # per-thread path tape


def configure(min_pallas_rows: Optional[int] = None,
              bucket_min: Optional[int] = None,
              bucket_max: Optional[int] = None,
              topk_max_segments: Optional[int] = None,
              topk_max_k: Optional[int] = None) -> DispatchConfig:
    if min_pallas_rows is not None:
        _config.min_pallas_rows = min_pallas_rows
    if bucket_min is not None:
        _config.bucket_min = bucket_min
    if bucket_max is not None:
        _config.bucket_max = bucket_max
    if topk_max_segments is not None:
        _config.topk_max_segments = topk_max_segments
    if topk_max_k is not None:
        _config.topk_max_k = topk_max_k
    return _config


def bucket_rows(n: int, minimum: Optional[int] = None) -> int:
    """Smallest power-of-two bucket >= n (floor ``minimum``).  This is the
    whole recompile-avoidance scheme: every operator pads its probe batch to
    a bucket, so the predeploy/jit caches see O(log max_batch) shapes."""
    lo = max(int(minimum) if minimum is not None else _config.bucket_min, 1)
    b = lo
    while b < n:
        b <<= 1
    return min(max(b, n), max(_config.bucket_max, n))


def bucket_stats() -> Dict[Tuple[str, int], int]:
    """(op, bucket) -> dispatch count; tests use this to pin down that
    nearby batch sizes share a compiled shape."""
    with _stats_lock:
        return dict(_bucket_hits)


def reset_bucket_stats() -> None:
    with _stats_lock:
        _bucket_hits.clear()


def _note(op: str, bucket: int) -> None:
    with _stats_lock:
        _bucket_hits[(op, bucket)] = _bucket_hits.get((op, bucket), 0) + 1


def path_stats() -> Dict[Tuple[str, str], int]:
    """(op, path) -> dispatch count for the segment_* aggregation ops;
    path is "kernel", "xla_64bit" (wide-dtype fallback, explicit by
    design), or "reference" (mode/size/envelope routing)."""
    with _stats_lock:
        return dict(_path_hits)


def reset_path_stats() -> None:
    with _stats_lock:
        _path_hits.clear()


def path_tape_start() -> None:
    """Start recording this thread's segment_* dispatch paths (the query
    layer wraps one execute() in a tape to report kernel-vs-fallback
    counts without cross-thread noise from concurrent feeds)."""
    _tls.paths = {}


def path_tape_stop() -> Dict[Tuple[str, str], int]:
    """Stop this thread's tape and return its (op, path) counts."""
    d = getattr(_tls, "paths", None) or {}
    _tls.paths = None
    return d


def _note_path(op: str, path: str) -> None:
    with _stats_lock:
        _path_hits[(op, path)] = _path_hits.get((op, path), 0) + 1
    d = getattr(_tls, "paths", None)
    if d is not None:
        d[(op, path)] = d.get((op, path), 0) + 1


def _use_pallas(rows: int) -> bool:
    # the row threshold applies only in "auto"; mode semantics stay in
    # one place (repro.kernels.resolve_use_pallas)
    if get_dispatch_mode() == "auto" and rows < _config.min_pallas_rows:
        return False
    return resolve_use_pallas(None)


# ---------------------------------------------------------------------------
# hash join probe
# ---------------------------------------------------------------------------

def sorted_join(probe: Array, ref_keys: Array) -> Tuple[Array, Array]:
    """Equi-join probe against a sorted sentinel-padded key column.
    Returns (idx (B,) int32 [-1 when absent], found (B,) bool) — the
    kernels/hash_probe/ref.py convention on both paths."""
    b = probe.shape[0]
    if not _use_pallas(b):
        from repro.core.enrich import ops
        return ops._sorted_join_ref(probe, ref_keys)
    bk = bucket_rows(b)
    _note("sorted_join", bk)
    probe_p = jnp.pad(probe, (0, bk - b), constant_values=KEY_SENTINEL)
    idx, found = hp_ops.sorted_probe(probe_p, ref_keys, use_pallas=True)
    return idx[:b], found[:b]


# ---------------------------------------------------------------------------
# spatial radius join
# ---------------------------------------------------------------------------

def _pad_points(points: Array, bk: int) -> Tuple[Array, Array]:
    b = points.shape[0]
    p = jnp.pad(points.astype(jnp.float32), ((0, bk - b), (0, 0)))
    return p[:, 0], p[:, 1]


def radius_topk(points: Array, refs: Array, radius: float, k: int,
                ref_valid: Optional[Array] = None,
                chunk: Optional[int] = None
                ) -> Tuple[Array, Array, Array]:
    """k nearest reference points within ``radius`` per probe point.
    Returns (idx (B,k) int32 [-1], dist2 (B,k) [inf], count (B,)).
    ``chunk`` only shapes the reference path's probe-row blocking (the
    kernel blocks in VMEM-sized tiles on its own)."""
    b = points.shape[0]
    if not _use_pallas(b):
        from repro.core.enrich import ops
        kw = {} if chunk is None else {"chunk": chunk}
        return ops._radius_topk_ref(points, refs, radius, k, ref_valid,
                                    **kw)
    bk = bucket_rows(b)
    _note("radius_topk", bk)
    px, py = _pad_points(points, bk)
    idx, d2, count = sj_ops.radius_join(px, py, refs[:, 0], refs[:, 1],
                                        radius, k, ref_valid,
                                        use_pallas=True)
    return idx[:b], d2[:b], count[:b]


def radius_count(points: Array, refs: Array, radius: float,
                 ref_valid: Optional[Array] = None,
                 chunk: Optional[int] = None) -> Array:
    """#reference points within ``radius`` of each probe point, (B,) int32.
    Kernel path: the radius join's count output with a minimal top-k."""
    b = points.shape[0]
    if not _use_pallas(b):
        from repro.core.enrich import ops
        kw = {} if chunk is None else {"chunk": chunk}
        return ops._radius_count_ref(points, refs, radius, ref_valid, **kw)
    bk = bucket_rows(b)
    _note("radius_count", bk)
    px, py = _pad_points(points, bk)
    _, _, count = sj_ops.radius_join(px, py, refs[:, 0], refs[:, 1],
                                     radius, 1, ref_valid, use_pallas=True)
    return count[:b]


# ---------------------------------------------------------------------------
# group-by aggregation
# ---------------------------------------------------------------------------

def _segment_64bit(values: Array) -> bool:
    # the MXU/VPU kernel accumulates in 32 bits; 64-bit inputs must take
    # the XLA path or high bits are silently lost
    return jnp.dtype(values.dtype).itemsize > 4


def segment_sum(values: Array, seg: Array, num_segments: int,
                valid: Optional[Array] = None) -> Array:
    r = values.shape[0]
    if _segment_64bit(values):
        # explicit, not silent: wide dtypes CANNOT ride the MXU kernel
        # (32-bit accumulator) in any mode — recorded as its own path so
        # QueryStats can report which dispatches fell back and why
        _note_path("segment_sum", "xla_64bit")
        from repro.core.enrich import ops
        return ops._segment_sum_ref(values, seg, num_segments, valid)
    if not _use_pallas(r):
        _note_path("segment_sum", "reference")
        from repro.core.enrich import ops
        return ops._segment_sum_ref(values, seg, num_segments, valid)
    _note_path("segment_sum", "kernel")
    rk = bucket_rows(r)
    _note("segment_sum", rk)
    seg = seg.astype(jnp.int32)
    if valid is not None:
        # invalid rows route to the dropped overflow segment
        seg = jnp.where(valid, seg, num_segments)
    values = jnp.pad(values, (0, rk - r))
    seg = jnp.pad(seg, (0, rk - r), constant_values=num_segments)
    return sr_ops.segment_sum(values, seg, num_segments, use_pallas=True)


def segment_count(seg: Array, num_segments: int,
                  valid: Optional[Array] = None) -> Array:
    ones = jnp.ones(seg.shape, jnp.int32)
    return segment_sum(ones, seg, num_segments, valid)


def segment_topk(values: Array, seg: Array, payload: Array,
                 num_segments: int, k: int,
                 valid: Optional[Array] = None) -> Tuple[Array, Array]:
    """Per-segment top-k by ``values`` desc (ties: row asc), returning
    ((S, k) payload -1-filled, (S, k) values 0-filled).  Kernel path: the
    tournament-selection kernel (kernels/segment_topk) picks winner ROW
    indices; payload/value gathers happen out here so any payload dtype
    rides along.  Falls back to the composite-key-sort reference outside
    the kernel's segment/k envelope or for 64-bit values (the winner
    table ranks in int32)."""
    r = values.shape[0]
    from repro.core.enrich import ops
    if (r == 0 or not _use_pallas(r) or num_segments < 1
            or num_segments > _config.topk_max_segments
            or k > _config.topk_max_k
            # the winner table ranks in int32: anything that does not
            # embed losslessly (64-bit, unsigned >= 2^31, floats) takes
            # the composite-sort reference
            or not jnp.issubdtype(values.dtype, jnp.signedinteger)
            or jnp.dtype(values.dtype).itemsize > 4):
        _note_path("segment_topk",
                   "xla_64bit" if jnp.dtype(values.dtype).itemsize > 4
                   else "reference")
        return ops._segment_topk_ref(values, seg, payload, num_segments,
                                     k, valid)
    _note_path("segment_topk", "kernel")
    rk = bucket_rows(r)
    _note("segment_topk", rk)
    segi = seg.astype(jnp.int32)
    if valid is not None:
        # invalid rows route to the dropped overflow segment
        segi = jnp.where(valid, segi, num_segments)
    vals_p = jnp.pad(values.astype(jnp.int32), (0, rk - r))
    seg_p = jnp.pad(segi, (0, rk - r), constant_values=num_segments)
    idx = st_ops.segment_topk_idx(vals_p, seg_p, num_segments, k,
                                  use_pallas=True)        # (S, k) rows
    found = idx >= 0
    safe = jnp.maximum(idx, 0)
    pay = jnp.where(found, jnp.take(payload, safe, axis=0),
                    jnp.asarray(-1, payload.dtype))
    val = jnp.where(found, jnp.take(values, safe, axis=0),
                    jnp.asarray(0, values.dtype))
    return pay, val


# ---------------------------------------------------------------------------
# batched-aggregation planner
# ---------------------------------------------------------------------------

def concat_rows(parts: Sequence[Dict[str, np.ndarray]]
                ) -> Tuple[Dict[str, np.ndarray], int]:
    """Concat-and-pad planner for the per-query batched aggregation path
    (core/query.py): the per-unit masked column slices of one query are
    concatenated IN SCAN ORDER into a single contiguous batch per column,
    so the whole query pays one ``segment_*`` dispatch per aggregate
    instead of one per surviving unit.  Returns ``(cols, n)`` with ``n``
    real rows; the caller pads row dimensions to ``bucket_rows(n)`` when
    it builds the segment-id vector (padding rows must route to the
    dropped overflow segment, which only the caller can number).  Scan
    order is preserved because downstream top-k tie-breaking is
    value-desc-then-scan-order — identical to the eager per-unit path and
    the naive reference.  The hit is recorded against the row bucket the
    dispatches will use, so ``bucket_stats()`` shows batched queries
    riding the same bounded jit-cache shape ladder as the write side."""
    parts = [p for p in parts if p and next(iter(p.values())).shape[0]]
    if not parts:
        return {}, 0
    if len(parts) == 1:
        cols = {k: np.asarray(v) for k, v in parts[0].items()}
    else:
        cols = {k: np.concatenate([np.asarray(p[k]) for p in parts])
                for k in parts[0]}
    n = int(next(iter(cols.values())).shape[0])
    _note("concat_rows", bucket_rows(n))
    return cols, n
