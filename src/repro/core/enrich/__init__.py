from repro.core.enrich.ops import (  # noqa: F401
    contains_any,
    pairwise_dist2,
    point_in_rect,
    radius_count,
    radius_topk,
    segment_count,
    segment_sum,
    segment_topk,
    sorted_join,
)
from repro.core.enrich.queries import (  # noqa: F401
    ALL_UDFS,
    EnrichUDF,
    get_udf,
    make_reference_tables,
)
