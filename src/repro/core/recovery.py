"""Crash-restart orchestration for durable feeds (core/durability.py).

``FeedManager.resume(plan, durable_dir)`` lands here.  The restart
sequence composes exactly-once out of three at-least-once pieces:

  1. **Recover the store** — every ``StoragePartition`` rebuilds from
     its fsynced manifest (``storage.recover()``): row counts, the pk
     index, per-unit lineage, zone maps, layout epoch.  Unflushed
     chunks are gone by definition; the checkpoint protocol flushed
     storage *before* recording a watermark, so nothing counted in the
     watermark can be missing.
  2. **Replay the intake log's tail** — every WAL record with
     seq > checkpoint watermark is re-pushed through the normal
     pipeline (parse -> enrich -> store) as a pre-stamped
     ``TrackedFrame``.  Some of those rows were already stored by the
     crashed run; the store's conditional pk-index insert (the same
     machinery repair rides) skips them, so the replay is idempotent.
  3. **Fast-forward the adapter** — ``adapter.resume(offset)`` with
     the last durable record's post-frame offset; frames the crashed
     run obtained but never durably logged are re-obtained from the
     source.  This is why only resumable adapters compile with
     ``durable=`` (core/plan.py).

Soft state rides the checkpoint and is restored *only when provably
valid*: repair's ref-event journal is trusted iff the checkpointed
reference-table fingerprints match the restarted process's tables (and
versions have not regressed) — otherwise the store's lineage is reset
so every unit is always-stale and repair re-scans from scratch (never
silently-current rows).  Per-group partition counts resume the feed at
the learned elastic scale.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

from repro.core.durability import (CheckpointStore, DurabilityRuntime,
                                   FrameLedger, IntakeLog, LogRecord,
                                   ref_fingerprint)
from repro.core.intake import Adapter, TrackedFrame
from repro.core.plan import IngestPlan, Pipeline, PlanError


@dataclasses.dataclass
class RecoveryState:
    """Everything ``FeedManager._start_new`` needs to wire a resumed
    feed: the recovered durability runtime (open WAL + primed ledger),
    the replay-prefixed adapter, the learned per-group partition
    counts, the restored repair event journal (None when untrusted),
    and whether stored lineage must be reset."""
    runtime: DurabilityRuntime
    adapter: Adapter
    partitions: Dict[str, int]
    repair_events: Optional[Dict[str, List]]
    reset_lineage: bool


class _ResumeAdapter(Adapter):
    """Replay-then-live adapter: yields the WAL tail's records as
    pre-stamped ``TrackedFrame``s (the intake job logs only plain
    frames, so a replay is never re-appended), then hands over to the
    fast-forwarded inner adapter.  ``offset`` mirrors the inner
    adapter's during the live phase so new WAL records carry correct
    resume positions."""

    resumable = True

    def __init__(self, inner: Adapter, records: List[LogRecord],
                 start_offset: int):
        super().__init__()
        self.inner = inner
        self.records = records
        self.offset = int(start_offset)

    def stop(self) -> None:
        super().stop()
        self.inner.stop()

    def frames(self) -> Iterator[List[bytes]]:
        for rec in self.records:
            if self._stop.is_set():
                return
            yield TrackedFrame(rec.lines, (rec.seq,))
        for frame in self.inner.frames():
            self.offset = self.inner.offset
            yield frame


def _override_dir(plan: IngestPlan, durable_dir: str) -> IngestPlan:
    """Re-point the plan's DurableSpec (and a spill_dir that was
    derived from it) at ``durable_dir`` — resuming a directory the
    plan object did not originally name."""
    spec = plan.store_spec
    assert spec is not None and spec.durable is not None
    new_d = dataclasses.replace(spec.durable, dir=durable_dir)
    spill = spec.spill_dir
    if spill == spec.durable.store_dir:
        spill = new_d.store_dir
    new_spec = dataclasses.replace(spec, durable=new_d, spill_dir=spill)
    sinks = tuple(dataclasses.replace(s, store=new_spec) if s.is_store
                  else s for s in plan.sinks)
    return dataclasses.replace(plan, sinks=sinks)


def resume_feed(manager, plan,
                durable_dir: Optional[str] = None):
    """Recover a crashed durable feed and return its live FeedHandle
    (``FeedManager.resume`` delegates here)."""
    if isinstance(plan, Pipeline):
        plan = plan.compile(manager.refstore)
    if not isinstance(plan, IngestPlan):
        raise TypeError("resume() takes an IngestPlan or Pipeline, "
                        f"got {type(plan).__name__}")
    store_spec = plan.store_spec
    if store_spec is None or store_spec.durable is None:
        raise PlanError(
            "resume() needs a durable plan: declare "
            ".store(durable=DurableSpec(dir=...)) so there is an intake "
            "log and checkpoint to recover from")
    if durable_dir is not None:
        plan = _override_dir(plan, durable_dir)
        store_spec = plan.store_spec
    dspec = store_spec.durable

    ck = CheckpointStore(dspec.dir).load() or {}
    watermark = int(ck.get("watermark", 0))
    # open the WAL: the constructor scans every segment, truncates the
    # active segment's torn tail, and leaves the writer positioned to
    # continue the valid prefix
    wal = IntakeLog(dspec.wal_dir, dspec.fsync, dspec.fsync_interval_s,
                    dspec.segment_bytes)
    tail_seq, tail_off = wal.tail()
    tail_seq = max(tail_seq, int(ck.get("last_seq", 0)))
    if tail_off is None:
        # log holds no records (fresh dir, or fully truncated by the
        # final checkpoint of a clean shutdown): the checkpoint's
        # offset is the resume point
        tail_off = int(ck.get("last_offset", 0))
    # materialize the replay BEFORE the feed starts: the intake thread
    # appends new records to the same files a lazy reader would walk
    records = list(wal.replay(watermark))

    ledger = FrameLedger(watermark=watermark, tail_seq=tail_seq,
                         tail_offset=tail_off)
    runtime = DurabilityRuntime(dspec, wal, ledger, recovered=True)
    runtime.replayed_frames = len(records)
    runtime.replayed_records = sum(len(r.lines) for r in records)
    runtime.replay_target_seq = tail_seq

    plan.adapter.resume(tail_off)
    adapter = _ResumeAdapter(plan.adapter, records, tail_off)

    repair_events: Optional[Dict[str, List]] = None
    reset_lineage = False
    if store_spec.refresh is not None and plan.udf is not None:
        trusted = _lineage_trusted(manager.refstore,
                                   plan.udf.ref_tables, ck)
        if trusted:
            repair_events = ck.get("repair_events")
        else:
            # the reference tables this process rebuilt are not the
            # ones the stored lineage was checkpointed against (or no
            # checkpoint survived): stored versions are meaningless.
            # Degrade to a full staleness re-scan — the recovery
            # contract is "never silently-current".
            reset_lineage = True

    state = RecoveryState(
        runtime=runtime, adapter=adapter,
        partitions={str(k): int(v)
                    for k, v in (ck.get("partitions") or {}).items()},
        repair_events=repair_events, reset_lineage=reset_lineage)
    return manager.submit(plan, _resume=state)


def _lineage_trusted(refstore, tables: Tuple[str, ...],
                     ck: Dict) -> bool:
    """Recovered lineage (and the checkpointed repair journal) may be
    trusted only if every subscribed table's current content hashes to
    the checkpointed fingerprint and its version counter has not gone
    backwards — i.e. this process provably rebuilt the same reference
    state the lineage's version numbers refer to."""
    fps = ck.get("ref_fingerprints") or {}
    vs = ck.get("ref_versions") or {}
    if not fps:
        return False
    for t in tables:
        if t not in fps or t not in refstore:
            return False
        if ref_fingerprint(refstore[t]) != fps[t]:
            return False
        if refstore[t].version < int(vs.get(t, 0)):
            return False
    return True
