"""Durable feeds: write-ahead intake log + coordinated checkpoints.

The column store survives a crash (``StoragePartition.recover()``:
manifest — any format, 1 through 3 — with lineage, zone maps, segment
levels and the layout epoch; a compaction or leveled merge commits its
rewritten manifest BEFORE queueing replaced files for GC, so the
checkpoint protocol below never cites storage state that a crash could
tear) but, before this module, the *feed* did not: adapter offsets,
in-flight holder frames, repair's event journal and the learned elastic
scale all lived in memory.  This
module is the durability half of the fix; ``core/recovery.py`` is the
restart half.  The design follows "Scalable Fault-Tolerant Data Feeds
in AsterixDB" (PAPERS.md): log the intake *before* acknowledging it,
replay at-least-once on restart, and de-duplicate at the storage
boundary (the pk-index conditional insert repair already rides), which
composes into exactly-once.  Per INGESTBASE, durability is a *compiled
property of the plan* — ``.store(durable=DurableSpec(...))`` — not
ad-hoc code in each job.

Three pieces live here (wire protocol documented in docs/DURABILITY.md):

``IntakeLog``
    Append-only segmented frame log.  Each record is a CRC-framed raw
    intake frame (the adapter's JSON-lines bytes, pre-parse) stamped
    with a monotonically increasing sequence number and the adapter's
    *resume offset after the frame*.  A torn tail (crash mid-append or
    an unsynced page) is detected by the CRC and truncated at open: the
    log's contract is that its readable prefix is exactly what was
    durably acknowledged, and anything lost past it is re-read from the
    resumable adapter at the last good record's offset.  That is why
    the default fsync policy ("interval") is safe: fsync cadence trades
    *recovery re-read volume*, never correctness.

``FrameLedger``
    The low-watermark tracker.  ``watermark()`` is the highest seq W
    such that every frame with seq <= W has been written to storage
    chunks; frames complete out of order (partition fan-out), so a done
    set above a contiguous ``low`` counter tracks the frontier.

``CheckpointStore`` / ``CheckpointJob``
    Atomic-rename checkpoint snapshots (tmp + fsync + ``os.replace`` +
    directory fsync, previous kept as ``.bak``) and the background
    thread that takes them: read W -> sync the WAL -> flush storage (so
    every row counted in W is segment-durable) -> write the checkpoint
    -> truncate sealed WAL segments <= W.  The checkpoint also carries
    the feed's soft state: repair's event journal, ref-table content
    fingerprints (recovery's lineage-trust test), and per-group
    partition counts (resume at the learned scale).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import struct
import threading
import time
import zlib
from typing import Dict, Iterator, List, NamedTuple, Optional, Tuple

import numpy as np

_MAGIC = b"IWL1"
_HEAD = struct.Struct("<QQI")   # seq, adapter offset after frame, len
_CRC = struct.Struct("<I")
_SEG_FMT = "wal-%020d.log"

FSYNC_POLICIES = ("always", "interval", "never")


@dataclasses.dataclass(frozen=True)
class DurableSpec:
    """Durability policy, declared on the plan (``.store(durable=...)``).

    ``fsync``: "always" fsyncs the WAL per append (smallest re-read
    window on crash), "interval" (default) fsyncs at most every
    ``fsync_interval_s`` (bounded re-read, near-zero overhead), "never"
    leaves it to the OS (checkpoints still sync explicitly).  All three
    are exactly-once — see the module docstring.
    """
    dir: str
    fsync: str = "interval"
    fsync_interval_s: float = 0.05
    checkpoint_interval_s: float = 5.0
    segment_bytes: int = 8 << 20

    def __post_init__(self):
        if not self.dir:
            raise ValueError("DurableSpec.dir is required")
        if self.fsync not in FSYNC_POLICIES:
            raise ValueError(
                f"fsync must be one of {FSYNC_POLICIES}, got "
                f"{self.fsync!r}")
        if self.fsync_interval_s <= 0:
            raise ValueError("fsync_interval_s must be > 0")
        if self.checkpoint_interval_s <= 0:
            raise ValueError("checkpoint_interval_s must be > 0")
        if self.segment_bytes < 1 << 12:
            raise ValueError("segment_bytes must be >= 4096")

    @property
    def wal_dir(self) -> str:
        return os.path.join(self.dir, "intake")

    @property
    def store_dir(self) -> str:
        return os.path.join(self.dir, "store")


class LogRecord(NamedTuple):
    seq: int
    offset: int          # adapter resume position AFTER this frame
    lines: List[bytes]   # the raw frame (newline-free JSON lines)


def fsync_dir(path: str) -> None:
    """Make a rename/unlink in ``path`` durable; best-effort on
    filesystems that reject directory fds."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _scan_segment(path: str, start_seq: int
                  ) -> Tuple[int, int, Optional[Tuple[int, int]]]:
    """Validate one segment's record prefix.  Returns ``(valid_bytes,
    records, last)`` where ``last`` is ``(seq, offset)`` of the final
    valid record (None if the segment holds no valid record).  Stops at
    the first torn/corrupt record — the WAL's prefix contract."""
    valid = 0
    nrec = 0
    last: Optional[Tuple[int, int]] = None
    expect = start_seq
    try:
        with open(path, "rb") as f:
            while True:
                head = f.read(4 + _HEAD.size + _CRC.size)
                if len(head) < 4 + _HEAD.size + _CRC.size:
                    break
                if head[:4] != _MAGIC:
                    break
                seq, off, ln = _HEAD.unpack_from(head, 4)
                (crc,) = _CRC.unpack_from(head, 4 + _HEAD.size)
                payload = f.read(ln)
                if len(payload) < ln:
                    break
                if zlib.crc32(head[4:4 + _HEAD.size] + payload) != crc:
                    break
                if seq != expect:
                    break
                valid = f.tell()
                nrec += 1
                last = (seq, off)
                expect = seq + 1
    except OSError:
        pass
    return valid, nrec, last


class IntakeLog:
    """Append-only segmented WAL of raw intake frames.

    Single conceptual writer (the intake thread) plus the checkpoint
    thread's ``sync()``/``truncate()`` and, under the "interval" policy,
    a background flusher thread; one lock serializes the file ops
    (the flusher moves its fsync outside it).  Segment files are named by the first sequence number they
    hold; ``truncate(upto)`` unlinks only *sealed* segments entirely
    <= ``upto`` and never the active one, so the tail record (whose
    offset is the adapter resume point) always survives.
    """

    def __init__(self, dir: str, fsync: str = "interval",
                 fsync_interval_s: float = 0.05,
                 segment_bytes: int = 8 << 20):
        if fsync not in FSYNC_POLICIES:
            raise ValueError(f"bad fsync policy {fsync!r}")
        self.dir = dir
        self.fsync = fsync
        self.fsync_interval_s = float(fsync_interval_s)
        self.segment_bytes = int(segment_bytes)
        os.makedirs(dir, exist_ok=True)
        # serializes append/rotate/sync/truncate — file I/O under it is
        # the point, like the repair/compaction step locks
        self._lock = threading.Lock()  # lock-name: wal blocking-ok
        self._f = None                 # guarded-by: _lock
        self._last_seq = 0             # guarded-by: _lock
        self._last_offset: Optional[int] = None  # guarded-by: _lock
        self._last_sync = 0.0          # guarded-by: _lock
        self.appended = 0              # single-writer stat
        self._fsync_hist = None        # obs histogram (set post-init)
        self._flush_stop = threading.Event()
        self._flusher: Optional[threading.Thread] = None
        segs = self._segments()
        if segs:
            # scan the whole log for the last valid record and truncate
            # the active segment's torn tail (crash mid-append) so the
            # next append continues the valid prefix
            self._last_seq = segs[-1][0] - 1
            for start, path in segs:
                valid, nrec, last = _scan_segment(path, start)
                if last is not None:
                    self._last_seq, self._last_offset = last
                if path == segs[-1][1]:
                    try:
                        if valid < os.path.getsize(path):
                            with open(path, "r+b") as f:
                                f.truncate(valid)
                    except OSError:
                        pass
            self._f = open(segs[-1][1], "ab")
        else:
            self._open_segment_locked(1)
        if self.fsync == "interval":
            # interval syncing runs on a background flusher so the
            # intake thread never blocks on fsync (the policy already
            # tolerates an unsynced tail: recovery re-reads it from the
            # resumable adapter, see the module docstring)
            self._flusher = threading.Thread(
                target=self._flush_loop, name="wal-flusher", daemon=True)
            self._flusher.start()

    # ------------------------------------------------------------ internals
    def _segments(self) -> List[Tuple[int, str]]:
        out = []
        try:
            names = os.listdir(self.dir)
        except OSError:
            return []
        for n in names:
            if n.startswith("wal-") and n.endswith(".log"):
                try:
                    out.append((int(n[4:-4]), os.path.join(self.dir, n)))
                except ValueError:
                    continue
        out.sort()
        return out

    def _open_segment_locked(self, start_seq) -> None:  # requires-lock: _lock
        # (the init-time call is pre-publication: no other thread yet)
        if self._f is not None:
            self._f.flush()
            os.fsync(self._f.fileno())   # seal: sealed data is durable
            self._f.close()
        path = os.path.join(self.dir, _SEG_FMT % start_seq)
        self._f = open(path, "ab")
        fsync_dir(self.dir)

    def _flush_loop(self) -> None:
        """Interval-fsync off the intake's critical path: sample the
        active file under the lock (dup the fd so rotation/close can't
        invalidate it), fsync OUTSIDE the lock.  Syncing a dup'd fd
        covers at least everything flushed at sample time — it can only
        over-sync, never under-sync."""
        while not self._flush_stop.wait(self.fsync_interval_s):
            with self._lock:
                if self._f is None:
                    return
                self._f.flush()
                try:
                    fd = os.dup(self._f.fileno())
                except OSError:
                    continue
            t0 = time.perf_counter()
            try:
                os.fsync(fd)
            except OSError:
                pass
            finally:
                os.close(fd)
            hist = self._fsync_hist
            if hist is not None:      # outside the wal lock by design
                hist.observe(time.perf_counter() - t0)

    # ------------------------------------------------------------------ API
    def set_fsync_histogram(self, hist) -> None:
        """Route fsync latencies into an obs histogram (``wal_fsync_s``).
        Called once at feed start, before concurrent appends; the
        flusher/sync paths read the attribute without the lock."""
        self._fsync_hist = hist

    def append_frame(self, offset: int, lines: List[bytes]) -> int:
        """Log one frame; returns its sequence number.  ``offset`` is
        the adapter's resume position *after* this frame.  (Named
        ``append_frame``, not ``append``, so feedlint's duck-typed call
        resolution never confuses it with ``list.append``.)"""
        payload = b"\n".join(lines)
        fsync_dt = 0.0
        with self._lock:
            if self._f is None:
                raise RuntimeError("intake log is closed")
            if self._f.tell() >= self.segment_bytes:
                self._open_segment_locked(self._last_seq + 1)
            seq = self._last_seq + 1
            head = _HEAD.pack(seq, int(offset), len(payload))
            crc = zlib.crc32(head + payload)
            self._f.write(_MAGIC + head + _CRC.pack(crc) + payload)
            self._f.flush()
            if self.fsync == "always":
                t0 = time.perf_counter()
                os.fsync(self._f.fileno())
                fsync_dt = time.perf_counter() - t0
            self._last_seq = seq
            self._last_offset = int(offset)
            self.appended += 1
        hist = self._fsync_hist
        if fsync_dt and hist is not None:
            hist.observe(fsync_dt)
        return seq

    def sync(self) -> None:
        """fsync the active segment (checkpoints call this before
        recording a tail seq/offset, so the checkpoint never references
        a record the disk does not have)."""
        t0 = time.perf_counter()
        synced = False
        with self._lock:
            if self._f is not None:
                self._f.flush()
                os.fsync(self._f.fileno())
                self._last_sync = time.monotonic()
                synced = True
        hist = self._fsync_hist
        if synced and hist is not None:
            hist.observe(time.perf_counter() - t0)

    def tail(self) -> Tuple[int, Optional[int]]:
        """(last logged seq, adapter offset after it).  Offset is None
        when the log holds no records (fresh, or fully truncated past a
        rotation) — the caller falls back to the checkpoint's offset."""
        with self._lock:
            return self._last_seq, self._last_offset

    def replay(self, from_seq: int) -> Iterator[LogRecord]:
        """Yield valid records with seq > ``from_seq`` in order,
        stopping at the first torn/corrupt record (prefix contract).
        Callers materialize the result before appending new frames."""
        for start, path in self._segments():
            expect = start
            try:
                f = open(path, "rb")
            except OSError:
                return
            with f:
                while True:
                    head = f.read(4 + _HEAD.size + _CRC.size)
                    if len(head) < 4 + _HEAD.size + _CRC.size:
                        break
                    if head[:4] != _MAGIC:
                        return
                    seq, off, ln = _HEAD.unpack_from(head, 4)
                    (crc,) = _CRC.unpack_from(head, 4 + _HEAD.size)
                    payload = f.read(ln)
                    if len(payload) < ln:
                        return
                    if zlib.crc32(head[4:4 + _HEAD.size]
                                  + payload) != crc:
                        return
                    if seq != expect:
                        return
                    expect = seq + 1
                    if seq > from_seq:
                        lines = payload.split(b"\n") if payload else []
                        yield LogRecord(seq, off, lines)

    def truncate(self, upto_seq: int) -> int:
        """Unlink sealed segments whose every record has seq <=
        ``upto_seq``; never the active segment.  Returns segments
        removed."""
        removed = 0
        with self._lock:
            segs = self._segments()
            for i in range(len(segs) - 1):
                if segs[i + 1][0] <= upto_seq + 1:
                    try:
                        os.unlink(segs[i][1])
                        removed += 1
                    except OSError:
                        pass
                else:
                    break
            if removed:
                fsync_dir(self.dir)
        return removed

    def close(self) -> None:
        self._flush_stop.set()
        if self._flusher is not None:
            # join BEFORE taking the lock (the loop acquires it)
            self._flusher.join(timeout=5)
        with self._lock:
            if self._f is not None:
                self._f.flush()
                try:
                    os.fsync(self._f.fileno())
                except OSError:
                    pass
                self._f.close()
                self._f = None


class FrameLedger:
    """Low-watermark tracker over WAL sequence numbers.

    ``mark_done(seqs)`` is called by the store consumer after the rows
    of those frames land in storage chunks; completions arrive out of
    order across partitions, so ``_done`` holds the frontier above the
    contiguous ``_low``.  On resume the ledger starts at the checkpoint
    watermark with the WAL tail pending, so a checkpoint can never
    claim progress past unreplayed frames.
    """

    def __init__(self, watermark: int = 0, tail_seq: int = 0,
                 tail_offset: int = 0):
        self._lock = threading.Lock()  # lock-name: wal-ledger
        self._low = int(watermark)             # guarded-by: _lock
        self._done: set = set()                # guarded-by: _lock
        self._tail_seq = max(int(tail_seq), int(watermark))  # guarded-by: _lock
        self._tail_offset = int(tail_offset)   # guarded-by: _lock

    def note_logged(self, seq: int, offset: int) -> None:
        with self._lock:
            if seq > self._tail_seq:
                self._tail_seq = seq
                self._tail_offset = int(offset)

    def mark_done(self, seqs) -> None:
        with self._lock:
            for s in seqs:
                if s > self._low:
                    self._done.add(s)
            while self._low + 1 in self._done:
                self._done.discard(self._low + 1)
                self._low += 1

    def watermark(self) -> int:
        with self._lock:
            return self._low

    def tail(self) -> Tuple[int, int]:
        with self._lock:
            return self._tail_seq, self._tail_offset

    def backlog(self) -> int:
        """Frames logged but not yet storage-complete."""
        with self._lock:
            return self._tail_seq - self._low


class CheckpointStore:
    """Atomic checkpoint snapshots: tmp + fsync + rename, previous kept
    as ``.bak`` so a crash mid-save (or a torn current file) falls back
    one checkpoint instead of losing recovery entirely."""

    FILE = "CHECKPOINT.json"

    def __init__(self, dir: str):
        self.dir = dir
        self.path = os.path.join(dir, self.FILE)

    def save(self, state: Dict) -> None:
        os.makedirs(self.dir, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(state, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(self.path):
            os.replace(self.path, self.path + ".bak")
        os.replace(tmp, self.path)
        fsync_dir(self.dir)

    def load(self) -> Optional[Dict]:
        for path in (self.path, self.path + ".bak"):
            try:
                with open(path) as f:
                    doc = json.load(f)
                if isinstance(doc, dict) and "watermark" in doc:
                    return doc
            except (OSError, json.JSONDecodeError):
                continue
        return None


def ref_fingerprint(table) -> str:
    """Content hash of a ref table's current snapshot (keys + value
    columns over the valid prefix).  Recovery compares checkpointed
    fingerprints against the restarted process's rebuilt tables: only
    on a match (plus a non-regressed version counter) can recovered
    lineage be trusted — otherwise every unit degrades to always-stale
    and repair re-scans, never silently-current."""
    snap = table.snapshot()
    h = hashlib.sha1()
    h.update(struct.pack("<q", int(snap.size)))
    for name in sorted(snap.arrays):
        a = np.ascontiguousarray(snap.arrays[name][:snap.size])
        h.update(name.encode())
        h.update(str(a.dtype).encode())
        h.update(a.tobytes())
    return h.hexdigest()


class DurabilityRuntime:
    """Per-feed durability state: the WAL, the ledger, the checkpoint
    store, and the background checkpoint thread.  Built fresh by
    ``FeedManager.submit`` (durable plans) or pre-initialized by
    ``core/recovery.py`` on resume."""

    def __init__(self, spec: DurableSpec, wal: IntakeLog,
                 ledger: FrameLedger, recovered: bool = False):
        self.spec = spec
        self.wal = wal
        self.ledger = ledger
        self.checkpoints = CheckpointStore(spec.dir)
        self.job: Optional[CheckpointJob] = None
        self.recovered = recovered
        # recovery stats (set by core/recovery.py before start)
        self.replayed_frames = 0
        self.replayed_records = 0
        self.replay_target_seq = 0
        self._closed = False

    @classmethod
    def create(cls, spec: DurableSpec) -> "DurabilityRuntime":
        """Fresh durable feed.  Refuses a dirty durable dir: appending
        a new feed's frames after an unrecovered log would replay them
        twice into a store this process did not recover — the caller
        wants ``FeedManager.resume`` instead."""
        ck = CheckpointStore(spec.dir)
        dirty = os.path.exists(ck.path) or os.path.exists(
            ck.path + ".bak")
        if not dirty and os.path.isdir(spec.wal_dir):
            dirty = any(n.startswith("wal-") and n.endswith(".log")
                        for n in os.listdir(spec.wal_dir))
        if dirty:
            raise RuntimeError(
                f"durable dir {spec.dir!r} already holds an intake "
                "log/checkpoint; use FeedManager.resume(plan) to "
                "recover it, or point DurableSpec.dir at a fresh "
                "directory")
        wal = IntakeLog(spec.wal_dir, spec.fsync,
                        spec.fsync_interval_s, spec.segment_bytes)
        return cls(spec, wal, FrameLedger())

    def start(self, handle, refstore, ref_tables: Tuple[str, ...]
              ) -> None:
        self.job = CheckpointJob(self, handle, refstore, ref_tables)
        self.job.start()

    def finish(self, timeout: float = 30.0) -> None:
        """Clean shutdown: stop the cadence thread, take one final
        checkpoint (the drained feed's watermark == tail, so the WAL
        truncates to just its active segment), close the WAL."""
        if self._closed:
            return
        self._closed = True
        if self.job is not None:
            self.job.finish(timeout)
        self.wal.close()

    def stop(self) -> None:
        """Abort path (join() raised): stop the thread without a final
        checkpoint — the on-disk state stays resumable as-is."""
        if self._closed:
            return
        self._closed = True
        if self.job is not None:
            self.job.stop()
        self.wal.close()


class CheckpointJob(threading.Thread):
    """Background coordinated checkpointer (one per durable feed).

    Each step: read watermark W and the WAL tail -> ``wal.sync()`` (the
    recorded tail is durable) -> ``storage.flush()`` (every row counted
    in W is segment-durable, not chunk-only) -> write the checkpoint
    atomically -> ``wal.truncate(W)``.  Steps are skipped while W has
    not advanced: soft state (repair events, scale) not captured by a
    skipped step degrades on resume to a lineage reset + full re-scan,
    which is safe (DURABILITY.md).
    """

    def __init__(self, rt: DurabilityRuntime, handle, refstore,
                 ref_tables: Tuple[str, ...]):
        super().__init__(name=f"checkpoint-{handle.cfg.name}",
                         daemon=True)
        self.rt = rt
        self.handle = handle
        self.refstore = refstore
        self.ref_tables = ref_tables
        # serializes steps (cadence vs final); long I/O under it is the
        # point, like repair-step/compaction-step
        self._step_lock = threading.Lock()  # lock-name: checkpoint-step blocking-ok
        self._wake = threading.Event()
        self._stopped = threading.Event()
        self._last_w = rt.ledger.watermark()   # guarded-by: _step_lock
        self.checkpoints = 0    # single-writer stat
        self.last_error: Optional[BaseException] = None
        self._obs = getattr(handle, "obs", None)
        self._ckpt_hist = (self._obs.registry.histogram("checkpoint_s")
                           if self._obs is not None else None)

    def run(self):
        while not self._stopped.is_set():
            self._wake.wait(self.rt.spec.checkpoint_interval_s)
            self._wake.clear()
            if self._stopped.is_set():
                return
            try:
                self.step()
            except Exception as e:   # keep checkpointing; surface last
                self.last_error = e

    def step(self, force: bool = False) -> bool:
        with self._step_lock:
            led = self.rt.ledger
            w = led.watermark()
            tail_seq, tail_off = led.tail()
            if w <= self._last_w and not force:
                return False
            t0 = time.perf_counter()
            self.rt.wal.sync()
            self.handle.storage.flush()
            self.rt.checkpoints.save(
                self._state(w, tail_seq, tail_off))
            self.rt.wal.truncate(w)
            self._last_w = w
            self.checkpoints += 1
            dur = time.perf_counter() - t0
            # under the checkpoint-step lock only (blocking-ok:
            # R6-exempt, edge declared in analysis/annotations.py)
            if self._ckpt_hist is not None:
                self._ckpt_hist.observe(dur)
            if self._obs is not None and self._obs.tracing:
                self._obs.emit("checkpoint", (), t0=time.monotonic(),
                               dur=dur, watermark=w)
            return True

    def _state(self, w: int, tail_seq: int, tail_off: int) -> Dict:
        h = self.handle
        st: Dict = {
            "format": 1,
            "feed": h.cfg.name,
            "watermark": int(w),
            "last_seq": int(tail_seq),
            "last_offset": int(tail_off),
            "partitions": {g.name: len(g.holders)
                           for g in h.stage_groups},
        }
        if h.repair is not None and self.ref_tables:
            st["repair_events"] = h.repair.snapshot_events()
            st["ref_versions"] = {
                t: self.refstore[t].version for t in self.ref_tables}
            st["ref_fingerprints"] = {
                t: ref_fingerprint(self.refstore[t])
                for t in self.ref_tables}
        return st

    def finish(self, timeout: float = 30.0) -> None:
        self._stopped.set()
        self._wake.set()
        self.join(timeout)
        self.step(force=True)

    def stop(self) -> None:
        self._stopped.set()
        self._wake.set()
        self.join(timeout=5.0)
