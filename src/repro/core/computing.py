"""The computing job (§5.3, §6): parse -> build UDF state -> apply UDF.

Implements all three computing models the paper analyzes so the experiments
can compare them:

  Model 1 ``per_record``  state rebuilt and UDF applied per record — sees
                          every reference change, unusable at rate (§5.3.2)
  Model 2 ``per_batch``   the paper's choice: state rebuilt per *batch*,
                          refreshing reference changes at batch boundaries
  Model 3 ``stream``      state built once for the whole feed — fastest,
                          but blind to reference updates ("current w/o
                          updates" in §8.2) and exactly the stateful-UDF
                          failure mode of Fig 15/16

plus the **version-gated** refresh (beyond-paper, EXPERIMENTS.md §Perf):
Model-2 freshness at Model-3 cost while reference data is quiet — the state
is a pure function of the refstore version, so we rebuild only when the
version actually changed.

Both the state builder and the probe are predeployed (AOT-compiled once per
shape, see predeploy.py) and invoked per batch with (batch, refs) as
parameters.  Reference snapshots are device-cached by version so quiet
tables are not re-uploaded.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import records
from repro.core.enrich.queries import EnrichUDF
from repro.core.predeploy import PredeployCache
from repro.core.refdata import RefSnapshot, RefStore


@dataclasses.dataclass
class StageStats:
    """Per-stage observability for fused (chained) UDFs: how often each
    stage's intermediate state was rebuilt vs reused and what it cost.
    Inside a multi-stage fused executable apply time cannot be attributed
    exactly per stage — the whole chain is ONE dispatch by design — so
    ``apply_s`` splits the batch's apply wall across the fused stages by
    **measured calibration fractions**: every ``CALIBRATE_EVERY``-th
    batch the runner replays the chain stage-by-stage through per-stage
    predeployed executables (compile excluded, off the hot path's
    accounting) and blends the observed shares into an EWMA weight per
    stage.  Until the first calibration lands the split is even — the
    pre-calibration behavior, still exact when the executable holds a
    single stage (the per-stage-split case the elasticity controller
    samples; model="per_record" also keeps the even split).  Exact
    *group*-level walls come from the tracer's ``apply.<group>`` spans
    (core/obs, docs/OBSERVABILITY.md)."""
    invocations: int = 0
    records: int = 0
    state_builds: int = 0
    state_reuses: int = 0
    state_s: float = 0.0
    apply_s: float = 0.0

    def merge(self, other: "StageStats") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))


@dataclasses.dataclass
class ComputingStats:
    invocations: int = 0
    records: int = 0
    parse_s: float = 0.0
    upload_s: float = 0.0
    convert_s: float = 0.0       # batch H2D + enriched-output D2H
    state_s: float = 0.0
    apply_s: float = 0.0
    state_builds: int = 0
    state_reuses: int = 0
    # stage-timing calibration passes taken (fused chains only); the
    # calibration walls themselves are NOT in apply_s — they price the
    # attribution, not the feed
    calibrations: int = 0
    # stage name -> StageStats, populated per enrichment stage (one entry
    # for a plain UDF, one per chained stage for a fused UDF)
    per_stage: Dict[str, StageStats] = dataclasses.field(
        default_factory=dict)

    def stage(self, name: str) -> StageStats:
        s = self.per_stage.get(name)
        if s is None:
            s = self.per_stage[name] = StageStats()
        return s

    def merge(self, other: "ComputingStats") -> None:
        for f in dataclasses.fields(self):
            if f.name == "per_stage":
                for name, ss in other.per_stage.items():
                    self.stage(name).merge(ss)
                continue
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))


@dataclasses.dataclass(frozen=True)
class ComputingSpec:
    udf: Optional[EnrichUDF]       # None = pure ingestion (no enrichment)
    batch_size: int
    model: str = "per_batch"       # per_record | per_batch | stream
    refresh: str = "always"        # always | version  (per_batch only)


class ComputingRunner:
    """One runner per computing-job worker.  Thread-confined."""

    def __init__(self, spec: ComputingSpec, refstore: RefStore,
                 cache: Optional[PredeployCache] = None):
        self.spec = spec
        self.refstore = refstore
        self.cache = cache or PredeployCache()
        self.stats = ComputingStats()
        self._device_refs: Dict[str, Tuple[int, Dict[str, jax.Array]]] = {}
        self._state = None            # (versions, state) for stream/gated
        self._state_versions: Optional[Tuple[int, ...]] = None
        # ref-version lineage of the LAST run() — the versions the batch
        # was actually enriched under (captured at snapshot time, so a ref
        # upsert racing the apply can never mark stored rows fresh).  The
        # feed tags storage-bound batches with this (core/repair.py).
        self.last_versions: Optional[Dict[str, int]] = None
        # fused UDFs: stage name -> (stage ref versions, state) so quiet
        # stages reuse their state while stale stages rebuild independently
        self._stage_states: Dict[str, Tuple[Tuple[int, ...], Any]] = {}
        # measured per-stage apply-time fractions (EWMA over calibration
        # passes); None until the first calibration -> even split
        self._stage_weights: Optional[Dict[str, float]] = None
        self._inv_since_cal = 0

    # ------------------------------------------------------------- snapshots
    TRIM_QUANTUM = 256

    def _refs_to_device(self, snaps: Dict[str, RefSnapshot]
                        ) -> Dict[str, Dict[str, jax.Array]]:
        """Upload snapshots, trimmed to a quantized valid prefix.

        §Perf: tables carry UPSERT headroom (sentinel rows); probing the
        full capacity wastes a proportional slice of every per-row
        reference op (3x on Q6's district tables).  Trimming to
        round_up(size, 256) keeps shapes stable across small UPSERTs (the
        predeployed executable survives); crossing a quantum recompiles
        once — the paper's compile-once/invoke-many contract still holds
        per shape."""
        out = {}
        t0 = time.perf_counter()
        force = self.spec.refresh == "always" and self.spec.model != "stream"
        q = self.TRIM_QUANTUM
        for name, snap in snaps.items():
            hit = self._device_refs.get(name)
            if hit is not None and hit[0] == snap.version and not force:
                out[name] = hit[1]
                continue
            n = min(snap.capacity,
                    ((max(snap.size, 1) + q - 1) // q) * q)
            dev = {k: jnp.asarray(v[:n]) for k, v in snap.arrays.items()}
            self._device_refs[name] = (snap.version, dev)
            out[name] = dev
        self.stats.upload_s += time.perf_counter() - t0
        return out

    # ----------------------------------------------------------------- state
    def _get_staged_state(self, refs, snaps: Dict[str, RefSnapshot]):
        """State for a fused UDF, built/refreshed per stage: each stage's
        state is keyed by the versions of the tables *that stage* reads, so
        under ``refresh="version"`` an upsert rebuilds only the stages it
        affects (Model-2 freshness per stage, Model-3 cost for the quiet
        ones).  ``refresh="always"`` rebuilds every stateful stage per
        batch, exactly like an unfused Model-2 UDF."""
        udf, spec = self.spec.udf, self.spec
        states = []
        for stage in udf.stages:
            if stage.state_fn is None:
                states.append(())
                continue
            ss = self.stats.stage(stage.name)
            sversions = tuple(snaps[t].version for t in stage.ref_tables)
            prev = self._stage_states.get(stage.name)
            reuse = prev is not None and (
                spec.model == "stream"
                or (spec.model == "per_batch"
                    and spec.refresh == "version"
                    and prev[0] == sversions))
            if reuse:
                ss.state_reuses += 1
                self.stats.state_reuses += 1
                states.append(prev[1])
                continue
            t0 = time.perf_counter()
            state = self.cache.invoke(f"state:{udf.name}:{stage.name}",
                                      stage.state_fn, refs)
            state = jax.block_until_ready(state)
            dt = time.perf_counter() - t0
            ss.state_builds += 1
            ss.state_s += dt
            self.stats.state_builds += 1
            self.stats.state_s += dt
            self._stage_states[stage.name] = (sversions, state)
            states.append(state)
        return tuple(states)

    def _get_state(self, refs, versions):
        udf = self.spec.udf
        if udf.state_fn is None:
            return ()
        reuse = (
            (self.spec.model == "stream" and self._state is not None)
            or (self.spec.model == "per_batch"
                and self.spec.refresh == "version"
                and self._state_versions == versions))
        if reuse:
            self.stats.state_reuses += 1
            return self._state
        t0 = time.perf_counter()
        state = self.cache.invoke(f"state:{udf.name}", udf.build_state, refs)
        state = jax.block_until_ready(state)
        self.stats.state_s += time.perf_counter() - t0
        self.stats.state_builds += 1
        self._state = state
        self._state_versions = versions
        return state

    # ----------------------------------------------------------------- parse
    def parse(self, frame) -> Dict[str, np.ndarray]:
        """Raw JSON-lines frame -> padded tensor records (a no-op for frames
        that arrive pre-parsed from a balanced intake).  Coalesced
        micro-batches exceeding the configured batch size are padded up to a
        power-of-two row bucket so the predeployed executables see a bounded
        set of shapes instead of one compile per coalesced size."""
        t0 = time.perf_counter()
        if isinstance(frame, dict):
            batch = frame
        else:
            batch = records.parse_json_lines(frame)
        size = self.spec.batch_size
        n = records.batch_rows(batch)
        if n > size and self.spec.model != "per_record":
            # per_record keeps pad_batch's loud oversize assert: its row
            # loop walks exactly batch_size rows, so a bucketed batch
            # would silently drop the tail
            from repro.core.enrich import dispatch
            size = dispatch.bucket_rows(n, minimum=size)
        batch = records.pad_batch(batch, size)
        self.stats.parse_s += time.perf_counter() - t0
        return batch

    # ------------------------------------------------------------------- run
    def run(self, frame) -> Dict[str, np.ndarray]:
        """One computing-job invocation: returns the enriched batch
        (original columns + UDF outputs + valid mask), as numpy."""
        batch = self.parse(frame)
        nvalid = int(batch["valid"].sum())
        udf = self.spec.udf
        if udf is None:
            self.stats.invocations += 1
            self.stats.records += nvalid
            return batch

        snaps = self.refstore.snapshot(udf.ref_tables)
        versions = tuple(s.version for s in snaps.values())
        self.last_versions = dict(zip(snaps.keys(), versions))
        refs = self._refs_to_device(snaps)
        apply_before = self.stats.apply_s

        t0 = time.perf_counter()
        dev_batch = {k: jnp.asarray(v) for k, v in batch.items()}
        self.stats.convert_s += time.perf_counter() - t0
        if self.spec.model == "per_record":
            enriched = self._run_per_record(dev_batch, refs, versions)
        else:
            if udf.stages and udf.state_fn is not None:
                state = self._get_staged_state(refs, snaps)
            else:
                state = self._get_state(refs, versions)
            t0 = time.perf_counter()
            enriched = self.cache.invoke(
                f"apply:{udf.name}", udf.apply_fn, dev_batch, state, refs)
            enriched = jax.block_until_ready(enriched)
            self.stats.apply_s += time.perf_counter() - t0

        out = dict(batch)
        t0 = time.perf_counter()
        for k, v in enriched.items():
            out[k] = np.asarray(v)
        self.stats.convert_s += time.perf_counter() - t0
        self.stats.invocations += 1
        self.stats.records += nvalid
        stages = udf.stages or (udf,)
        # per-stage wall attribution: a fused chain is ONE dispatch, so
        # this batch's apply wall is split across its stages by measured
        # calibration fractions (even split until the first calibration;
        # see the StageStats docstring)
        weights = self._stage_weights
        if len(stages) > 1 and self.spec.model != "per_record":
            self._inv_since_cal += 1
            # first calibration at the CALIBRATE_EVERY-th fused batch —
            # NOT the first, so short feeds keep the strict one-dispatch
            # profile (and its predeploy-cache footprint) unchanged
            if self._inv_since_cal >= self.CALIBRATE_EVERY:
                weights = self._calibrate_stages(stages, dev_batch,
                                                 state, refs)
                self._inv_since_cal = 0
        batch_apply_s = self.stats.apply_s - apply_before
        even = 1.0 / len(stages)
        for st in stages:
            frac = weights.get(st.name, even) if weights else even
            ss = self.stats.stage(st.name)
            ss.invocations += 1
            ss.records += nvalid
            ss.apply_s += batch_apply_s * frac
        return out

    # ------------------------------------------------------------ calibration
    CALIBRATE_EVERY = 64     # fused-chain batches between stage re-timings

    def _calibrate_stages(self, stages, dev_batch, state, refs
                          ) -> Dict[str, float]:
        """Time each fused stage individually — the chain replayed through
        per-stage predeployed executables, outputs feeding forward exactly
        like the fused ``apply_fn`` — and blend the observed shares into
        the EWMA weights.  ``cache.get`` runs untimed first so a cold
        executable's compile never pollutes the measured fraction, and
        none of this wall lands in ``apply_s``: calibration prices the
        *attribution*, not the feed.  Per-stage executables share the
        predeploy cache with single-UDF feeds of the same stage (same
        (name, fn, signature) key)."""
        udf = self.spec.udf
        states = (state if udf.stages and udf.state_fn is not None
                  else ((),) * len(stages))
        durs: Dict[str, float] = {}
        cur = dict(dev_batch)
        for st, s in zip(stages, states):
            name = f"apply:{st.name}"
            self.cache.get(name, st.apply_fn, cur, s, refs)
            t0 = time.perf_counter()
            res = self.cache.invoke(name, st.apply_fn, cur, s, refs)
            res = jax.block_until_ready(res)
            durs[st.name] = max(time.perf_counter() - t0, 1e-9)
            cur.update(res)
        total = sum(durs.values())
        fresh = {n: d / total for n, d in durs.items()}
        prev = self._stage_weights
        if prev is None:
            weights = fresh
        else:
            weights = {n: 0.5 * prev.get(n, f) + 0.5 * f
                       for n, f in fresh.items()}
            norm = sum(weights.values())
            weights = {n: w / norm for n, w in weights.items()}
        self._stage_weights = weights
        self.stats.calibrations += 1
        return weights

    def _run_per_record(self, dev_batch, refs, versions):
        """Model 1: per-record evaluation — state refreshed per record."""
        udf = self.spec.udf
        n = self.spec.batch_size
        outs = []
        for i in range(n):
            row = {k: v[i:i + 1] for k, v in dev_batch.items()}
            if udf.state_fn is None:
                state = ()
            else:
                t0 = time.perf_counter()
                state = self.cache.invoke(
                    f"state:{udf.name}", udf.build_state, refs)
                self.stats.state_s += time.perf_counter() - t0
                self.stats.state_builds += 1
            t0 = time.perf_counter()
            o = self.cache.invoke(
                f"apply1:{udf.name}", udf.apply_fn, row, state, refs)
            outs.append(jax.block_until_ready(o))
            self.stats.apply_s += time.perf_counter() - t0
        return {k: jnp.concatenate([o[k] for o in outs])
                for k in outs[0]}
