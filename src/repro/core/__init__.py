"""The paper's primary contribution: the IDEA ingestion/enrichment
framework — intake / computing / storage jobs, partition holders,
parameterized predeployed (AOT-compiled) computing jobs, versioned
reference data, and the Q1-Q7 enrichment-UDF workload."""

from repro.core.compaction import (  # noqa: F401
    CompactionJob,
    CompactionSpec,
    CompactionStats,
)
from repro.core.computing import (  # noqa: F401
    ComputingRunner,
    ComputingSpec,
    ComputingStats,
)
from repro.core.durability import (  # noqa: F401
    CheckpointStore,
    DurableSpec,
    FrameLedger,
    IntakeLog,
    ref_fingerprint,
)
from repro.core.elasticity import (  # noqa: F401
    ElasticityController,
    ElasticSpec,
)
from repro.core.feed import FeedConfig, FeedHandle, FeedManager  # noqa: F401
from repro.core.plan import (  # noqa: F401
    IngestPlan,
    Pipeline,
    PlanError,
    SinkSpec,
    StageGroup,
    StoreSpec,
    pipeline,
)
from repro.core.intake import (  # noqa: F401
    Adapter,
    FileAdapter,
    IntakeJob,
    NotResumableError,
    SocketAdapter,
    SyntheticAdapter,
    TrackedFrame,
)
from repro.core.obs import (  # noqa: F401
    FeedHealthModel,
    FeedObs,
    HealthReport,
    HealthSpec,
    HistogramSnapshot,
    JourneyProfiler,
    MetricsRegistry,
    MetricValue,
    ObsServer,
    ProfileReport,
    ProfileSpec,
    Tracer,
    TraceSpec,
)
from repro.core.partition_holder import (  # noqa: F401
    STOP,
    ActivePartitionHolder,
    PartitionHolder,
    PartitionHolderManager,
    StopRecord,
)
from repro.core.predeploy import PredeployCache  # noqa: F401
from repro.core.query import (  # noqa: F401
    Query,
    QueryError,
    QueryResult,
    QueryStats,
    StoreSnapshot,
    agg,
    col,
)
from repro.core.repair import (  # noqa: F401
    RepairJob,
    RepairSpec,
    RepairStats,
)
from repro.core.refdata import (  # noqa: F401
    KEY_SENTINEL,
    RefSnapshot,
    RefStore,
    RefTable,
)
from repro.core.storage import StorageJob, StoragePartition  # noqa: F401
