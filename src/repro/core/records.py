"""Tweet record schema, parser, and synthetic source.

The paper ingests JSON tweets (Figure 1: open datatype, required ``id`` +
``text``) and enriches them against reference datasets.  ADM's open records
become **fixed-width tensor records** (struct-of-arrays) here so every batch
has the same shapes and the predeployed (AOT-compiled) computing job is
reusable across batches:

    id              int64    primary key
    country         int32    dictionary code (the paper joins on country)
    lat, lon        float32  tweet location (spatial UDFs Q4-Q7)
    created_at      int64    seconds (Q7's 2-month attack window)
    user_name_hash  int64    hashed author name (Q5's suspicious-names join)
    text_tokens     int64[T] hashed text tokens, 0-padded (T=16)

Text adaptation (DESIGN.md §2): SQL++ ``contains(text, keyword)`` becomes a
membership test of the keyword's hash among the tweet's token hashes —
substring scan is pointer-chasing the TPU cannot do; tokenized-hash
membership is the vectorizable equivalent, computed with a (T, K) equality
matrix on the VPU.

The parser converts raw JSON-lines bytes -> tensor records; in the *new*
framework it runs inside the computing job (paper Fig 23), in the "current
feeds" baseline it runs on the single intake node (the Fig 24 bottleneck).
"""

from __future__ import annotations

import dataclasses
import functools
import json
from typing import Dict, Iterator, List

import numpy as np

try:                     # §Perf: orjson parses ~3-5x faster than stdlib —
    import orjson        # the parser is the paper's Fig-24 bottleneck
    _loads = orjson.loads
except ImportError:      # pragma: no cover
    _loads = json.loads

TEXT_TOKENS = 16
NUM_COUNTRIES = 256


@functools.lru_cache(maxsize=1 << 20)
def hash64(s: str) -> int:
    """Deterministic 63-bit FNV-1a (stable across processes, unlike
    ``hash()``; avoids the int64 sign bit).  Memoized: token vocabularies
    repeat heavily, and the per-byte python loop was 77% of parse time
    (§Perf — profiled before/after in EXPERIMENTS.md)."""
    h = 14695981039346656037
    for b in s.encode():
        h = (h ^ b) * 1099511628211 & 0x7FFFFFFFFFFFFFFF
    return h & 0x7FFFFFFFFFFFFFFF   # empty string: mask the basis too


TWEET_SCHEMA: Dict[str, np.dtype] = {
    "id": np.dtype(np.int64),
    "country": np.dtype(np.int32),
    "lat": np.dtype(np.float32),
    "lon": np.dtype(np.float32),
    "created_at": np.dtype(np.int64),
    "user_name_hash": np.dtype(np.int64),
    "text_tokens": np.dtype((np.int64, (TEXT_TOKENS,))),
}


def empty_batch(n: int) -> Dict[str, np.ndarray]:
    out = {}
    for k, dt in TWEET_SCHEMA.items():
        if dt.subdtype is not None:
            base, shape = dt.subdtype
            out[k] = np.zeros((n,) + shape, base)
        else:
            out[k] = np.zeros((n,), dt)
    out["valid"] = np.zeros((n,), bool)
    return out


def batch_rows(batch: Dict[str, np.ndarray]) -> int:
    return int(batch["id"].shape[0])


# ---------------------------------------------------------------------------
# parsing (bytes -> tensor records)
# ---------------------------------------------------------------------------

def parse_json_lines(lines: List[bytes]) -> Dict[str, np.ndarray]:
    """The parser stage: JSON-lines -> struct-of-arrays."""
    n = len(lines)
    out = empty_batch(n)
    for i, raw in enumerate(lines):
        rec = _loads(raw)
        out["id"][i] = rec["id"]
        out["country"][i] = rec.get("country", 0)
        out["lat"][i] = rec.get("lat", 0.0)
        out["lon"][i] = rec.get("lon", 0.0)
        out["created_at"][i] = rec.get("created_at", 0)
        out["user_name_hash"][i] = hash64(rec.get("user", ""))
        toks = [hash64(w) for w in rec.get("text", "").split()[:TEXT_TOKENS]]
        out["text_tokens"][i, :len(toks)] = toks
        out["valid"][i] = True
    return out


def pad_batch(batch: Dict[str, np.ndarray], size: int
              ) -> Dict[str, np.ndarray]:
    """Pad to the compiled batch size (valid=False rows are inert in every
    UDF and dropped by the storage job).  Columns beyond the tweet schema —
    enriched outputs of an upstream stage group crossing an intermediate
    partition holder — are zero-padded at their own dtype/shape."""
    n = batch_rows(batch)
    if n == size:
        return batch
    assert n < size, (n, size)
    out = empty_batch(size)
    for k, v in batch.items():
        if k not in out:
            out[k] = np.zeros((size,) + v.shape[1:], v.dtype)
        out[k][:n] = v
    return out


def concat_batches(batches: List[Dict[str, np.ndarray]]
                   ) -> Dict[str, np.ndarray]:
    return {k: np.concatenate([b[k] for b in batches])
            for k in batches[0]}


# ---------------------------------------------------------------------------
# synthetic source
# ---------------------------------------------------------------------------

_WORDS = [f"w{i}" for i in range(4096)] + ["bomb", "alert", "match", "storm"]


@dataclasses.dataclass
class SyntheticTweets:
    """Deterministic synthetic tweet stream (the experiments' data source).
    Emits raw JSON-lines bytes (so parsing cost is real, as in the paper) or
    pre-parsed tensor records."""
    seed: int = 0
    start_id: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._next_id = self.start_id

    def raw_lines(self, n: int) -> List[bytes]:
        recs = []
        rng = self._rng
        ids = np.arange(self._next_id, self._next_id + n)
        self._next_id += n
        countries = rng.integers(0, NUM_COUNTRIES, n)
        lats = rng.uniform(-60, 60, n)
        lons = rng.uniform(-180, 180, n)
        ts = rng.integers(1_500_000_000, 1_600_000_000, n)
        for i in range(n):
            nwords = int(rng.integers(4, TEXT_TOKENS))
            words = rng.choice(len(_WORDS), nwords)
            recs.append(json.dumps({
                "id": int(ids[i]),
                "country": int(countries[i]),
                "lat": round(float(lats[i]), 4),
                "lon": round(float(lons[i]), 4),
                "created_at": int(ts[i]),
                "user": f"user{int(rng.integers(0, 1_000_000))}",
                "text": " ".join(_WORDS[w] for w in words),
            }).encode())
        return recs

    def batches(self, total: int, batch: int) -> Iterator[List[bytes]]:
        left = total
        while left > 0:
            n = min(batch, left)
            yield self.raw_lines(n)
            left -= n
