"""Sharded, crash-consistent checkpointing with reshard-on-restore.

Layout:  <dir>/step_<N>/
            MANIFEST.json     tree structure, shapes, dtypes, crc32s
            leaf_<i>.npy      one file per pytree leaf

Commit protocol: everything is written into ``step_<N>.tmp`` and the
directory is atomically renamed — a crash mid-save never corrupts the
latest durable checkpoint; ``latest_step`` only ever sees committed dirs.
Integrity: every leaf carries a crc32 verified on restore.

Reshard-on-restore: ``restore`` optionally takes target NamedShardings and
``jax.device_put``s each leaf, so a checkpoint written on one mesh restarts
on any other (elastic scaling: the mesh is rebuilt from the live device
set, and the same logical-axis rules produce the new shardings —
runtime/elastic.py).

``AsyncCheckpointer`` overlaps the serialization+fsync with training: save
returns immediately after snapshotting device arrays to host; a background
thread does the IO; ``wait()`` joins before the next save or at exit.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _leaf_paths(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, state: Any, keep: int = 3) -> str:
    """Synchronous checkpoint save with atomic commit. Returns the path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = _leaf_paths(state)
    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        path = os.path.join(tmp, f"leaf_{i:05d}.npy")
        np.save(path, arr)
        manifest["leaves"].append({
            "shape": list(arr.shape),
            "dtype": arr.dtype.str,
            "crc32": zlib.crc32(arr.tobytes()),
        })
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic commit

    # retention
    steps = sorted(all_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)
    return final


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name,
                                           "MANIFEST.json")):
                out.append(int(name[5:]))
    return out


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return max(steps) if steps else None


def restore(directory: str, like: Any, step: Optional[int] = None,
            shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  Verifies crc32s; optionally reshards every leaf to
    ``shardings`` (same treedef) — elastic restart path."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)

    leaves_like, treedef = jax.tree.flatten(like)
    if len(leaves_like) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"target structure has {len(leaves_like)}")
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves_like))

    out = []
    for i, (meta, tgt, shd) in enumerate(
            zip(manifest["leaves"], leaves_like, shard_leaves)):
        arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
        if zlib.crc32(arr.tobytes()) != meta["crc32"]:
            raise IOError(f"checksum mismatch in leaf {i} of {path}")
        if tuple(arr.shape) != tuple(tgt.shape):
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != "
                f"target {tgt.shape}")
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr, dtype=tgt.dtype))
    return jax.tree.unflatten(treedef, out)


class AsyncCheckpointer:
    """Background-thread checkpointing (overlaps IO with compute)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None
        self.saves = 0

    def save(self, step: int, state: Any) -> None:
        self.wait()
        # snapshot to host before returning control to the train loop
        host = jax.tree.map(lambda x: np.asarray(x), state)

        def run():
            try:
                save(self.directory, step, host, self.keep)
            except BaseException as e:
                self._err = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()
        self.saves += 1

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err
