from repro.data.packing import StreamPacker  # noqa: F401
from repro.data.tokenizer import HashTokenizer  # noqa: F401
