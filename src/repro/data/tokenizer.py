"""Hash tokenizer: stable word -> id mapping into a fixed vocab.

The IDEA intake parser already hashes text tokens (records.hash64); the LM
data plane folds those hashes into [reserved, vocab) ids.  Reserved ids:
0=pad, 1=bos, 2=eos, 3..15 special.
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.core.records import hash64

PAD, BOS, EOS = 0, 1, 2
RESERVED = 16


class HashTokenizer:
    def __init__(self, vocab_size: int):
        assert vocab_size > RESERVED
        self.vocab_size = vocab_size

    def fold(self, token_hashes: np.ndarray) -> np.ndarray:
        """int64 hashes (0 = empty slot) -> vocab ids (0 = pad)."""
        ids = token_hashes % (self.vocab_size - RESERVED) + RESERVED
        return np.where(token_hashes == 0, PAD, ids).astype(np.int32)

    def encode(self, text: str) -> List[int]:
        return [int(self.fold(np.asarray([hash64(w)], np.int64))[0])
                for w in text.split()]
