"""Sequence packing: variable-length documents -> dense (B, S) batches with
segment ids and per-segment positions, so packed documents never attend to
each other (the packing-aware mask in models/layers.causal_mask).
"""

from __future__ import annotations

from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.data.tokenizer import BOS, EOS


class StreamPacker:
    """Greedy first-fit packing of a document stream into fixed shapes.

    Emits batches {tokens, targets, segment_ids, positions, loss_mask}, all
    (B, S) int32.  targets are next-token; the final token of each document
    predicts EOS; padding has loss_mask 0 and segment_id 0.
    """

    def __init__(self, seq_len: int, batch_size: int):
        self.seq_len = seq_len
        self.batch_size = batch_size
        self._rows: List[List[Dict]] = []   # per-row list of docs

    def add(self, doc: List[int]) -> Optional[Dict[str, np.ndarray]]:
        """Add one document (list of token ids); returns a full batch when
        one becomes available."""
        doc = [BOS] + list(doc)[: self.seq_len - 2] + [EOS]
        for row in self._rows:
            used = sum(len(d["ids"]) for d in row)
            if used + len(doc) <= self.seq_len:
                row.append({"ids": doc})
                break
        else:
            self._rows.append([{"ids": doc}])
        if len(self._rows) > self.batch_size or (
                len(self._rows) == self.batch_size
                and self._row_full(self._rows[self.batch_size - 1])):
            return self._emit()
        return None

    def _row_full(self, row) -> bool:
        return sum(len(d["ids"]) for d in row) >= self.seq_len - 4

    def flush(self) -> Optional[Dict[str, np.ndarray]]:
        return self._emit() if self._rows else None

    def _emit(self) -> Dict[str, np.ndarray]:
        b, s = self.batch_size, self.seq_len
        rows, self._rows = self._rows[:b], self._rows[b:]
        tokens = np.zeros((b, s), np.int32)
        targets = np.zeros((b, s), np.int32)
        segment = np.zeros((b, s), np.int32)
        positions = np.zeros((b, s), np.int32)
        loss = np.zeros((b, s), np.float32)
        for i, row in enumerate(rows):
            cur = 0
            for seg, d in enumerate(row, start=1):
                ids = d["ids"]
                n = len(ids)
                tokens[i, cur:cur + n] = ids
                targets[i, cur:cur + n - 1] = ids[1:]
                targets[i, cur + n - 1] = EOS
                segment[i, cur:cur + n] = seg
                positions[i, cur:cur + n] = np.arange(n)
                loss[i, cur:cur + n] = 1.0
                cur += n
        return {"tokens": tokens, "targets": targets,
                "segment_ids": segment, "positions": positions,
                "loss_mask": loss}


def pack_stream(docs: Iterator[List[int]], seq_len: int, batch_size: int
                ) -> Iterator[Dict[str, np.ndarray]]:
    packer = StreamPacker(seq_len, batch_size)
    for doc in docs:
        out = packer.add(doc)
        if out is not None:
            yield out
    out = packer.flush()
    if out is not None:
        yield out
