"""Elastic scaling: checkpoint -> remesh -> reshard-on-restore.

JAX SPMD programs are fixed-mesh, so elasticity (the Hyracks scheduler's
dynamic node sets) is realized at restart boundaries: when the live device
set changes, rebuild the mesh from whatever is alive, re-derive every
sharding from the *logical* axis rules (models/sharding.py — the rules are
mesh-shape-agnostic), and restore the latest checkpoint with per-leaf
``device_put`` resharding (ckpt/checkpoint.py).  Nothing about the model or
step function changes — the same lowering just repartitions.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh

from repro.models.sharding import Rules, tree_shardings


def build_mesh(devices: Optional[Sequence] = None,
               model_parallel: int = 1,
               axis_names: Tuple[str, str] = ("data", "model")) -> Mesh:
    """Mesh over the live device set: data-parallel dim absorbs whatever
    count survives, model dim is the requested TP width."""
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    assert n % model_parallel == 0, (n, model_parallel)
    import numpy as np
    arr = np.array(devices).reshape(n // model_parallel, model_parallel)
    return Mesh(arr, axis_names)


def remesh_shardings(shape_tree: Any, axes_tree: Any, mesh: Mesh,
                     rules: Optional[Rules] = None) -> Any:
    """NamedShardings for ``shape_tree`` on a (possibly new) mesh — the
    reshard plan handed to ckpt.restore after a device-set change."""
    return tree_shardings(shape_tree, axes_tree, mesh=mesh, rules=rules)
