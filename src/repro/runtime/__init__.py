from repro.runtime.elastic import remesh_shardings  # noqa: F401
from repro.runtime.fault import retry  # noqa: F401
