"""Failure-handling primitives shared by the feed manager and the trainer:
bounded exponential-backoff retry and a metrics surface for fault events."""

from __future__ import annotations

import functools
import logging
import time
from typing import Callable, Tuple, Type

log = logging.getLogger(__name__)


def retry(max_attempts: int = 3, backoff_s: float = 0.05,
          exceptions: Tuple[Type[BaseException], ...] = (Exception,),
          on_retry: Callable[[int, BaseException], None] | None = None):
    """Decorator: retries with exponential backoff; re-raises after
    ``max_attempts`` total attempts."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            attempt = 0
            while True:
                try:
                    return fn(*args, **kwargs)
                except exceptions as e:
                    attempt += 1
                    if attempt >= max_attempts:
                        raise
                    if on_retry is not None:
                        on_retry(attempt, e)
                    log.warning("retry %d/%d after %s", attempt,
                                max_attempts, e)
                    time.sleep(backoff_s * (2 ** (attempt - 1)))
        return wrapped
    return deco
