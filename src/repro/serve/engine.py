"""Slot-based continuous-batching serving engine.

A fixed pool of ``slots`` decode lanes shares one batched KV/SSD cache.
Incoming requests are prefillled one at a time (prompt lengths bucketed to
bound the number of compiled prefill shapes) and spliced into a free slot
with ``dynamic_update_slice``; the decode step always runs the full batch,
and finished slots are immediately refilled between steps — decode
utilization does not drain while long requests finish (the serving-side
analog of the paper's decoupled intake/compute jobs: admission never blocks
the compute loop).

Bucketed prefill correctness: the prompt is right-padded to the bucket, the
slot's ``len`` is reset to the true prompt length, and the first-token
logits are taken at the true last position.  Junk cache rows beyond the
true length are overwritten by the decode writes before the causal mask can
ever expose them (attention families).  SSM/hybrid caches carry recurrent
state, so those families use exact-length prefill (no bucketing).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.tokenizer import EOS
from repro.models import api


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    stop_at_eos: bool = True
    rid: int = dataclasses.field(default_factory=itertools.count().__next__)
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, slots: int = 4,
                 max_len: int = 256, prompt_bucket: int = 16):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.bucket = prompt_bucket if cfg.family not in ("ssm", "hybrid") \
            else 1
        cshapes, _ = api.cache_specs(cfg, slots, max_len)
        self.cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), cshapes)
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        self.decode_steps = 0
        self.prefills = 0

        self._decode = jax.jit(
            lambda p, c, t: api.decode_step(cfg, p, c, t))
        self._prefill = jax.jit(
            lambda p, t, fe: api.prefill(cfg, p, t, fe))
        self._apply = jax.jit(lambda p, b: api.apply(cfg, p, b))

    # ----------------------------------------------------------------- admin
    def submit(self, req: Request) -> Request:
        self.queue.append(req)
        return req

    def _insert(self, slot: int, req: Request) -> None:
        true_len = len(req.prompt)
        blen = _round_up(true_len, self.bucket)
        prompt = np.zeros((1, blen), np.int32)
        prompt[0, :true_len] = req.prompt
        tokens = jnp.asarray(prompt)
        frontend = None
        if self.cfg.family in ("vlm", "encdec"):
            frontend = jnp.zeros(
                (1, self.cfg.num_frontend_tokens, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype))
        cache1, _ = self._prefill(self.params, tokens, frontend)
        cache1 = api.pad_cache(self.cfg, cache1, self.max_len)
        self.prefills += 1
        # first-token logits at the true last prompt position
        batch = {"tokens": tokens}
        if frontend is not None:
            batch["frontend"] = frontend
        logits, _ = self._apply(self.params, batch)
        nf = (self.cfg.num_frontend_tokens
              if self.cfg.family == "vlm" else 0)
        first = int(jnp.argmax(logits[0, true_len - 1]))

        new_cache = {}
        for key, full in self.cache.items():
            if key == "len":
                new_cache[key] = full.at[slot].set(true_len + nf)
            else:   # splice the single-request cache into batch slot
                new_cache[key] = jax.tree.map(
                    lambda f, s: jax.lax.dynamic_update_slice(
                        f, s.astype(f.dtype),
                        (0, slot) + (0,) * (f.ndim - 2)),
                    full, cache1[key])
        self.cache = new_cache
        req.tokens.append(first)
        self.active[slot] = req
        if req.stop_at_eos and first == EOS:
            self._finish(slot)

    def _finish(self, slot: int) -> None:
        req = self.active[slot]
        req.done = True
        self.completed.append(req)
        self.active[slot] = None

    # ------------------------------------------------------------------ run
    def step(self) -> bool:
        """Admit + one decode step.  Returns False when fully idle."""
        for slot in range(self.slots):
            if self.active[slot] is None and self.queue:
                self._insert(slot, self.queue.pop(0))
        live = [s for s in range(self.slots) if self.active[s] is not None]
        if not live:
            return bool(self.queue)
        tok = np.zeros((self.slots, 1), np.int32)
        for s in live:
            tok[s, 0] = self.active[s].tokens[-1]
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tok))
        self.decode_steps += 1
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for s in live:
            req = self.active[s]
            t = int(nxt[s])
            req.tokens.append(t)
            if (req.stop_at_eos and t == EOS) or \
                    len(req.tokens) >= req.max_new_tokens or \
                    len(req.prompt) + len(req.tokens) >= self.max_len - 1:
                self._finish(s)
        return True

    def run(self, max_steps: int = 10_000) -> List[Request]:
        for _ in range(max_steps):
            if not self.step():
                break
        done, self.completed = self.completed, []
        return done
