"""olmoe-1b-7b — OLMoE: 7B total / 1B active MoE LM. [arXiv:2409.02060; hf]"""
from repro.configs.base import ModelConfig, register


@register("olmoe-1b-7b")
def olmoe_1b_7b() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,          # GQA kv=16 (MHA-equivalent)
        d_ff=1024,                # per-expert FFN width
        vocab_size=50_304,
        head_dim=128,
        num_experts=64,
        experts_per_token=8,
        moe_period=1,             # every layer is MoE
        param_dtype="float32",
        remat="dots",
        source="arXiv:2409.02060; hf",
    )
