"""command-r-plus-104b — Cohere Command R+ class dense LM (GQA, no-bias).
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.configs.base import ModelConfig, register


@register("command-r-plus-104b")
def command_r_plus_104b() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b",
        family="dense",
        num_layers=64,
        d_model=12_288,
        num_heads=96,
        num_kv_heads=8,
        d_ff=33_792,
        vocab_size=256_000,
        head_dim=128,
        qkv_bias=False,
        tie_embeddings=True,      # command-r ties input/output embeddings
        param_dtype="bfloat16",
        remat="full",
        source="hf:CohereForAI/c4ai-command-r-v01; unverified",
    )
