"""mamba2-130m — Mamba-2 SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig, register


@register("mamba2-130m")
def mamba2_130m() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        family="ssm",
        num_layers=24,
        d_model=768,
        num_heads=0,              # attention-free
        num_kv_heads=0,
        d_ff=0,                   # no MLP; mixer is the SSD block
        vocab_size=50_280,
        ssm_state=128,
        ssm_expand=2,             # d_inner = 1536
        ssm_headdim=64,           # 24 SSD heads
        ssm_conv=4,
        ssm_chunk=256,
        tie_embeddings=True,
        param_dtype="float32",
        remat="full",   # chunked-SSD intra-chunk tensors are O(S*Q*H):
                        # without remat the 24-layer backward residuals
                        # exceed HBM at train_4k (see EXPERIMENTS.md)
        source="arXiv:2405.21060; unverified",
    )
