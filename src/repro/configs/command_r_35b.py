"""command-r-35b — Cohere Command R dense LM (GQA, no-bias).
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.configs.base import ModelConfig, register


@register("command-r-35b")
def command_r_35b() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b",
        family="dense",
        num_layers=40,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=22_528,
        vocab_size=256_000,
        head_dim=128,
        qkv_bias=False,
        tie_embeddings=True,
        param_dtype="bfloat16",
        remat="full",
        source="hf:CohereForAI/c4ai-command-r-v01; unverified",
    )
