"""Model / shape configuration for the repro framework.

Every assigned architecture is a ``ModelConfig`` registered under its public id
(``--arch <id>``).  Configs are frozen dataclasses so they can be hashed into
the predeploy (AOT compile) cache key — the same mechanism the paper uses for
parameterized predeployed jobs, where the *query* is compiled once and invoked
per batch.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    ``family`` selects the forward implementation:
      dense   — decoder-only transformer (GQA + SwiGLU)
      moe     — decoder-only transformer with MoE FFN every ``moe_period`` layers
      ssm     — Mamba2 (SSD) stack, attention-free
      hybrid  — Jamba-style 1:``attn_period`` attention:mamba interleave (+MoE)
      encdec  — Whisper-style encoder/decoder (stubbed conv frontend)
      vlm     — decoder-only LM with prepended patch-embedding stub
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_period: int = 1          # MoE FFN on layers where (i % moe_period)==moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    moe_ep: bool = False          # explicit shard_map expert parallelism
                                  # (all_to_all dispatch) instead of GSPMD

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # --- hybrid (Jamba) ---
    attn_period: int = 0          # one attention layer per ``attn_period`` layers
    attn_offset: int = 4          # its index within the period

    # --- encdec (Whisper) ---
    encoder_layers: int = 0

    # --- modality frontend stubs (audio frames / vision patches) ---
    num_frontend_tokens: int = 0

    # --- misc ---
    qkv_bias: bool = False
    mlp_variant: str = "swiglu"   # "swiglu" | "gelu"
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"       # activation dtype
    param_dtype: str = "float32"  # parameter dtype (bf16 for the huge archs)
    remat: str = "full"           # "none" | "dots" | "full"
    use_pallas_attention: bool = False  # flash kernel (TPU); jnp ref path on CPU
    logits_softcap: float = 0.0
    source: str = ""              # provenance tag from the assignment table

    # ---------------------------------------------------------------- helpers
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_subquadratic(self) -> bool:
        """True when the arch can serve ``long_500k`` (attention-free or
        hybrid with O(S) memory growth only on a small fraction of layers)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive decoder

    def moe_layer(self, i: int) -> bool:
        if self.num_experts == 0:
            return False
        return (i % self.moe_period) == self.moe_offset

    def attn_layer(self, i: int) -> bool:
        """hybrid family: which layers are attention (vs mamba)."""
        if self.family != "hybrid":
            return self.family != "ssm"
        return (i % self.attn_period) == self.attn_offset

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Parameter-count estimate (used for roofline MODEL_FLOPS = 6·N·D).
    def param_count(self, active_only: bool = False) -> int:
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d

        def attn_params() -> int:
            p = d * self.num_heads * hd           # q
            p += 2 * d * self.num_kv_heads * hd   # k, v
            p += self.num_heads * hd * d          # o
            if self.qkv_bias:
                p += (self.num_heads + 2 * self.num_kv_heads) * hd
            return p

        def dense_ffn() -> int:
            if self.mlp_variant == "swiglu":
                return 3 * d * self.d_ff
            return 2 * d * self.d_ff

        def moe_ffn() -> int:
            per_expert = 3 * d * self.d_ff
            e = self.experts_per_token if active_only else self.num_experts
            return e * per_expert + d * self.num_experts  # + router

        def mamba_params() -> int:
            di = self.d_inner
            n_ = d * (2 * di + 2 * self.ssm_state + self.ssm_heads)  # in_proj
            n_ += self.ssm_conv * (di + 2 * self.ssm_state)          # conv
            n_ += self.ssm_heads * 2                                  # A, D
            n_ += di * d                                              # out_proj
            return n_

        for i in range(self.num_layers):
            if self.family == "ssm":
                n += mamba_params()
                continue
            if self.family == "hybrid" and not self.attn_layer(i):
                n += mamba_params()
            else:
                n += attn_params()
            if self.family != "ssm":
                n += moe_ffn() if self.moe_layer(i) else dense_ffn()
        if self.family == "encdec":
            for _ in range(self.encoder_layers):
                n += attn_params() + dense_ffn()   # encoder self-attn + mlp
            n += self.num_layers * attn_params()   # decoder cross-attn
        n += 2 * d * max(self.num_layers, 1)       # norms (approx)
        return n


# ---------------------------------------------------------------------------
# Input-shape specifications (assigned per-arch shape set)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Returns (applicable, reason-if-not). long_500k needs sub-quadratic
    attention; pure full-attention archs skip it (see DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "skipped (full-attention arch; long_500k needs sub-quadratic)"
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[arch_id] = fn
        return fn
    return deco


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _REGISTRY:
        # import side-effect registration
        from repro import configs as _c  # noqa: F401
    if arch_id not in _REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]()


def list_archs():
    from repro import configs as _c  # noqa: F401
    return sorted(_REGISTRY)


def smoke_config(arch_id: str) -> ModelConfig:
    """Reduced config of the same family for CPU smoke tests."""
    cfg = get_config(arch_id)
    kw = dict(
        num_layers=2 if cfg.family != "hybrid" else cfg.attn_period,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        param_dtype="float32",
        dtype="float32",
        remat="none",
    )
    if cfg.num_experts:
        kw.update(num_experts=4, experts_per_token=2)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_headdim=16, ssm_chunk=8)
    if cfg.family == "encdec":
        kw.update(encoder_layers=2)
    if cfg.num_frontend_tokens:
        kw.update(num_frontend_tokens=8)
    return cfg.replace(**kw)
