"""whisper-medium — encoder/decoder speech model; conv frontend STUBBED
(``input_specs()`` supplies precomputed frame embeddings). [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig, register


@register("whisper-medium")
def whisper_medium() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="encdec",
        num_layers=24,            # decoder layers
        encoder_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=51_865,
        head_dim=64,
        mlp_variant="gelu",
        qkv_bias=True,            # whisper uses biased projections
        tie_embeddings=True,
        num_frontend_tokens=1536, # ~30 s of audio after the (stubbed) conv
                                  # stack; 1500 padded to 1536 for TPU-aligned
                                  # attention blocks (see DESIGN.md §2)
        param_dtype="float32",
        remat="dots",
        source="arXiv:2212.04356; unverified",
    )
