"""qwen1.5-32b — Qwen 1.5 32B dense LM (QKV bias). [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.configs.base import ModelConfig, register


@register("qwen1.5-32b")
def qwen1_5_32b() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b",
        family="dense",
        num_layers=64,
        d_model=5120,
        num_heads=40,
        num_kv_heads=40,          # MHA (GQA kv=40)
        d_ff=27_392,
        vocab_size=152_064,
        head_dim=128,
        qkv_bias=True,            # Qwen-style attention bias
        param_dtype="bfloat16",
        remat="full",
        source="hf:Qwen/Qwen1.5-0.5B; hf",
    )
