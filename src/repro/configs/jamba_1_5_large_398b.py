"""jamba-1.5-large-398b — Jamba hybrid: Mamba + attention 7:1 interleave,
MoE 16e top-2 on alternating layers. [arXiv:2403.19887; hf]"""
from repro.configs.base import ModelConfig, register


@register("jamba-1.5-large-398b")
def jamba_1_5_large_398b() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        num_layers=72,            # 9 periods of 8 (7 mamba + 1 attention)
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=24_576,              # per-expert / dense FFN width
        vocab_size=65_536,
        head_dim=128,
        num_experts=16,
        experts_per_token=2,
        moe_period=2,             # MoE FFN every other layer
        moe_offset=1,
        attn_period=8,            # attention layer once per 8
        attn_offset=4,
        ssm_state=128,
        ssm_expand=2,
        ssm_headdim=64,
        ssm_conv=4,
        ssm_chunk=256,
        param_dtype="bfloat16",
        remat="full",
        source="arXiv:2403.19887; hf",
    )
