"""Config registry — importing this package registers all assigned archs."""
from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ShapeSpec,
    get_config,
    list_archs,
    register,
    shape_applicable,
    smoke_config,
)

# Registration side effects (one module per assigned architecture).
from repro.configs import olmoe_1b_7b  # noqa: F401
from repro.configs import kimi_k2_1t_a32b  # noqa: F401
from repro.configs import command_r_plus_104b  # noqa: F401
from repro.configs import qwen1_5_32b  # noqa: F401
from repro.configs import deepseek_coder_33b  # noqa: F401
from repro.configs import command_r_35b  # noqa: F401
from repro.configs import mamba2_130m  # noqa: F401
from repro.configs import whisper_medium  # noqa: F401
from repro.configs import internvl2_2b  # noqa: F401
from repro.configs import jamba_1_5_large_398b  # noqa: F401

ALL_ARCHS = (
    "olmoe-1b-7b",
    "kimi-k2-1t-a32b",
    "command-r-plus-104b",
    "qwen1.5-32b",
    "deepseek-coder-33b",
    "command-r-35b",
    "mamba2-130m",
    "whisper-medium",
    "internvl2-2b",
    "jamba-1.5-large-398b",
)
