"""kimi-k2-1t-a32b — Kimi K2, trillion-param MoE (paper-table config).
[arXiv:2501.kimi2; unverified]"""
from repro.configs.base import ModelConfig, register


@register("kimi-k2-1t-a32b")
def kimi_k2_1t_a32b() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=64,
        num_kv_heads=8,           # GQA kv=8
        d_ff=2048,                # per-expert FFN width
        vocab_size=163_840,
        head_dim=112,             # 7168 / 64
        num_experts=384,
        experts_per_token=8,
        moe_period=1,
        param_dtype="bfloat16",   # 1T params: bf16 master + sharded opt state
        remat="full",
        source="arXiv:2501.kimi2; unverified",
    )
