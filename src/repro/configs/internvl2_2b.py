"""internvl2-2b — InternViT + InternLM2 VLM; ViT frontend STUBBED
(``input_specs()`` supplies precomputed patch embeddings). [arXiv:2404.16821; hf]"""
from repro.configs.base import ModelConfig, register


@register("internvl2-2b")
def internvl2_2b() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b",
        family="vlm",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=92_553,
        head_dim=128,
        tie_embeddings=True,
        num_frontend_tokens=256,  # one image tile worth of patch embeddings
        param_dtype="float32",
        remat="dots",
        source="arXiv:2404.16821; hf",
    )
