"""deepseek-coder-33b — llama-architecture dense code LM. [arXiv:2401.14196; hf]"""
from repro.configs.base import ModelConfig, register


@register("deepseek-coder-33b")
def deepseek_coder_33b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b",
        family="dense",
        num_layers=62,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        d_ff=19_200,
        vocab_size=32_256,
        head_dim=128,
        param_dtype="bfloat16",
        remat="full",
        source="arXiv:2401.14196; hf",
    )
