"""feedlint — AST-based concurrency-invariant analyzer for the core.

Six rules, all driven by the comment annotations documented in
repro.analysis.annotations and docs/CONCURRENCY.md:

R1 guarded-field       fields declared ``# guarded-by: <lock>`` (or
                       ``write-guarded-by``) are read/mutated only inside
                       ``with <lock>`` or a ``# requires-lock`` method.
R2 lock-order          every observed nested acquisition (lexical
                       with-in-with plus transitive may-acquire through
                       resolvable calls) must lie inside the declared
                       acquisition order (annotations.LOCK_ORDER plus
                       in-file ``# feedlint: order a -> b``); cycles and
                       re-entrant acquisitions always fail.
R3 blocking-under-lock JIT/dispatch, npz/file I/O, time.sleep and queue
                       puts lexically under a ``with <lock>`` body
                       (locks tagged ``blocking-ok`` — dedicated
                       background serialization locks — are exempt).
R4 epoch-fence         repair_rows/delete_rows/update_lineage call sites
                       outside storage.py must pass ``expect_epoch=``.
R5 listener-under-lock subscriber callbacks (``# fires-listeners``
                       methods, or callables iterated from a
                       ``# listener-registry`` field) never run under a
                       held lock.
R6 obs-under-lock      telemetry publication — histogram ``.observe()``
                       and span ``.emit()`` — never runs under a strict
                       (non-``blocking-ok``) lock; counters and gauges
                       are lock-free and stay legal anywhere.

The analyzer is pure stdlib ``ast`` + ``tokenize``: it never imports the
code it scans.  Exit status 0 means a clean tree.
"""

from __future__ import annotations

import argparse
import ast
import dataclasses
import re
import sys
import tokenize
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

try:
    from repro.analysis.annotations import LOCK_ORDER
except ImportError:
    # Bare-runner path (the feedlint CI job installs nothing): importing
    # the repro package pulls in jax via repro/__init__, so when invoked
    # as a file — ``python src/repro/analysis/feedlint.py src/`` — load
    # the stdlib-only annotations module by path instead.
    import importlib.util as _ilu

    _spec = _ilu.spec_from_file_location(
        "_feedlint_annotations",
        Path(__file__).resolve().parent / "annotations.py")
    _mod = _ilu.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    LOCK_ORDER = _mod.LOCK_ORDER

_RE_LOCK_NAME = re.compile(r"lock-name:\s*([\w.-]+)")
_RE_BLOCKING_OK = re.compile(r"\bblocking-ok\b")
_RE_GUARDED = re.compile(r"(?<![\w-])guarded-by:\s*(\w+)")
_RE_WRITE_GUARDED = re.compile(r"write-guarded-by:\s*(\w+)")
_RE_REQUIRES = re.compile(r"requires-lock:\s*(\w+)")
_RE_FIRES = re.compile(r"\bfires-listeners\b")
_RE_LISTENER_REG = re.compile(r"\blistener-registry\b")
_RE_ALLOW = re.compile(r"feedlint:\s*allow\[([\w,\s-]+)\]")
_RE_ORDER = re.compile(r"feedlint:\s*order\s+([\w.-]+)\s*->\s*([\w.-]+)")

#: methods that mutate their receiver — a call through a guarded field
#: counts as a write to that field.
_MUTATORS = {
    "append", "appendleft", "extend", "insert", "pop", "popleft",
    "remove", "clear", "add", "discard", "update", "setdefault",
    "sort", "reverse", "merge",
}

#: module-level callables that block (I/O, sleep, JIT) keyed by the
#: *resolved* module name (import aliases are followed).
_BLOCKING_MODULE_CALLS: Dict[str, Set[str]] = {
    "time": {"sleep"},
    "numpy": {"load", "save", "savez", "savez_compressed", "fromfile"},
    "json": {"dump", "load"},
    "os": {"replace", "unlink", "remove", "rename", "makedirs",
           "rmdir", "fsync"},
    "shutil": {"rmtree", "copy", "copy2", "move"},
    "jax": {"jit", "block_until_ready", "device_put", "device_get"},
}

#: resolved method calls that block: queue puts and JIT dispatch.
_BLOCKING_METHODS = {
    ("PartitionHolder", "push"), ("PartitionHolder", "close"),
    ("PredeployCache", "get"), ("PredeployCache", "invoke"),
    ("ComputingRunner", "run"),
}

#: R4: conditional storage writes that must be epoch-fenced outside
#: storage.py.
_EPOCH_FENCED = {"repair_rows", "delete_rows", "update_lineage"}

#: names never resolved via the unique-method-name fallback (too common
#: across stdlib types to trust).
_FALLBACK_BLOCKLIST = {"join", "get", "run", "start", "stop", "put",
                       "items", "keys", "values", "copy", "index",
                       "count", "split", "strip", "read", "write"}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    msg: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule}: {self.msg}"


@dataclasses.dataclass
class ClassInfo:
    name: str
    scan: "Scan"
    node: ast.ClassDef
    bases: List[str] = dataclasses.field(default_factory=list)
    locks: Dict[str, str] = dataclasses.field(default_factory=dict)
    aliases: Dict[str, str] = dataclasses.field(default_factory=dict)
    # field -> (lock attr, mode) where mode is "rw" or "w"
    guarded: Dict[str, Tuple[str, str]] = dataclasses.field(
        default_factory=dict)
    listener_fields: Set[str] = dataclasses.field(default_factory=set)
    requires: Dict[str, str] = dataclasses.field(default_factory=dict)
    fires: Set[str] = dataclasses.field(default_factory=set)
    props: Set[str] = dataclasses.field(default_factory=set)
    methods: Dict[str, ast.FunctionDef] = dataclasses.field(
        default_factory=dict)
    attr_types: Dict[str, str] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Scan:
    path: str
    tree: ast.Module
    comments: Dict[int, str]
    comment_only: Set[int] = dataclasses.field(default_factory=set)
    # name bound by a plain ``import`` -> resolved module dotted name
    mod_imports: Dict[str, str] = dataclasses.field(default_factory=dict)
    # name bound by ``from m import n`` -> (module dotted, n)
    from_imports: Dict[str, Tuple[str, str]] = dataclasses.field(
        default_factory=dict)
    classes: Dict[str, ClassInfo] = dataclasses.field(default_factory=dict)
    funcs: Dict[str, ast.FunctionDef] = dataclasses.field(
        default_factory=dict)
    mod_locks: Dict[str, str] = dataclasses.field(default_factory=dict)
    mod_guarded: Dict[str, Tuple[str, str]] = dataclasses.field(
        default_factory=dict)
    orders: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    dotted: str = ""


def _collect_comments(text: str) -> Tuple[Dict[int, str], Set[int]]:
    """comment text per line + the lines that are comment-only."""
    out: Dict[int, str] = {}
    own: Set[int] = set()
    lines = text.splitlines(True)
    try:
        for tok in tokenize.generate_tokens(iter(lines).__next__):
            if tok.type == tokenize.COMMENT:
                row, col = tok.start
                out[row] = tok.string
                if lines[row - 1][:col].strip() == "":
                    own.add(row)
    except (tokenize.TokenError, IndentationError):  # pragma: no cover
        pass
    return out, own


def _allow_set(comment: Optional[str]) -> Set[str]:
    if not comment:
        return set()
    m = _RE_ALLOW.search(comment)
    if not m:
        return set()
    return {t.strip() for t in m.group(1).split(",") if t.strip()}


def _line_allow(scan: "Scan", line: int) -> Set[str]:
    """Allows on the line itself plus contiguous comment-only lines
    directly above it (block-comment style suppressions)."""
    out = set(_allow_set(scan.comments.get(line)))
    j = line - 1
    while j in scan.comment_only:
        out |= _allow_set(scan.comments.get(j))
        j -= 1
    return out


def _decl_comment(scan: "Scan", line: int) -> str:
    """Declaration-site comment text: the line's own trailing comment
    plus contiguous comment-only lines directly above (for annotations
    that don't fit on the assignment line)."""
    parts = []
    j = line - 1
    while j in scan.comment_only:
        parts.append(scan.comments.get(j, ""))
        j -= 1
    parts.reverse()
    parts.append(scan.comments.get(line, ""))
    return "\n".join(p for p in parts if p)


def _block_allow(scan: "Scan", line: int) -> Set[str]:
    """Allows attached to a def/with header: its own line, comment-only
    lines above, and the leading comment block of its body below."""
    out = _line_allow(scan, line)
    j = line + 1
    while j in scan.comment_only:
        out |= _allow_set(scan.comments.get(j))
        j += 1
    return out


def _ann_name(node: Optional[ast.AST]) -> Optional[str]:
    """Best-effort class name out of an annotation expression."""
    if node is None:
        return None
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        name = node.value.strip().strip("'\"")
        return name.split("[")[0].split(".")[-1] or None
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Subscript):
        base = _ann_name(node.value)
        if base == "Optional":
            return _ann_name(node.slice)
        return None
    return None


def _dotted_of(path: Path) -> str:
    """Module dotted name, rooted at the first ``repro`` path component."""
    parts = list(path.with_suffix("").parts)
    if "repro" in parts:
        parts = parts[parts.index("repro"):]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _lock_ctor(value: ast.AST) -> Optional[str]:
    """'lock' | 'condition' if the assigned value constructs one."""
    for node in ast.walk(value):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "threading"):
            if node.func.attr in ("Lock", "RLock"):
                return "lock"
            if node.func.attr == "Condition":
                return "condition"
    return None


def _condition_target(value: ast.AST) -> Optional[str]:
    """The ``X`` in ``threading.Condition(self.X)``, if present."""
    for node in ast.walk(value):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "Condition" and node.args):
            arg = node.args[0]
            if (isinstance(arg, ast.Attribute)
                    and isinstance(arg.value, ast.Name)
                    and arg.value.id == "self"):
                return arg.attr
    return None


def _annotated_guard(ann: ast.AST) -> Optional[Tuple[str, str]]:
    """Parse ``Annotated[T, guarded_by("_lock")]`` declarations."""
    if not (isinstance(ann, ast.Subscript)
            and _ann_name(ann.value) == "Annotated"
            and isinstance(ann.slice, ast.Tuple)):
        return None
    for meta in ann.slice.elts[1:]:
        if (isinstance(meta, ast.Call) and isinstance(meta.func, ast.Name)
                and meta.func.id in ("guarded_by", "write_guarded_by")
                and meta.args and isinstance(meta.args[0], ast.Constant)):
            mode = "w" if meta.func.id == "write_guarded_by" else "rw"
            return str(meta.args[0].value), mode
    return None


class Linter:
    def __init__(self, scans: List[Scan],
                 extra_order: Sequence[Tuple[str, str]] = ()):
        self.scans = scans
        self.findings: List[Finding] = []
        # (outer, inner) -> first observed (path, line)
        self.edges: Dict[Tuple[str, str], Tuple[str, int]] = {}
        self.edge_allowed: Set[Tuple[str, str]] = set()
        self.declared: Set[Tuple[str, str]] = set(LOCK_ORDER)
        self.declared.update(extra_order)
        self.classes: Dict[str, Optional[ClassInfo]] = {}
        self.method_index: Dict[str, List[ClassInfo]] = {}
        self.by_dotted: Dict[str, Scan] = {}
        self.blocking_ok: Set[str] = set()
        self._summaries: Dict[int, Set[str]] = {}
        self._in_progress: Set[int] = set()
        self._index()

    # -- registry construction -------------------------------------------

    def _index(self) -> None:
        for scan in self.scans:
            self.by_dotted[scan.dotted] = scan
            self.declared.update(scan.orders)
            for cls in scan.classes.values():
                if cls.name in self.classes:
                    self.classes[cls.name] = None  # ambiguous
                else:
                    self.classes[cls.name] = cls
                for m in cls.methods:
                    self.method_index.setdefault(m, []).append(cls)
        for scan in self.scans:
            for line, comment in scan.comments.items():
                if _RE_LOCK_NAME.search(comment) and _RE_BLOCKING_OK.search(
                        comment):
                    self.blocking_ok.add(_RE_LOCK_NAME.search(comment).group(1))

    # -- small lookups through the (single-inheritance) base chain -------

    def _base_chain(self, cls: ClassInfo) -> List[ClassInfo]:
        chain, seen = [cls], {cls.name}
        cur = cls
        while True:
            nxt = None
            for b in cur.bases:
                cand = self.classes.get(b)
                if cand is not None and cand.name not in seen:
                    nxt = cand
                    break
            if nxt is None:
                return chain
            chain.append(nxt)
            seen.add(nxt.name)
            cur = nxt

    def _cls_lock(self, cls: ClassInfo, attr: str) -> Optional[str]:
        for c in self._base_chain(cls):
            if attr in c.aliases:
                attr = c.aliases[attr]
            if attr in c.locks:
                return c.locks[attr]
        return None

    def _cls_guard(self, cls: ClassInfo,
                   field: str) -> Optional[Tuple[ClassInfo, str, str]]:
        for c in self._base_chain(cls):
            if field in c.guarded:
                lockattr, mode = c.guarded[field]
                return c, lockattr, mode
        return None

    def _cls_method(self, cls: ClassInfo,
                    name: str) -> Optional[Tuple[ClassInfo, ast.FunctionDef]]:
        for c in self._base_chain(cls):
            if name in c.methods:
                return c, c.methods[name]
        return None

    def _cls_attr_type(self, cls: ClassInfo, attr: str) -> Optional[str]:
        for c in self._base_chain(cls):
            if attr in c.attr_types:
                return c.attr_types[attr]
        return None

    def _cls_requires(self, cls: ClassInfo, meth: str) -> Optional[str]:
        for c in self._base_chain(cls):
            if meth in c.requires:
                return c.requires[meth]
        return None

    def _is_listener_field(self, cls: ClassInfo, field: str) -> bool:
        return any(field in c.listener_fields for c in self._base_chain(cls))

    # -- type inference ---------------------------------------------------

    def infer(self, expr: ast.AST, env: Dict[str, object],
              scan: Scan):
        """-> ClassInfo | ("module", dotted) | None."""
        if isinstance(expr, ast.Name):
            v = env.get(expr.id)
            if v is not None:
                return v
            if expr.id in scan.mod_imports:
                return ("module", scan.mod_imports[expr.id])
            fi = scan.from_imports.get(expr.id)
            if fi and f"{fi[0]}.{fi[1]}" in self.by_dotted:
                return ("module", f"{fi[0]}.{fi[1]}")
            return None
        if isinstance(expr, ast.Attribute):
            base = self.infer(expr.value, env, scan)
            if isinstance(base, tuple) and base[0] == "module":
                dotted = f"{base[1]}.{expr.attr}"
                if dotted in self.by_dotted:
                    return ("module", dotted)
                return ("module", dotted)
            if isinstance(base, ClassInfo):
                t = self._cls_attr_type(base, expr.attr)
                if t:
                    return self.classes.get(t)
            return None
        if isinstance(expr, ast.Call):
            target = self.resolve_call(expr, env, scan, None)
            if target and target[0] == "ctor":
                return target[1]
            if target and target[0] == "method":
                owner, fn = target[1], target[2]
                ret = _ann_name(owner.methods[fn].returns)
                if ret:
                    return self.classes.get(ret)
            return None
        if isinstance(expr, ast.Subscript):
            base = self.infer(expr.value, env, scan)
            if isinstance(base, ClassInfo):
                got = self._cls_method(base, "__getitem__")
                if got:
                    ret = _ann_name(got[1].returns)
                    if ret:
                        return self.classes.get(ret)
            return None
        if isinstance(expr, ast.IfExp):
            return (self.infer(expr.body, env, scan)
                    or self.infer(expr.orelse, env, scan))
        if isinstance(expr, ast.BoolOp):
            for v in expr.values:
                got = self.infer(v, env, scan)
                if got is not None:
                    return got
        return None

    def resolve_call(self, call: ast.Call, env: Dict[str, object],
                     scan: Scan, cls: Optional[ClassInfo]):
        """-> ("method", owner ClassInfo, name)
             | ("ctor", ClassInfo)
             | ("func", Scan, name) | None."""
        fn = call.func
        if isinstance(fn, ast.Name):
            target_cls = self.classes.get(fn.id)
            if target_cls is not None and fn.id not in env:
                return ("ctor", target_cls)
            if fn.id in scan.funcs:
                return ("func", scan, fn.id)
            fi = scan.from_imports.get(fn.id)
            if fi:
                src = self.by_dotted.get(fi[0])
                if src and fi[1] in src.funcs:
                    return ("func", src, fi[1])
            return None
        if isinstance(fn, ast.Attribute):
            base = self.infer(fn.value, env, scan)
            if isinstance(base, tuple) and base[0] == "module":
                src = self.by_dotted.get(base[1])
                if src and fn.attr in src.funcs:
                    return ("func", src, fn.attr)
                return None
            if isinstance(base, ClassInfo):
                got = self._cls_method(base, fn.attr)
                if got:
                    return ("method", got[0], fn.attr)
                return None
            # unique-method-name fallback for duck-typed receivers
            if isinstance(fn.value, ast.Constant):
                return None
            name = fn.attr
            if (name.startswith("__") or name in _FALLBACK_BLOCKLIST):
                return None
            owners = self.method_index.get(name, [])
            if len(owners) == 1:
                return ("method", owners[0], name)
        return None

    def _target_fn(self, target) -> Optional[Tuple[Optional[ClassInfo],
                                                   ast.FunctionDef, Scan]]:
        if target is None:
            return None
        if target[0] == "method":
            owner, name = target[1], target[2]
            return owner, owner.methods[name], owner.scan
        if target[0] == "ctor":
            owner = target[1]
            init = owner.methods.get("__init__")
            return (owner, init, owner.scan) if init else None
        if target[0] == "func":
            return None, target[1].funcs[target[2]], target[1]
        return None

    # -- may-acquire summaries -------------------------------------------

    def may_acquire(self, cls: Optional[ClassInfo], fn: ast.FunctionDef,
                    scan: Scan) -> Set[str]:
        key = id(fn)
        if key in self._summaries:
            return self._summaries[key]
        if key in self._in_progress:
            return set()
        self._in_progress.add(key)
        acquired: Set[str] = set()
        env = self._env_for(cls, fn)

        def visit(node: ast.AST) -> None:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)) and node is not fn:
                return
            if isinstance(node, ast.With):
                for item in node.items:
                    g = self._lock_of(item.context_expr, env, scan, cls)
                    if g:
                        acquired.add(g)
            if isinstance(node, ast.Call):
                sub = self._callee_summary(node, env, scan, cls)
                acquired.update(sub)
            if isinstance(node, ast.Attribute) and isinstance(
                    node.ctx, ast.Load):
                acquired.update(self._prop_summary(node, env, scan))
            if isinstance(node, ast.Assign) and isinstance(
                    node.targets[0], ast.Name):
                got = self.infer(node.value, env, scan)
                if got is not None:
                    env[node.targets[0].id] = got
            for child in ast.iter_child_nodes(node):
                visit(child)

        visit(fn)
        self._in_progress.discard(key)
        self._summaries[key] = acquired
        return acquired

    def _callee_summary(self, call: ast.Call, env, scan,
                        cls) -> Set[str]:
        target = self.resolve_call(call, env, scan, cls)
        got = self._target_fn(target)
        if not got:
            return set()
        owner, fn, src = got
        if fn is None:
            return set()
        out = set(self.may_acquire(owner, fn, src))
        if owner is not None:
            req = self._cls_requires(owner, fn.name)
            if req:
                g = self._cls_lock(owner, req)
                if g:
                    out.discard(g)  # the caller already holds it
        return out

    def _prop_summary(self, node: ast.Attribute, env, scan) -> Set[str]:
        base = self.infer(node.value, env, scan)
        if not isinstance(base, ClassInfo):
            return set()
        for c in self._base_chain(base):
            if node.attr in c.props:
                return self.may_acquire(c, c.methods[node.attr], c.scan)
        return set()

    # -- lock expression resolution --------------------------------------

    def _lock_of(self, expr: ast.AST, env, scan: Scan,
                 cls: Optional[ClassInfo]) -> Optional[str]:
        if isinstance(expr, ast.Name):
            return scan.mod_locks.get(expr.id)
        if isinstance(expr, ast.Attribute):
            base = self.infer(expr.value, env, scan)
            if isinstance(base, ClassInfo):
                return self._cls_lock(base, expr.attr)
        return None

    def _env_for(self, cls: Optional[ClassInfo],
                 fn: ast.FunctionDef,
                 outer: Optional[Dict[str, object]] = None
                 ) -> Dict[str, object]:
        env: Dict[str, object] = dict(outer) if outer else {}
        args = list(fn.args.posonlyargs) + list(fn.args.args) + \
            list(fn.args.kwonlyargs)
        for a in args:
            t = _ann_name(a.annotation)
            if t and self.classes.get(t):
                env[a.arg] = self.classes[t]
            else:
                env.pop(a.arg, None)  # param shadows any closure binding
        if cls is not None and args and args[0].arg == "self":
            env["self"] = cls
        return env

    # -- the main per-function rule pass ---------------------------------

    def check_function(self, cls: Optional[ClassInfo], fn: ast.FunctionDef,
                       scan: Scan,
                       outer_env: Optional[Dict[str, object]] = None) -> None:
        env = self._env_for(cls, fn, outer_env)
        parents: Dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(fn):
            for child in ast.iter_child_nodes(parent):
                parents[child] = parent
        held: List[str] = []
        allow_stack: List[Set[str]] = [_block_allow(scan, fn.lineno)]
        listener_vars: Set[str] = set()
        in_init = fn.name in ("__init__", "__new__", "__post_init__")
        nested: List[Tuple[ast.FunctionDef, Dict[str, object]]] = []
        checked_writes: Set[int] = set()

        req = self._cls_requires(cls, fn.name) if cls else None
        req_global = self._cls_lock(cls, req) if (cls and req) else None
        if req_global:
            held.append(req_global)

        def allowed(rule: str, line: int) -> bool:
            if rule in _line_allow(scan, line):
                return True
            return any(rule in s for s in allow_stack)

        def report(rule: str, line: int, msg: str) -> None:
            if not allowed(rule, line):
                self.findings.append(Finding(rule, scan.path, line, msg))

        def note_edges(inner: Set[str], line: int) -> None:
            for h in held:
                for m in inner:
                    if m == h:
                        report("lock-order", line,
                               f"re-entrant acquisition of lock '{h}'")
                        continue
                    self.edges.setdefault((h, m), (scan.path, line))
                    if allowed("lock-order", line):
                        self.edge_allowed.add((h, m))

        def check_field_access(node: ast.Attribute, owner: ClassInfo,
                               field: str) -> None:
            guard = self._cls_guard(owner, field)
            if not guard:
                return
            gcls, lockattr, mode = guard
            is_write = self._is_write(node, parents, checked_writes)
            if mode == "w" and not is_write:
                return
            need = self._cls_lock(gcls, lockattr)
            if need is None or need in held:
                return
            verb = "written" if is_write else "read"
            report("guarded-field", node.lineno,
                   f"field '{field}' ({verb}) is guarded by lock "
                   f"'{need}' which is not held here")

        def check_call(node: ast.Call) -> None:
            # R4 — epoch fencing outside storage.py
            fname = None
            if isinstance(node.func, ast.Attribute):
                fname = node.func.attr
            elif isinstance(node.func, ast.Name):
                fname = node.func.id
            if (fname in _EPOCH_FENCED
                    and Path(scan.path).name != "storage.py"
                    and not any(k.arg == "expect_epoch"
                                for k in node.keywords)):
                report("epoch-fence", node.lineno,
                       f"call to {fname}() outside storage.py must pass "
                       "expect_epoch=")

            target = self.resolve_call(node, env, scan, cls)

            strict_held = [h for h in held if h not in self.blocking_ok]
            if strict_held:
                # R3 — blocking work lexically under a lock
                block = self._blocking_reason(node, target, env, scan)
                if block:
                    report("blocking-under-lock", node.lineno,
                           f"{block} under lock '{strict_held[-1]}'")
                # R6 — telemetry publication under a strict lock: histogram
                # .observe() takes the per-instrument 'metrics' lock and
                # span .emit() can take 'trace-rings' on a thread's first
                # emit; both must run after release (counter .inc() /
                # gauge .set() are lock-free and stay legal anywhere).
                # blocking-ok step locks are exempt (their inward edges to
                # 'metrics'/'trace-rings' are declared in annotations.py).
                if (isinstance(node.func, ast.Attribute)
                        and node.func.attr in ("observe", "emit")):
                    report("obs-under-lock", node.lineno,
                           f".{node.func.attr}() publishes telemetry under "
                           f"lock '{strict_held[-1]}'; record under the "
                           "lock, observe/emit after release")
            if held:
                # R5 — listener callbacks under any lock
                if (isinstance(node.func, ast.Name)
                        and node.func.id in listener_vars):
                    report("listener-under-lock", node.lineno,
                           f"listener callback '{node.func.id}' invoked "
                           f"under lock '{held[-1]}'")
                if target and target[0] == "method":
                    owner, name = target[1], target[2]
                    if any(name in c.fires for c in self._base_chain(owner)):
                        report("listener-under-lock", node.lineno,
                               f"{owner.name}.{name}() fires listeners but "
                               f"is called under lock '{held[-1]}'")
                # R2 — transitive acquisitions through the callee
                note_edges(self._callee_summary(node, env, scan, cls),
                           node.lineno)

        def visit(node: ast.AST) -> None:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not fn:
                nested.append((node, dict(env)))
                return
            if isinstance(node, ast.Lambda):
                return
            if isinstance(node, ast.With):
                pushed = 0
                allow_stack.append(_block_allow(scan, node.lineno))
                for item in node.items:
                    g = self._lock_of(item.context_expr, env, scan, cls)
                    visit(item.context_expr)
                    if g:
                        note_edges({g}, node.lineno)
                        held.append(g)
                        pushed += 1
                    if item.optional_vars is not None:
                        visit(item.optional_vars)
                for stmt in node.body:
                    visit(stmt)
                for _ in range(pushed):
                    held.pop()
                allow_stack.pop()
                return
            if isinstance(node, ast.For):
                lv = self._listener_loop_var(node, env, scan)
                if lv:
                    listener_vars.add(lv)
                for child in ast.iter_child_nodes(node):
                    visit(child)
                if lv:
                    listener_vars.discard(lv)
                return
            if isinstance(node, ast.Call):
                check_call(node)
            if isinstance(node, ast.Attribute):
                if held:
                    note_edges(self._prop_summary(node, env, scan),
                               node.lineno)
                if not in_init:
                    base = self.infer(node.value, env, scan)
                    if isinstance(base, ClassInfo):
                        check_field_access(node, base, node.attr)
            if isinstance(node, ast.Name) and not in_init:
                g = scan.mod_guarded.get(node.id)
                if g is not None and isinstance(
                        node.ctx, (ast.Load, ast.Store, ast.Del)):
                    self._check_global_access(node, g, scan, held,
                                              parents, checked_writes,
                                              report)
            if isinstance(node, ast.Assign) and isinstance(
                    node.targets[0], ast.Name):
                got = self.infer(node.value, env, scan)
                if got is not None:
                    env[node.targets[0].id] = got
            for child in ast.iter_child_nodes(node):
                visit(child)

        visit(fn)
        for sub, sub_env in nested:
            self.check_function(cls, sub, scan, sub_env)

    def _check_global_access(self, node: ast.Name,
                             guard: Tuple[str, str], scan: Scan,
                             held: List[str], parents, checked,
                             report) -> None:
        lockvar, mode = guard
        need = scan.mod_locks.get(lockvar)
        if need is None or need in held:
            return
        is_write = self._is_write(node, parents, checked)
        if mode == "w" and not is_write:
            return
        verb = "written" if is_write else "read"
        report("guarded-field", node.lineno,
               f"module global '{node.id}' ({verb}) is guarded by lock "
               f"'{need}' which is not held here")

    @staticmethod
    def _is_write(node: ast.AST, parents: Dict[ast.AST, ast.AST],
                  checked: Set[int]) -> bool:
        ctx = getattr(node, "ctx", None)
        if isinstance(ctx, (ast.Store, ast.Del)):
            return True
        p = parents.get(node)
        if (isinstance(p, ast.Subscript) and p.value is node
                and isinstance(p.ctx, (ast.Store, ast.Del))):
            return True
        if isinstance(p, ast.Attribute) and p.value is node:
            gp = parents.get(p)
            if (isinstance(gp, ast.Call) and gp.func is p
                    and p.attr in _MUTATORS):
                return True
        return False

    def _listener_loop_var(self, node: ast.For, env,
                           scan: Scan) -> Optional[str]:
        if not isinstance(node.target, ast.Name):
            return None
        it = node.iter
        if (isinstance(it, ast.Call) and isinstance(it.func, ast.Name)
                and it.func.id in ("list", "tuple") and it.args):
            it = it.args[0]
        if isinstance(it, ast.Attribute):
            base = self.infer(it.value, env, scan)
            if isinstance(base, ClassInfo) and self._is_listener_field(
                    base, it.attr):
                return node.target.id
        return None

    def _blocking_reason(self, node: ast.Call, target, env,
                         scan: Scan) -> Optional[str]:
        fn = node.func
        if isinstance(fn, ast.Name) and fn.id == "open":
            return "open() file I/O"
        if isinstance(fn, ast.Attribute):
            base = self.infer(fn.value, env, scan)
            if isinstance(base, tuple) and base[0] == "module":
                mod = base[1]
                root = mod.split(".")[0]
                names = _BLOCKING_MODULE_CALLS.get(
                    mod, _BLOCKING_MODULE_CALLS.get(root, set()))
                if fn.attr in names:
                    return f"{mod}.{fn.attr}() blocking call"
        if target and target[0] == "method":
            owner, name = target[1], target[2]
            for c in self._base_chain(owner):
                if (c.name, name) in _BLOCKING_METHODS:
                    kind = ("queue put/close" if c.name.endswith("Holder")
                            else "JIT dispatch")
                    return f"{c.name}.{name}() {kind}"
        return None

    # -- drive everything -------------------------------------------------

    def run(self) -> List[Finding]:
        for scan in self.scans:
            for fname, fn in scan.funcs.items():
                self.check_function(None, fn, scan)
            for cls in scan.classes.values():
                for fn in cls.methods.values():
                    self.check_function(cls, fn, scan)
        self._check_lock_graph()
        self.findings.sort(key=lambda f: (f.path, f.line, f.rule))
        return self.findings

    def _closure(self, edges: Set[Tuple[str, str]]) -> Set[Tuple[str, str]]:
        adj: Dict[str, Set[str]] = {}
        for a, b in edges:
            adj.setdefault(a, set()).add(b)
        out: Set[Tuple[str, str]] = set()
        for start in adj:
            stack, seen = [start], set()
            while stack:
                cur = stack.pop()
                for nxt in adj.get(cur, ()):
                    if nxt not in seen:
                        seen.add(nxt)
                        stack.append(nxt)
            out.update((start, x) for x in seen)
        return out

    def _check_lock_graph(self) -> None:
        declared_closed = self._closure(self.declared)
        for (a, b), (path, line) in sorted(self.edges.items()):
            if (a, b) in declared_closed or (a, b) in self.edge_allowed:
                continue
            self.findings.append(Finding(
                "lock-order", path, line,
                f"undeclared nested acquisition: '{a}' -> '{b}' (declare "
                "it in annotations.LOCK_ORDER or a '# feedlint: order' "
                "comment if intended)"))
        # cycle detection over declared + observed
        graph: Dict[str, Set[str]] = {}
        for a, b in set(self.declared) | set(self.edges):
            graph.setdefault(a, set()).add(b)
        state: Dict[str, int] = {}
        cycle: List[str] = []

        def dfs(n: str, trail: List[str]) -> bool:
            state[n] = 1
            for m in sorted(graph.get(n, ())):
                if state.get(m, 0) == 1:
                    cycle.extend(trail[trail.index(n):] + [n, m]
                                 if n in trail else [n, m])
                    return True
                if state.get(m, 0) == 0 and dfs(m, trail + [m]):
                    return True
            state[n] = 2
            return False

        for n in sorted(graph):
            if state.get(n, 0) == 0 and dfs(n, [n]):
                self.findings.append(Finding(
                    "lock-order", "<lock-graph>", 0,
                    "cycle in the lock acquisition graph: "
                    + " -> ".join(cycle)))
                break


# -- file scanning --------------------------------------------------------

def scan_file(path: Path) -> Optional[Scan]:
    try:
        text = path.read_text()
        tree = ast.parse(text, filename=str(path))
    except (SyntaxError, UnicodeDecodeError, OSError):
        return None
    comments, comment_only = _collect_comments(text)
    scan = Scan(path=str(path), tree=tree, comments=comments,
                comment_only=comment_only, dotted=_dotted_of(path))
    for comment in scan.comments.values():
        m = _RE_ORDER.search(comment)
        if m:
            scan.orders.append((m.group(1), m.group(2)))
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                bound = alias.asname or alias.name.split(".")[0]
                scan.mod_imports[bound] = (alias.name if alias.asname
                                           else alias.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if node.level:  # relative import -> anchor at this package
                pkg = scan.dotted.rsplit(".", node.level)[0]
                mod = f"{pkg}.{mod}" if mod else pkg
            for alias in node.names:
                scan.from_imports[alias.asname or alias.name] = (
                    mod, alias.name)
    modbase = Path(path).stem
    for stmt in tree.body:
        if isinstance(stmt, ast.FunctionDef):
            scan.funcs[stmt.name] = stmt
        elif isinstance(stmt, (ast.Assign, ast.AnnAssign)):
            targets = (stmt.targets if isinstance(stmt, ast.Assign)
                       else [stmt.target])
            value = stmt.value
            if value is None or len(targets) != 1 or not isinstance(
                    targets[0], ast.Name):
                continue
            name = targets[0].id
            comment = _decl_comment(scan, stmt.lineno)
            if _lock_ctor(value) == "lock":
                m = _RE_LOCK_NAME.search(comment)
                scan.mod_locks[name] = (
                    m.group(1) if m else f"{modbase}.{name}")
            wm = _RE_WRITE_GUARDED.search(comment)
            gm = _RE_GUARDED.search(comment)
            if wm:
                scan.mod_guarded[name] = (wm.group(1), "w")
            elif gm:
                scan.mod_guarded[name] = (gm.group(1), "rw")
        elif isinstance(stmt, ast.ClassDef):
            scan.classes[stmt.name] = _scan_class(stmt, scan, modbase)
    return scan


def _scan_class(node: ast.ClassDef, scan: Scan, modbase: str) -> ClassInfo:
    cls = ClassInfo(name=node.name, scan=scan, node=node,
                    bases=[b.id for b in node.bases
                           if isinstance(b, ast.Name)])
    for stmt in node.body:
        if isinstance(stmt, ast.FunctionDef):
            cls.methods[stmt.name] = stmt
            if any(isinstance(d, ast.Name) and d.id == "property"
                   for d in stmt.decorator_list):
                cls.props.add(stmt.name)
            comment = _decl_comment(scan, stmt.lineno)
            m = _RE_REQUIRES.search(comment)
            if m:
                cls.requires[stmt.name] = m.group(1)
            if _RE_FIRES.search(comment):
                cls.fires.add(stmt.name)
        elif isinstance(stmt, ast.AnnAssign) and isinstance(
                stmt.target, ast.Name):
            guard = _annotated_guard(stmt.annotation)
            comment = _decl_comment(scan, stmt.lineno)
            wm = _RE_WRITE_GUARDED.search(comment)
            gm = _RE_GUARDED.search(comment)
            if guard:
                cls.guarded[stmt.target.id] = guard
            elif wm:
                cls.guarded[stmt.target.id] = (wm.group(1), "w")
            elif gm:
                cls.guarded[stmt.target.id] = (gm.group(1), "rw")
            if _RE_LISTENER_REG.search(comment):
                cls.listener_fields.add(stmt.target.id)
    for meth in cls.methods.values():
        _scan_self_assigns(cls, meth, scan, modbase)
    return cls


def _scan_self_assigns(cls: ClassInfo, meth: ast.FunctionDef, scan: Scan,
                       modbase: str) -> None:
    param_ann = {a.arg: _ann_name(a.annotation)
                 for a in (list(meth.args.posonlyargs) + list(meth.args.args)
                           + list(meth.args.kwonlyargs))}
    for node in ast.walk(meth):
        if isinstance(node, ast.AnnAssign) and isinstance(
                node.target, ast.Attribute) and isinstance(
                node.target.value, ast.Name) and \
                node.target.value.id == "self":
            t = _ann_name(node.annotation)
            if t:
                cls.attr_types.setdefault(node.target.attr, t)
            _note_field_decl(cls, node.target.attr,
                             _decl_comment(scan, node.lineno))
            continue
        if not isinstance(node, ast.Assign) or len(node.targets) != 1:
            continue
        target = node.targets[0]
        if not (isinstance(target, ast.Attribute)
                and isinstance(target.value, ast.Name)):
            continue
        recv = target.value.id
        attr = target.attr
        comment = _decl_comment(scan, node.lineno)
        if recv == "self":
            kind = _lock_ctor(node.value)
            if kind == "lock":
                m = _RE_LOCK_NAME.search(comment)
                cls.locks[attr] = (m.group(1) if m
                                   else f"{modbase}.{cls.name}.{attr}")
            elif kind == "condition":
                wrapped = _condition_target(node.value)
                if wrapped:
                    cls.aliases[attr] = wrapped
                else:
                    m = _RE_LOCK_NAME.search(comment)
                    cls.locks[attr] = (m.group(1) if m
                                       else f"{modbase}.{cls.name}.{attr}")
            _note_field_decl(cls, attr, comment)
            _note_attr_type(cls, attr, node.value, param_ann)
    # cross-object constructor assigns (``handle.intake = IntakeJob(...)``
    # through an annotated param) land on the receiver's class; same-file
    # classes resolve here, cross-file ones via _resolve_pending.
    for node in ast.walk(meth):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Attribute) and \
                isinstance(node.targets[0].value, ast.Name) and \
                node.targets[0].value.id != "self":
            recv = node.targets[0].value.id
            t = param_ann.get(recv)
            tv = _ctor_name(node.value)
            if t and tv:
                other = scan.classes.get(t)
                if other is not None:
                    other.attr_types.setdefault(node.targets[0].attr, tv)
                else:
                    cls.scan.__dict__.setdefault(
                        "_pending_attr", []).append(
                        (t, node.targets[0].attr, tv))


def _ctor_name(value: ast.AST) -> Optional[str]:
    if isinstance(value, ast.Call) and isinstance(value.func, ast.Name):
        return value.func.id
    return None


def _note_field_decl(cls: ClassInfo, attr: str, comment: str) -> None:
    wm = _RE_WRITE_GUARDED.search(comment)
    gm = _RE_GUARDED.search(comment)
    if wm:
        cls.guarded.setdefault(attr, (wm.group(1), "w"))
    elif gm:
        cls.guarded.setdefault(attr, (gm.group(1), "rw"))
    if _RE_LISTENER_REG.search(comment):
        cls.listener_fields.add(attr)


def _note_attr_type(cls: ClassInfo, attr: str, value: ast.AST,
                    param_ann: Dict[str, Optional[str]]) -> None:
    tv = _ctor_name(value)
    if tv:
        cls.attr_types.setdefault(attr, tv)
        return
    if isinstance(value, ast.Name):
        t = param_ann.get(value.id)
        if t:
            cls.attr_types.setdefault(attr, t)
        return
    if isinstance(value, ast.IfExp):
        for side in (value.body, value.orelse):
            _note_attr_type(cls, attr, side, param_ann)
    if isinstance(value, ast.BoolOp):
        for side in value.values:
            _note_attr_type(cls, attr, side, param_ann)


def _resolve_pending(scans: List[Scan]) -> None:
    by_name: Dict[str, ClassInfo] = {}
    for scan in scans:
        for cls in scan.classes.values():
            by_name.setdefault(cls.name, cls)
    for scan in scans:
        for t, attr, tv in scan.__dict__.get("_pending_attr", []):
            other = by_name.get(t)
            if other is not None:
                other.attr_types.setdefault(attr, tv)


def collect_files(paths: Sequence[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(f for f in path.rglob("*.py")
                              if "__pycache__" not in f.parts))
        elif path.suffix == ".py":
            out.append(path)
    return out


def run_paths(paths: Sequence[str],
              extra_order: Sequence[Tuple[str, str]] = ()
              ) -> List[Finding]:
    scans = [s for s in (scan_file(f) for f in collect_files(paths))
             if s is not None]
    _resolve_pending(scans)
    linter = Linter(scans, extra_order=extra_order)
    return linter.run()


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="feedlint",
        description="concurrency-invariant analyzer for the ingestion core")
    parser.add_argument("paths", nargs="+", help="files or directories")
    parser.add_argument("--debug-graph", action="store_true",
                        help="print the observed lock acquisition edges")
    args = parser.parse_args(argv)
    scans = [s for s in (scan_file(f) for f in collect_files(args.paths))
             if s is not None]
    _resolve_pending(scans)
    linter = Linter(scans)
    findings = linter.run()
    if args.debug_graph:
        locks = sorted({g for s in scans for g in
                        list(s.mod_locks.values())
                        + [v for c in s.classes.values()
                           for v in c.locks.values()]})
        print(f"locks: {', '.join(locks)}")
        for (a, b), (path, line) in sorted(linter.edges.items()):
            print(f"edge: {a} -> {b}  ({path}:{line})")
    for f in findings:
        print(f)
    n = len(findings)
    print(f"feedlint: {n} finding{'s' if n != 1 else ''} "
          f"in {len(scans)} files")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
