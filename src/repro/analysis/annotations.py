"""Annotation grammar and canonical lock hierarchy for feedlint.

The concurrent core documents its lock discipline with lightweight,
machine-readable source annotations.  All of them are trailing comments,
so they cost nothing at runtime and survive refactors reviewably:

``# lock-name: <name>``
    On the line that creates a lock (``self._lock = threading.Lock()`` or
    a module-level ``_lock = threading.Lock()``).  Gives the lock a
    *global* name used in the acquisition-order graph.  Two locks may
    share a name when they are literally the same object passed across
    objects (e.g. the intake job borrows the feed-handle lock).  A
    ``threading.Condition(self._lock)`` is auto-detected as an alias of
    the wrapped lock and needs no annotation.

``# guarded-by: <lock-attr>``
    On the line that first assigns a field (usually in ``__init__``, or a
    module-level global).  Every read AND write of that field must happen
    inside ``with <lock>`` or in a method marked ``# requires-lock``.

``# write-guarded-by: <lock-attr>``
    Like ``guarded-by`` but only *mutations* are checked.  Used for
    single-word fields that are deliberately read lock-free (GIL-atomic
    reference reads documented in docs/CONCURRENCY.md).

``# requires-lock: <lock-attr>``
    On a ``def`` line.  The method's contract is "caller holds this
    lock"; its body is analyzed as if the lock were held, and the
    ``_locked`` suffix convention in storage.py maps onto it.

``# fires-listeners``
    On a ``def`` line.  The method invokes subscriber callbacks, so it
    must never be called while a lock is held (rule R5).

``# listener-registry``
    On a guarded field declaration holding subscriber callbacks; calling
    an element of it under a lock is an R5 violation.

``# feedlint: order <outer> -> <inner>``
    Module-level declaration of an allowed nested acquisition, unioned
    with LOCK_ORDER below (test fixtures use this form).

``# feedlint: allow[<rule>[,<rule>...]] <reason>``
    Suppress a finding on this line (or, on a ``with``/``def`` line, in
    that whole block).  Reasons are mandatory by convention and audited
    in docs/CONCURRENCY.md — e.g. storage.py flushes npz segments under
    the partition lock *deliberately* so flush+manifest stay atomic.
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

#: Rule identifiers, also the tags accepted by ``feedlint: allow[...]``.
RULES: Dict[str, str] = {
    "guarded-field": "R1 guarded fields accessed only under their lock",
    "lock-order": "R2 nested lock acquisitions follow the declared order",
    "blocking-under-lock": "R3 no JIT/file-I/O/sleep/queue-put under a lock",
    "epoch-fence": "R4 conditional storage writes pass expect_epoch",
    "listener-under-lock": "R5 listener callbacks fire outside locks",
    "obs-under-lock": "R6 no histogram observe / span emit under a "
                      "core lock (blocking-ok step locks exempt)",
}

#: Canonical allowed nested acquisitions, ``(outer, inner)`` by global
#: lock name.  This *is* the lock hierarchy of the core (see
#: docs/CONCURRENCY.md for the prose version).  feedlint fails on any
#: observed nesting not in the transitive closure of this list, and on
#: any cycle.
LOCK_ORDER: List[Tuple[str, str]] = [
    # RepairJob.step serializes on repair-step, then touches partitions,
    # reference tables (version probes + runner re-enrichment), its own
    # event journal, holder backlogs (feed_busy yield check) and the
    # predeploy executable cache (runner invocations).
    ("repair-step", "partition"),
    ("repair-step", "ref-table"),
    ("repair-step", "ref-build"),
    ("repair-step", "repair-events"),
    ("repair-step", "holder"),
    ("repair-step", "predeploy"),
    # CompactionJob.step: same shape — partitions + holder backlog probe.
    ("compaction-step", "partition"),
    ("compaction-step", "holder"),
    # FeedHandle.scale_up/_add_partition_locked registers the new holder
    # with the process-wide registry while holding the handle lock.
    ("handle", "holder-registry"),
    # RefTable.snapshot: the build lock admits one column-sort at a time
    # and takes the table write lock briefly at both ends.
    ("ref-build", "ref-table"),
    # CheckpointJob.step (core/durability.py) serializes on
    # checkpoint-step, then syncs the WAL, reads the ledger, flushes
    # storage partitions, and snapshots repair's event journal plus
    # reference-table fingerprints/versions for the checkpoint record.
    ("checkpoint-step", "wal"),
    ("checkpoint-step", "wal-ledger"),
    ("checkpoint-step", "partition"),
    ("checkpoint-step", "repair-events"),
    ("checkpoint-step", "ref-table"),
    ("checkpoint-step", "ref-build"),
    # Observability (core/obs): the blocking-ok step locks may observe
    # histograms (tiny per-instrument 'metrics' lock) and emit spans
    # (whose first-emit-per-thread registration takes 'trace-rings');
    # hot-path emit sites run outside strict locks (rule R6), so these
    # are the only declared inward edges.
    ("repair-step", "metrics"),
    ("repair-step", "trace-rings"),
    ("compaction-step", "metrics"),
    ("compaction-step", "trace-rings"),
    ("checkpoint-step", "metrics"),
    ("checkpoint-step", "trace-rings"),
    # No ("wal", "metrics") edge on purpose: IntakeLog times fsyncs
    # under the wal lock but observes the histogram only after release.
]


def guarded_by(lock: str) -> Dict[str, Any]:
    """Annotation helper: ``x: Annotated[int, guarded_by("_lock")]``.

    The comment convention above is what the core uses (it works on
    plain assignments); this helper is the equivalent for annotated
    class-level declarations and is recognized by feedlint too.  It
    returns inert metadata — nothing at runtime reads it.
    """
    return {"guarded_by": lock}


def write_guarded_by(lock: str) -> Dict[str, Any]:
    """``Annotated`` twin of ``# write-guarded-by: <lock>``."""
    return {"write_guarded_by": lock}
