"""Static concurrency-invariant analysis for the ingestion core.

``feedlint`` (repro.analysis.feedlint) is a custom ``ast``-based analyzer
that machine-checks the lock discipline the concurrent core relies on —
guarded-field access, the inter-module lock acquisition order, no blocking
work under a lock, epoch-fenced conditional storage writes, and listener
callbacks fired outside the write lock.  The annotation grammar and the
canonical lock hierarchy live in repro.analysis.annotations; the full
human story is docs/CONCURRENCY.md.

Run it as::

    python -m repro.analysis.feedlint src/

It is wired as a blocking CI job; a clean tree exits 0.
"""

from repro.analysis.annotations import LOCK_ORDER, guarded_by  # noqa: F401
