from repro.train.optimizer import OptConfig, adamw_init, adamw_update  # noqa: F401
from repro.train.steps import (  # noqa: F401
    TrainState,
    init_train_state,
    make_train_step,
    train_state_axes,
    train_state_shapes,
)
