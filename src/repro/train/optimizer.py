"""AdamW in pure JAX, with the memory knobs the trillion-parameter dry-run
configs require:

  * ``state_dtype``   — bf16 first/second moments for the huge archs,
  * ``factored_v``    — Adafactor-style rank-1 second moment for >=2-D
                        params (v is stored as row/col means), shrinking
                        optimizer state from 2x to ~1x param bytes,
  * global-norm gradient clipping, decoupled weight decay,
  * linear-warmup + cosine-decay schedule.

Optimizer state mirrors the parameter tree (same logical axes), so the same
sharding rules shard it — ZeRO-style, for free, through ``tree_shardings``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"     # moments dtype
    factored_v: bool = False         # rank-1 second moment for >=2-D params


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    floor = cfg.min_lr_ratio
    return cfg.lr * warm * (floor + (1 - floor) * cos)


def _factored(p: jax.Array) -> bool:
    return p.ndim >= 2


def adamw_init(cfg: OptConfig, params: Any) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.state_dtype)

    def m_like(p):
        return jnp.zeros(p.shape, dt)

    def v_like(p):
        if cfg.factored_v and _factored(p):
            return {"row": jnp.zeros(p.shape[:-1], jnp.float32),
                    "col": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                     jnp.float32)}
        return jnp.zeros(p.shape, dt)

    return {"m": jax.tree.map(m_like, params),
            "v": jax.tree.map(v_like, params)}


def _vhat(cfg: OptConfig, v, g2: jax.Array) -> Tuple[Any, jax.Array]:
    """Update the second moment and return (new_v, per-element estimate)."""
    if isinstance(v, dict):                       # factored
        row = cfg.b2 * v["row"] + (1 - cfg.b2) * jnp.mean(g2, axis=-1)
        col = cfg.b2 * v["col"] + (1 - cfg.b2) * jnp.mean(g2, axis=-2)
        denom = jnp.maximum(jnp.mean(row, axis=-1, keepdims=True), 1e-30)
        est = (row / denom)[..., None] * col[..., None, :]
        return {"row": row, "col": col}, est
    new_v = (cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g2)
    return new_v.astype(v.dtype), new_v


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: OptConfig, params: Any, grads: Any,
                 opt_state: Dict[str, Any], step: jax.Array
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** (step.astype(jnp.float32) + 1)
    b2c = 1 - cfg.b2 ** (step.astype(jnp.float32) + 1)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])

    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        g = g.astype(jnp.float32) * clip
        m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v2, vest = _vhat(cfg, v, jnp.square(g))
        mhat = m2 / b1c
        vhat = (vest.astype(jnp.float32) if not isinstance(v2, dict)
                else vest) / b2c
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                            # decoupled weight decay
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_m.append(m2.astype(m.dtype))
        new_v.append(v2)

    params = jax.tree.unflatten(tdef, new_p)
    opt_state = {"m": jax.tree.unflatten(tdef, new_m),
                 "v": jax.tree.unflatten(tdef, new_v)}
    return params, opt_state, {"grad_norm": gnorm, "lr": lr}


def opt_state_axes(cfg: OptConfig, param_axes: Any) -> Dict[str, Any]:
    """Logical axes for the optimizer state (mirrors params; factored v
    drops the factored dim)."""
    def v_axes(ax):
        if cfg.factored_v and len(ax) >= 2:
            return {"row": tuple(ax[:-1]), "col": tuple(ax[:-2] + ax[-1:])}
        return ax

    is_ax = lambda x: isinstance(x, tuple) and all(  # noqa: E731
        e is None or isinstance(e, str) for e in x)
    return {"m": param_axes,
            "v": jax.tree.map(v_axes, param_axes, is_leaf=is_ax)}
