"""Train state + jit-able train step (one definition for all 10 archs).

The step is built once per (model config, opt config) and AOT-compiles in
the dry-run exactly like the enrichment computing jobs — same predeploy
pattern, one level up.  Microbatch gradient accumulation happens inside the
step via lax.scan (keeps the HLO O(1) in the accumulation factor); the
batch dims stay sharded over (pod, data) so XLA inserts the gradient
reduce-scatter/all-reduce where the sharding demands it.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import api
from repro.models import params as P
from repro.train.optimizer import (OptConfig, adamw_init, adamw_update,
                                   opt_state_axes)

TrainState = Dict[str, Any]        # {"params", "opt", "step"}


def init_train_state(cfg: ModelConfig, opt: OptConfig,
                     rng: jax.Array) -> TrainState:
    params = api.init_params(cfg, rng)
    return {"params": params, "opt": adamw_init(opt, params),
            "step": jnp.zeros((), jnp.int32)}


def train_state_shapes(cfg: ModelConfig, opt: OptConfig) -> TrainState:
    """ShapeDtypeStructs for dry-run lowering (no allocation)."""
    params = api.param_shapes(cfg)
    dt = jnp.dtype(opt.state_dtype)

    def m_like(p):
        return jax.ShapeDtypeStruct(p.shape, dt)

    def v_like(p):
        if opt.factored_v and len(p.shape) >= 2:
            return {"row": jax.ShapeDtypeStruct(p.shape[:-1], jnp.float32),
                    "col": jax.ShapeDtypeStruct(
                        p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return jax.ShapeDtypeStruct(p.shape, dt)

    return {"params": params,
            "opt": {"m": jax.tree.map(m_like, params),
                    "v": jax.tree.map(v_like, params)},
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def train_state_axes(cfg: ModelConfig, opt: OptConfig) -> TrainState:
    axes = api.param_axes(cfg)
    return {"params": axes, "opt": opt_state_axes(opt, axes),
            "step": ()}


def make_train_step(cfg: ModelConfig, opt: OptConfig,
                    microbatches: int = 1, aux_weight: float = 0.01):
    """Returns step(state, batch) -> (state, metrics).  ``microbatches``
    splits the per-step batch along dim 0 and accumulates grads in fp32."""

    def loss_fn(params, batch):
        loss, metrics = api.loss(cfg, params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def accumulate(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape((microbatches, b // microbatches)
                             + x.shape[1:])

        mb = jax.tree.map(split, batch)
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, microbatch):
            acc, loss_acc = carry
            (loss, metrics), grads = grad_fn(params, microbatch)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / microbatches,
                acc, grads)
            return (acc, loss_acc + loss / microbatches), metrics

        (grads, loss), metrics = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32)), mb)
        metrics = jax.tree.map(lambda x: x[-1], metrics)
        return loss, metrics, grads

    def step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        loss, metrics, grads = accumulate(state["params"], batch)
        params, opt_state, om = adamw_update(
            opt, state["params"], grads, state["opt"], state["step"])
        new_state = {"params": params, "opt": opt_state,
                     "step": state["step"] + 1}
        out = {"loss": loss, **{k: v for k, v in metrics.items()
                                if k != "loss"}, **om}
        return new_state, out

    return step
