"""The LM data plane: an IDEA feed whose computing jobs tokenize (and
optionally safety-filter) the incoming stream, with a sink that packs the
enriched records into dense (B, S) training batches.

This is the paper's pipeline doing real work for training: the
safety-check UDF's SensitiveWords lexicon is *reference data* — upserting a
keyword mid-training immediately changes which records enter the training
stream (Model-2 freshness), with zero recompilation (predeployed jobs).
Adaptive data curation for free.
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.core import FeedConfig, FeedManager, SyntheticAdapter
from repro.core.enrich import queries as Q
from repro.data.packing import StreamPacker


class FeedDataSource:
    """Iterator of packed LM batches, produced by a live IDEA feed."""

    def __init__(self, manager: FeedManager, vocab_size: int,
                 seq_len: int, batch_size: int,
                 total_records: int = 100_000,
                 frame_size: int = 256,
                 safety_filter: bool = False,
                 num_partitions: int = 2,
                 seed: int = 0,
                 queue_batches: int = 8):
        self.packer = StreamPacker(seq_len, batch_size)
        self._q: "queue.Queue[Optional[Dict]]" = queue.Queue(queue_batches)
        self._packer_lock = threading.Lock()
        tokenize = Q.make_lm_tokenize(vocab_size)
        if safety_filter:
            udf = Q.chain("curated_lm_stream", Q.UDF2, tokenize)
        else:
            udf = tokenize
        self.filtered = 0

        def sink(batch: Dict[str, np.ndarray]) -> None:
            keep = batch["valid"]
            if safety_filter:
                red = batch["safety_check_flag"] != 0
                self.filtered += int((keep & red).sum())
                keep = keep & ~red
            with self._packer_lock:
                for i in np.where(keep)[0]:
                    ids = [int(t) for t in batch["lm_tokens"][i] if t != 0]
                    if not ids:
                        continue
                    out = self.packer.add(ids)
                    if out is not None:
                        self._q.put(out)

        cfg = FeedConfig(name=f"lm-data-{seed}", udf=udf,
                         batch_size=frame_size,
                         num_partitions=num_partitions, sink=sink)
        self.handle = manager.start(
            cfg, SyntheticAdapter(total=total_records,
                                  frame_size=frame_size, seed=seed))
        self._drained = False
        threading.Thread(target=self._drain, daemon=True).start()

    def _drain(self):
        try:
            self.handle.join()
            out = self.packer.flush()
            if out is not None:
                self._q.put(out)
        finally:
            self._q.put(None)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            item = self._q.get()
            if item is None:
                return
            yield item

    def stop(self):
        self.handle.stop()
