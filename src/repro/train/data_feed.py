"""The LM data plane: an IDEA ingestion *plan* whose computing jobs
tokenize (and optionally safety-filter) the incoming stream, with a tee
sink that packs the enriched records into dense (B, S) training batches.

This is the paper's pipeline doing real work for training, now built on
the declarative plan API (core/plan.py):

    pipeline(adapter).parse(...).enrich(UDF2).enrich(tokenize)
        .filter(safe).tee(packer_sink)[.store(...)]

The safety UDF and the tokenizer fuse into ONE predeployed apply per
batch; the filter stage clears ``valid`` for flagged records inside that
same fused executable, so curation costs zero extra dispatches.  The
safety-check UDF's SensitiveWords lexicon is *reference data* — upserting
a keyword mid-training immediately changes which records enter the
training stream (Model-2 freshness), with zero recompilation (predeployed
jobs).  Adaptive data curation for free.  With ``store_enriched`` the same
plan tees the enriched stream to the column store as well — training data
plane and durable enriched dataset from one ingestion pass.
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.core import FeedManager, SyntheticAdapter, pipeline
from repro.core.enrich import queries as Q
from repro.data.packing import StreamPacker


class FeedDataSource:
    """Iterator of packed LM batches, produced by a live IDEA feed."""

    def __init__(self, manager: FeedManager, vocab_size: int,
                 seq_len: int, batch_size: int,
                 total_records: int = 100_000,
                 frame_size: int = 256,
                 safety_filter: bool = False,
                 num_partitions: int = 2,
                 seed: int = 0,
                 queue_batches: int = 8,
                 store_enriched: bool = False):
        self.packer = StreamPacker(seq_len, batch_size)
        self._q: "queue.Queue[Optional[Dict]]" = queue.Queue(queue_batches)
        self._packer_lock = threading.Lock()
        self.filtered = 0

        def sink(batch: Dict[str, np.ndarray]) -> None:
            if safety_filter:
                # red rows already have valid=False (filter stage); the
                # flag column still flows for observability
                self.filtered += int((batch["safety_check_flag"] != 0).sum())
            with self._packer_lock:
                for i in np.where(batch["valid"])[0]:
                    ids = [int(t) for t in batch["lm_tokens"][i] if t != 0]
                    if not ids:
                        continue
                    out = self.packer.add(ids)
                    if out is not None:
                        self._q.put(out)

        p = (pipeline(SyntheticAdapter(total=total_records,
                                       frame_size=frame_size, seed=seed),
                      f"lm-data-{seed}")
             .parse(batch_size=frame_size)
             .options(num_partitions=num_partitions))
        if safety_filter:
            p.enrich(Q.UDF2)
        p.enrich(Q.make_lm_tokenize(vocab_size))
        if safety_filter:
            p.filter(lambda b: b["safety_check_flag"] == 0, name="safe_only")
        p.tee(sink, name="lm_data_plane")
        if store_enriched:
            p.store()
        self.handle = manager.submit(p)
        self._drained = False
        threading.Thread(target=self._drain, daemon=True).start()

    def _drain(self):
        try:
            self.handle.join()
            out = self.packer.flush()
            if out is not None:
                self._q.put(out)
        finally:
            self._q.put(None)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            item = self._q.get()
            if item is None:
                return
            yield item

    def stop(self):
        self.handle.stop()
