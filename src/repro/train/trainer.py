"""Fault-tolerant training loop fed by the IDEA pipeline.

Responsibilities:
  * jit the train step once (predeploy pattern), donate the state buffers,
  * checkpoint every ``ckpt_every`` steps (async, atomic, keep-k),
  * on a step failure: restore the latest checkpoint and resume — bounded
    restarts, mirroring the feed manager's computing-job retry,
  * surface throughput + loss metrics.

On a real cluster the same loop runs under ``jax.distributed`` with the
production mesh from launch/mesh.py; CPU runs exercise every code path at
smoke scale (tests/test_train.py).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.ckpt import AsyncCheckpointer, latest_step, restore
from repro.configs.base import ModelConfig
from repro.train.optimizer import OptConfig
from repro.train.steps import init_train_state, make_train_step

log = logging.getLogger(__name__)


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    microbatches: int = 1
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    max_restarts: int = 2
    log_every: int = 10
    seed: int = 0


class Trainer:
    def __init__(self, model_cfg: ModelConfig, opt_cfg: OptConfig,
                 tcfg: TrainerConfig):
        self.model_cfg = model_cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.step_fn = jax.jit(
            make_train_step(model_cfg, opt_cfg, tcfg.microbatches),
            donate_argnums=(0,))
        self.state = init_train_state(model_cfg, opt_cfg,
                                      jax.random.key(tcfg.seed))
        self.ckpt = (AsyncCheckpointer(tcfg.ckpt_dir, tcfg.ckpt_keep)
                     if tcfg.ckpt_dir else None)
        self.history: List[Dict[str, float]] = []
        self.restarts = 0
        if tcfg.ckpt_dir and latest_step(tcfg.ckpt_dir) is not None:
            self._restore()

    # ----------------------------------------------------------------- ckpt
    def _save(self, step: int) -> None:
        if self.ckpt is not None:
            self.ckpt.save(step, self.state)

    def _restore(self) -> None:
        step = latest_step(self.tcfg.ckpt_dir)
        log.warning("restoring from checkpoint step %s", step)
        self.state = restore(self.tcfg.ckpt_dir, self.state, step)

    # ------------------------------------------------------------------ run
    def run(self, batches: Iterator[Dict[str, np.ndarray]],
            fault_hook=None) -> List[Dict[str, float]]:
        """Consume ``batches`` until ``steps`` steps are done.  On failure,
        restore + resume (replaying the stream from where it stands —
        at-least-once over data, exactly-once over optimizer steps thanks
        to the step counter in the checkpoint)."""
        it = iter(batches)
        t0 = time.perf_counter()
        while int(self.state["step"]) < self.tcfg.steps:
            try:
                batch = next(it)
            except StopIteration:
                log.warning("data stream ended at step %s",
                            int(self.state["step"]))
                break
            try:
                step_before = int(self.state["step"])
                if fault_hook is not None:
                    fault_hook(step_before)
                self.state, metrics = self.step_fn(self.state, batch)
                step = step_before + 1
                if step % self.tcfg.log_every == 0 or \
                        step == self.tcfg.steps:
                    m = {k: float(v) for k, v in metrics.items()}
                    m["step"] = step
                    m["wall_s"] = time.perf_counter() - t0
                    self.history.append(m)
                if self.tcfg.ckpt_dir and step % self.tcfg.ckpt_every == 0:
                    self._save(step)
            except Exception:
                self.restarts += 1
                if self.restarts > self.tcfg.max_restarts or \
                        self.ckpt is None:
                    raise
                # donated buffers may be invalid: rebuild from checkpoint
                self.state = init_train_state(
                    self.model_cfg, self.opt_cfg,
                    jax.random.key(self.tcfg.seed))
                if latest_step(self.tcfg.ckpt_dir) is not None:
                    self._restore()
                log.warning("restart %d at step %s", self.restarts,
                            int(self.state["step"]))
        if self.ckpt is not None:
            self._save(int(self.state["step"]))
            self.ckpt.wait()
        return self.history
