"""int8 error-feedback gradient compression (distributed-optimization
trick for bandwidth-bound DP meshes).

Used on the explicit-collective path (shard_map DP): each worker quantizes
its local gradient to int8 with a per-block fp32 scale before the
all-reduce, and keeps the quantization residual in an error buffer that is
added back into the next step's gradient — the classic EF-SGD construction
that keeps SGD/Adam convergence despite 4x less collective traffic.

Pure functions; the trainer owns the error-buffer tree.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x: jax.Array) -> Tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    return jnp.pad(flat, (0, pad)), flat.shape[0]


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """g -> (int8 values, per-block fp32 scales)."""
    flat, _ = _pad_to_block(g.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize(q: jax.Array, scale: jax.Array, shape, n: int) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]
    return flat.reshape(shape)


def compress_tree(grads: Any, error: Any) -> Tuple[Any, Any]:
    """(grads + error) -> (compressed tree of (q, scale), new error tree).

    The returned error is the residual (g + e) - dequant(quant(g + e)).
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize(corrected)
        deq = dequantize(q, s, g.shape, g.size)
        return (q, s), corrected - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    comp = jax.tree.unflatten(tdef, [c for c, _ in out])
    new_err = jax.tree.unflatten(tdef, [e for _, e in out])
    return comp, new_err


def decompress_tree(comp: Any, like: Any) -> Any:
    def one(c, g):
        q, s = c
        return dequantize(q, s, g.shape, g.size).astype(jnp.float32)

    flat_c = jax.tree.leaves(comp, is_leaf=lambda x: isinstance(x, tuple))
    flat_g, tdef = jax.tree.flatten(like)
    return jax.tree.unflatten(tdef, [one(c, g) for c, g
                                     in zip(flat_c, flat_g)])


def init_error(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def psum_compressed(grads: Any, error: Any, axis_name: str
                    ) -> Tuple[Any, Any]:
    """Error-feedback compressed data-parallel mean, for use inside
    shard_map: quantize locally, move int8 payloads (+1.5% fp32 scales)
    over the interconnect via all_gather, dequantize-and-mean locally.
    Exact mean of the per-worker *dequantized* gradients — the EF residual
    accounts for precisely the local quantization error."""
    comp, new_err = compress_tree(grads, error)

    def reduce_one(c, g):
        q, s = c
        n = jax.lax.psum(1, axis_name)
        qall = jax.lax.all_gather(q, axis_name)        # int8 on the wire
        sall = jax.lax.all_gather(s, axis_name)
        per = qall.astype(jnp.float32) * sall[:, :, None]
        mean = jnp.sum(per, axis=0) / n
        return mean.reshape(-1)[:g.size].reshape(g.shape)

    flat_c = jax.tree.leaves(comp, is_leaf=lambda x: isinstance(x, tuple)
                             and len(x) == 2)
    flat_g, tdef = jax.tree.flatten(grads)
    out = [reduce_one(c, g) for c, g in zip(flat_c, flat_g)]
    return jax.tree.unflatten(tdef, out), new_err
