"""Trainer substrate tests: optimizer, train step, checkpoint/restore,
fault-tolerant resume, gradient compression, and the IDEA-fed data plane."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import latest_step, restore, save
from repro.configs import smoke_config
from repro.core import FeedManager, RefStore
from repro.core.enrich import queries as Q
from repro.data.packing import StreamPacker
from repro.models import api
from repro.train import OptConfig, init_train_state, make_train_step
from repro.train import compression as C
from repro.train.data_feed import FeedDataSource
from repro.train.trainer import Trainer, TrainerConfig

CFG = smoke_config("deepseek-coder-33b")
OPT = OptConfig(lr=1e-3, warmup_steps=2, total_steps=50, weight_decay=0.01)


def _batches(n, b=2, s=32, seed=0, vocab=None):
    rng = np.random.default_rng(seed)
    v = vocab or CFG.vocab_size
    for _ in range(n):
        t = rng.integers(3, v, (b, s)).astype(np.int32)
        yield {"tokens": t, "targets": np.roll(t, -1, 1)}


# ---------------------------------------------------------------------------
# optimizer / step
# ---------------------------------------------------------------------------

def test_train_step_decreases_loss():
    state = init_train_state(CFG, OPT, jax.random.key(0))
    step = jax.jit(make_train_step(CFG, OPT))
    batch = next(_batches(1))
    losses = []
    for _ in range(20):
        state, m = step(state, batch)     # overfit one batch
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::5]
    assert int(state["step"]) == 20


def test_microbatch_accumulation_matches_full_batch():
    opt = OptConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                    weight_decay=0.0, grad_clip=1e9)
    s1 = init_train_state(CFG, opt, jax.random.key(1))
    s2 = jax.tree.map(jnp.copy, s1)
    batch = next(_batches(1, b=4))
    step1 = jax.jit(make_train_step(CFG, opt, microbatches=1))
    step2 = jax.jit(make_train_step(CFG, opt, microbatches=2))
    s1, m1 = step1(s1, batch)
    s2, m2 = step2(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)


def test_factored_adam_state_is_smaller_and_trains():
    opt = OptConfig(lr=1e-3, factored_v=True, state_dtype="bfloat16",
                    warmup_steps=0, total_steps=50)
    state = init_train_state(CFG, opt, jax.random.key(0))
    full = sum(x.size for x in jax.tree.leaves(
        init_train_state(CFG, OPT, jax.random.key(0))["opt"]))
    fact = sum(x.size for x in jax.tree.leaves(state["opt"]))
    assert fact < 0.6 * full
    step = jax.jit(make_train_step(CFG, opt))
    batch = next(_batches(1))
    l0 = None
    for _ in range(15):
        state, m = step(state, batch)
        l0 = l0 or float(m["loss"])
    assert float(m["loss"]) < l0


# ---------------------------------------------------------------------------
# checkpoint / restore
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_retention(tmp_path):
    state = init_train_state(CFG, OPT, jax.random.key(0))
    for s in (1, 2, 3, 4):
        save(str(tmp_path), s, state, keep=2)
    assert latest_step(str(tmp_path)) == 4
    assert sorted(os.listdir(tmp_path)) == ["step_00000003",
                                            "step_00000004"]
    back = restore(str(tmp_path), state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_detects_corruption(tmp_path):
    state = {"w": jnp.arange(10, dtype=jnp.float32)}
    path = save(str(tmp_path), 1, state)
    leaf = os.path.join(path, "leaf_00000.npy")
    arr = np.load(leaf)
    arr[0] = 999
    np.save(leaf, arr)
    with pytest.raises(IOError, match="checksum"):
        restore(str(tmp_path), state)


def test_trainer_resumes_after_injected_failure(tmp_path):
    tcfg = TrainerConfig(steps=12, ckpt_dir=str(tmp_path), ckpt_every=4,
                         log_every=1, max_restarts=2)
    trainer = Trainer(CFG, OPT, tcfg)
    fails = {"left": 1}

    def fault_hook(step):
        if step == 6 and fails["left"]:
            fails["left"] -= 1
            raise RuntimeError("injected node failure")

    hist = trainer.run(_batches(100), fault_hook=fault_hook)
    assert trainer.restarts == 1
    assert int(trainer.state["step"]) == 12
    # resumed from step 4 checkpoint, not from scratch
    steps = [h["step"] for h in hist]
    assert 12 in steps


def test_trainer_fed_by_idea_pipeline():
    """End-to-end: IDEA feed -> tokenize UDF -> packer -> train steps."""
    store = RefStore()
    Q.make_reference_tables(store, scale=0.002, seed=7)
    mgr = FeedManager(store)
    src = FeedDataSource(mgr, vocab_size=CFG.vocab_size, seq_len=32,
                         batch_size=2, total_records=3000, frame_size=128,
                         safety_filter=True, num_partitions=2)
    tcfg = TrainerConfig(steps=5, log_every=1)
    trainer = Trainer(CFG, OPT, tcfg)
    hist = trainer.run(iter(src))
    assert int(trainer.state["step"]) == 5
    assert all(np.isfinite(h["loss"]) for h in hist)


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------

def test_packer_roundtrip_properties():
    packer = StreamPacker(seq_len=32, batch_size=2)
    docs = [[10, 11, 12], [20] * 40, [30, 31], [40, 41, 42, 43]] * 3
    batches = []
    for d in docs:
        out = packer.add(d)
        if out:
            batches.append(out)
    out = packer.flush()
    if out:
        batches.append(out)
    assert batches
    for b in batches:
        assert b["tokens"].shape == (2, 32)
        # loss mask covers exactly the segment-id-nonzero positions
        np.testing.assert_array_equal(b["loss_mask"] > 0,
                                      b["segment_ids"] > 0)
        # positions restart per segment
        for i in range(2):
            for seg in np.unique(b["segment_ids"][i]):
                if seg == 0:
                    continue
                pos = b["positions"][i][b["segment_ids"][i] == seg]
                np.testing.assert_array_equal(pos, np.arange(len(pos)))


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_compression_error_feedback_roundtrip():
    rng = np.random.default_rng(0)
    g = {"a": jnp.asarray(rng.normal(size=(300,)).astype(np.float32)),
         "b": jnp.asarray(rng.normal(size=(17, 5)).astype(np.float32))}
    err = C.init_error(g)
    comp, err2 = C.compress_tree(g, err)
    deq = C.decompress_tree(comp, g)
    # int8 quantization: ~1% relative error at block scale
    for k in g:
        rel = np.abs(np.asarray(deq[k] - g[k])).max() / \
            np.abs(np.asarray(g[k])).max()
        assert rel < 0.02, (k, rel)
        # error buffer holds exactly the residual
        np.testing.assert_allclose(np.asarray(err2[k]),
                                   np.asarray(g[k] - deq[k]), atol=1e-6)


def test_compressed_psum_matches_mean_multidevice():
    """8 fake devices: compressed DP mean ~= exact mean (subprocess so the
    512-device dry-run flag never leaks into this process)."""
    import subprocess
    import sys
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
import repro  # enables x64
from repro.train import compression as C

mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.normal(size=(8, 1024)).astype(np.float32))
err = jnp.zeros((8, 1024), jnp.float32)

def f(gl, el):
    red, e2 = C.psum_compressed({"g": gl[0]}, {"g": el[0]}, "data")
    return red["g"][None], e2["g"][None]

red, _ = jax.jit(shard_map(f, mesh=mesh,
                 in_specs=(P("data"), P("data")),
                 out_specs=(P("data"), P("data"))))(g, err)
exact = jnp.mean(g, axis=0)
got = np.asarray(red)[0]
rel = np.abs(got - np.asarray(exact)).max()
assert rel < 0.02, rel
print("OK", rel)
"""
    out = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                         env={**os.environ, "PYTHONPATH": "src"},
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
