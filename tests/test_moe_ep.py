"""Expert-parallel MoE (shard_map + all_to_all) correctness: must match the
single-device reference routing exactly when capacity is ample (8 fake
devices, subprocess)."""

import os
import subprocess
import sys

import pytest


def _run(code: str) -> str:
    out = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                         env={**os.environ, "PYTHONPATH": "src"},
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_moe_ep_matches_reference():
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
import repro
from repro.configs import smoke_config
from repro.models import moe as M
from repro.models import moe_ep as MEP
from repro.models.params import init_tree
from repro.models.sharding import sharding_ctx

# 2 (data) x 4 (model) mesh; 8 experts -> 2 per model shard
from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh((2, 4), ("data", "model"))
cfg = smoke_config("olmoe-1b-7b").replace(
    num_experts=8, experts_per_token=2, capacity_factor=8.0,
    dtype="float32", param_dtype="float32")
p = init_tree(M.moe_specs(cfg), jax.random.key(0), "float32")
x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model),
                      jnp.float32) * 0.3

y_ref, aux_ref = M.moe_ffn(cfg, p, x)        # no-mesh reference

with sharding_ctx(mesh):
    y_ep, aux_ep = jax.jit(
        lambda p, x: MEP.moe_ffn_ep(cfg, p, x))(p, x)

np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                           rtol=2e-4, atol=2e-5)
# aux is a load-balance heuristic: per-device pmean vs global mean differ
# at the percent level by construction
np.testing.assert_allclose(float(aux_ep), float(aux_ref), rtol=0.05)
print("OK exact-match")

# and through the full train loss of the moe family
from repro.models import api
cfg2 = smoke_config("olmoe-1b-7b").replace(
    capacity_factor=8.0, moe_ep=True, dtype="float32")
params = api.init_params(cfg2, jax.random.key(0))
batch = {"tokens": jnp.zeros((4, 16), jnp.int32),
         "targets": jnp.zeros((4, 16), jnp.int32)}
with sharding_ctx(mesh):
    l_ep, _ = jax.jit(lambda p, b: api.loss(cfg2, p, b))(params, batch)
l_ref, _ = api.loss(cfg2.replace(moe_ep=False), params, batch)
np.testing.assert_allclose(float(l_ep), float(l_ref), rtol=1e-4)
print("OK loss-match", float(l_ep), float(l_ref))
"""
    out = _run(code)
    assert "OK exact-match" in out and "OK loss-match" in out
