"""Progressive re-enrichment (core/repair.py): lineage capture on the
plan path, compile-time preconditions, the repair scheduler's control
surface (staleness, dirty-key refinement, budget, backlog yield,
exactly-once under supersession), executable reuse from the predeploy
cache, and the end-to-end convergence guarantee under concurrent
ingestion.

Deliberately hypothesis-free: runs in the minimal-install CI job.  A
module-level pytest-timeout bounds the thread-heavy tests.
"""

import threading
import time
import warnings
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import (ComputingRunner, ComputingSpec, FeedConfig,
                        FeedManager, PlanError, RefStore, RepairJob,
                        RepairSpec, StorageJob, SyntheticAdapter, pipeline)
from repro.core.enrich import queries as Q
from repro.core.records import SyntheticTweets, parse_json_lines

pytestmark = pytest.mark.timeout(180)


def make_manager(scale=0.002):
    store = RefStore()
    Q.make_reference_tables(store, scale=scale, seed=7)
    return FeedManager(store)


def q1_plan(mgr, total=0, batch=50, name="rp", refresh=None, **store_kw):
    p = (pipeline(SyntheticAdapter(total=total, frame_size=batch, seed=3),
                  name)
         .parse(batch_size=batch)
         .options(num_partitions=2)
         .enrich(Q.Q1)
         .store(refresh=refresh, **store_kw))
    return p.compile(mgr.refstore)


def seed_storage(mgr, plan, nrows, seed=3, nparts=2, upsert=False):
    """Materialize a store the way the feed would: enrich through a runner
    sharing the manager's predeploy cache, write with lineage."""
    runner = ComputingRunner(ComputingSpec(plan.udf, plan.batch_size),
                             mgr.refstore, mgr.predeploy)
    storage = StorageJob(nparts, upsert=upsert)
    for frame in SyntheticTweets(seed=seed).batches(nrows, plan.batch_size):
        out = runner.run(frame)
        storage.write(plan.restrict(out), lineage=runner.last_versions)
    return storage


def safety_table(mgr):
    snap = mgr.refstore["safety_levels"].snapshot()
    a = snap.arrays
    return {int(k): int(v) for k, v in
            zip(a["key"][:snap.size], a["safety_level"][:snap.size])}


def stored_rows(storage):
    """{pk: row} with latest-occurrence-wins (global row order)."""
    rows = {}
    for c in storage.scan():
        for i in range(c["id"].shape[0]):
            rows[int(c["id"][i])] = {k: c[k][i] for k in c}
    return rows


def assert_store_current(mgr, storage):
    """Every stored row's safety_level equals a from-scratch enrichment
    under the CURRENT reference snapshot (bitwise: exact int compare)."""
    table = safety_table(mgr)
    rows = stored_rows(storage)
    assert rows, "empty store"
    for pk, row in rows.items():
        assert int(row["safety_level"]) == table.get(int(row["country"]),
                                                     -1), pk


# ---------------------------------------------------------------------------
# spec + compile-time preconditions
# ---------------------------------------------------------------------------

def test_repair_spec_validation():
    with pytest.raises(ValueError):
        RepairSpec(budget_rows_s=0)
    with pytest.raises(ValueError):
        RepairSpec(max_lag_s=-1)
    with pytest.raises(ValueError):
        RepairSpec(interval_s=0)
    with pytest.raises(ValueError):
        RepairSpec(yield_backlog_batches=-0.5)


def test_store_refresh_accepts_kwargs_dict():
    mgr = make_manager()
    plan = q1_plan(mgr, refresh={"budget_rows_s": 1234.0})
    assert plan.store_spec.refresh.budget_rows_s == 1234.0
    with pytest.raises(PlanError, match="invalid refresh spec"):
        q1_plan(mgr, refresh={"nope": 1})
    with pytest.raises(PlanError, match="RepairSpec or dict"):
        q1_plan(mgr, refresh=42)


def test_refresh_requires_enrich_stage():
    mgr = make_manager()
    p = (pipeline(SyntheticAdapter(total=0, frame_size=50), "r")
         .parse(batch_size=50).store(refresh=RepairSpec()))
    with pytest.raises(PlanError, match="at least one enrich stage"):
        p.compile(mgr.refstore)


def test_refresh_rejects_per_record_model():
    mgr = make_manager()
    p = (pipeline(SyntheticAdapter(total=0, frame_size=8), "r")
         .parse(batch_size=8, model="per_record")
         .enrich(Q.Q1).store(refresh=RepairSpec()))
    with pytest.raises(PlanError, match="per_record"):
        p.compile(mgr.refstore)


def test_refresh_rejects_stream_model():
    """Stream feeds enrich with feed-lifetime state but lineage records
    per-batch snapshot versions — stale-state rows would be tagged fresh
    and never repaired, so the combination is a compile error."""
    mgr = make_manager()
    p = (pipeline(SyntheticAdapter(total=0, frame_size=50), "r")
         .parse(batch_size=50, model="stream")
         .enrich(Q.Q2).store(refresh=RepairSpec()))
    with pytest.raises(PlanError, match="stream"):
        p.compile(mgr.refstore)


def test_lag_samples_bounded():
    from repro.core.repair import RepairStats
    st = RepairStats()
    for i in range(RepairStats.MAX_LAG_SAMPLES + 10):
        st.add_lag(float(i))
    assert len(st.lag_samples) <= RepairStats.MAX_LAG_SAMPLES
    assert st.lag_samples[-1] == float(RepairStats.MAX_LAG_SAMPLES + 9)


def test_clean_pass_cannot_swallow_racing_upsert():
    """Regression: a ref write landing between step()'s version read and
    its clean-pass bookkeeping must leave the scheduler armed (the flag
    is cleared BEFORE the scan, so the racing listener re-sets it)."""
    mgr = make_manager()
    plan = q1_plan(mgr, refresh=RepairSpec())
    storage = seed_storage(mgr, plan, 100)
    job = RepairJob(plan, storage, mgr.refstore, mgr.predeploy)
    assert job.step(force=True) == 0            # clean pass: flag cleared
    assert not job._maybe_stale
    mgr.refstore["safety_levels"].upsert(       # listener re-arms
        np.arange(10, dtype=np.int64),
        safety_level=np.full(10, 2, np.int32))
    assert job._maybe_stale
    while not job.converged():
        job.step(force=True)
    assert_store_current(mgr, storage)
    job.stop()


def test_reftable_upsert_vectorized_semantics():
    """The vectorized upsert must keep the old sequential semantics:
    replace-on-existing, insert-on-new, last duplicate wins, capacity
    enforced before any mutation."""
    t = RefStore().create("t", 4, {"v": np.int32})
    t.upsert(np.array([7, 3, 7], np.int64),
             v=np.array([70, 30, 71], np.int32))
    assert len(t) == 2
    snap = t.snapshot()
    got = {int(k): int(v) for k, v in
           zip(snap.arrays["key"][:snap.size], snap.arrays["v"][:snap.size])}
    assert got == {3: 30, 7: 71}                # last duplicate won
    t.upsert(np.array([3, 9], np.int64), v=np.array([31, 90], np.int32))
    snap = t.snapshot()
    got = {int(k): int(v) for k, v in
           zip(snap.arrays["key"][:snap.size], snap.arrays["v"][:snap.size])}
    assert got == {3: 31, 7: 71, 9: 90}
    with pytest.raises(RuntimeError, match="over capacity"):
        t.upsert(np.array([10, 11], np.int64),
                 v=np.array([1, 2], np.int32))
    assert len(t) == 3                          # rejected atomically


def test_refresh_rejects_multi_group_plans():
    mgr = make_manager()
    p = (pipeline(SyntheticAdapter(total=0, frame_size=50), "r")
         .parse(batch_size=50)
         .enrich(Q.Q1).enrich(Q.Q2, partitions=2)
         .store(refresh=RepairSpec()))
    with pytest.raises(PlanError, match="single stage group"):
        p.compile(mgr.refstore)


def test_refresh_requires_schema_columns_stored():
    mgr = make_manager()
    p = (pipeline(SyntheticAdapter(total=0, frame_size=50), "r")
         .parse(batch_size=50).enrich(Q.Q1)
         .project("safety_level")
         .store(refresh=RepairSpec()))
    with pytest.raises(PlanError, match="every input schema column"):
        p.compile(mgr.refstore)
    # projecting the full schema + outputs is fine
    from repro.core.records import TWEET_SCHEMA
    p2 = (pipeline(SyntheticAdapter(total=0, frame_size=50), "r2")
          .parse(batch_size=50).enrich(Q.Q1)
          .project("safety_level", *TWEET_SCHEMA)
          .store(refresh=RepairSpec()))
    assert p2.compile(mgr.refstore).store_spec.refresh is not None


# ---------------------------------------------------------------------------
# lineage capture on the plan path
# ---------------------------------------------------------------------------

def test_plan_feed_records_lineage_per_chunk():
    mgr = make_manager()
    plan = q1_plan(mgr, total=500)
    h = mgr.submit(plan)
    stats = h.join(timeout=120)
    assert stats.stored == 500
    v = mgr.refstore["safety_levels"].version
    units = [u for p in h.storage.partitions for u in p.lineage_units()]
    assert units
    for _, _, lin in units:
        assert lin == {"safety_levels": v}


# ---------------------------------------------------------------------------
# the scheduler, synchronously (thread never started)
# ---------------------------------------------------------------------------

def test_step_repairs_stale_rows_to_convergence():
    mgr = make_manager()
    plan = q1_plan(mgr, refresh=RepairSpec())
    storage = seed_storage(mgr, plan, 200)
    job = RepairJob(plan, storage, mgr.refstore, mgr.predeploy)
    assert job.converged()
    t = mgr.refstore["safety_levels"]
    keys = np.arange(10, dtype=np.int64)        # existing keys 0..9
    t.upsert(keys, safety_level=np.full(10, 4, np.int32))
    assert not job.converged()
    while not job.converged():
        assert job.step(force=True) >= 0
    assert_store_current(mgr, storage)
    assert job.stats.repaired_rows > 0
    assert job.stats.repaired_rows == job.stats.stale_rows
    assert storage.count == 200                 # exactly-once: no dups
    assert job.stats.repair_lag_p95_s >= job.stats.repair_lag_p50_s > 0
    # a further step is a no-op
    assert job.step(force=True) == 0
    job.stop()


def test_dirty_key_probe_refines_untouched_units():
    mgr = make_manager()
    plan = q1_plan(mgr, refresh=RepairSpec())
    storage = seed_storage(mgr, plan, 40)       # few rows: sparse countries
    present = {int(c) for r in stored_rows(storage).values()
               for c in [r["country"]]}
    absent = next(k for k in range(100) if k not in present)
    job = RepairJob(plan, storage, mgr.refstore, mgr.predeploy)
    mgr.refstore["safety_levels"].upsert(
        np.asarray([absent], np.int64),
        safety_level=np.asarray([2], np.int32))
    before = {pk: int(r["safety_level"])
              for pk, r in stored_rows(storage).items()}
    assert job.step(force=True) == 0
    assert job.converged()
    assert job.stats.units_refined == job.stats.units_scanned > 0
    assert job.stats.repaired_rows == 0
    assert job.stats.repair_invocations == 0    # zero enrichment work
    assert {pk: int(r["safety_level"])
            for pk, r in stored_rows(storage).items()} == before
    job.stop()


def test_repair_reuses_predeployed_executable():
    mgr = make_manager()
    plan = q1_plan(mgr, refresh=RepairSpec())
    storage = seed_storage(mgr, plan, 200)      # warms apply:q1 @ (50,)
    name = f"apply:{plan.udf.name}"
    compiles = mgr.predeploy.by_name[name]["compiles"]
    job = RepairJob(plan, storage, mgr.refstore, mgr.predeploy)
    mgr.refstore["safety_levels"].upsert(      # existing keys: no resize
        np.arange(5, dtype=np.int64),
        safety_level=np.full(5, 1, np.int32))
    while not job.converged():
        job.step(force=True)
    assert job.stats.repair_invocations > 0
    assert mgr.predeploy.by_name[name]["compiles"] == compiles
    job.stop()


def test_budget_paces_repair():
    mgr = make_manager()
    # 1 row/s with a 1-row bucket: one 50-row unit overdraws the bucket
    # for ~49s — a budgeted second step must do nothing
    spec = RepairSpec(budget_rows_s=1.0, burst_s=1.0)
    plan = q1_plan(mgr, refresh=spec)
    storage = seed_storage(mgr, plan, 500, nparts=1)   # 50-row units
    job = RepairJob(plan, storage, mgr.refstore, mgr.predeploy)
    mgr.refstore["safety_levels"].upsert(
        np.arange(100, dtype=np.int64),                # every country dirty
        safety_level=np.full(100, 3, np.int32))
    job.step()                                         # budgeted step
    assert job.stats.units_scanned == 1                # one unit, then broke
    job.step()                                         # bucket overdrawn
    assert job.stats.units_scanned == 1
    assert not job.converged()                         # work remains
    while not job.converged():
        job.step(force=True)                           # drain ignores budget
    assert_store_current(mgr, storage)
    job.stop()


def test_repair_yields_to_ingestion_backlog():
    mgr = make_manager()
    plan = q1_plan(mgr, refresh=RepairSpec())
    storage = seed_storage(mgr, plan, 100)
    backlog = [(plan.batch_size * 10, 0)]
    holder = SimpleNamespace(backlog=lambda: backlog[0])
    handle = SimpleNamespace(
        _live_workers=1,
        stage_groups=[SimpleNamespace(holders=[holder], elastic=None)])
    job = RepairJob(plan, storage, mgr.refstore, mgr.predeploy,
                    handle=handle)
    mgr.refstore["safety_levels"].upsert(
        np.arange(100, dtype=np.int64),
        safety_level=np.full(100, 3, np.int32))
    assert job.step() == 0                      # backlogged: yield
    assert job.stats.yields == 1
    assert job.stats.units_scanned == 0
    backlog[0] = (0, 0)                         # feed caught up
    assert job.step() > 0
    handle._live_workers = 0                    # feed done: never yields
    backlog[0] = (plan.batch_size * 10, 0)
    job.step()
    assert job.stats.yields == 1
    job.stop()


def test_max_lag_slo_overrides_backlog_yield():
    """While the oldest pending ref change is younger than max_lag_s,
    repair defers to backlog; once older, it stops yielding (freshness
    SLO) — the row budget still bounds how hard it competes."""
    mgr = make_manager()
    plan = q1_plan(mgr, refresh=RepairSpec(max_lag_s=0.05))
    storage = seed_storage(mgr, plan, 100)
    holder = SimpleNamespace(backlog=lambda: (plan.batch_size * 10, 0))
    handle = SimpleNamespace(
        _live_workers=1,
        stage_groups=[SimpleNamespace(holders=[holder], elastic=None)])
    job = RepairJob(plan, storage, mgr.refstore, mgr.predeploy,
                    handle=handle)
    mgr.refstore["safety_levels"].upsert(
        np.arange(100, dtype=np.int64),
        safety_level=np.full(100, 3, np.int32))
    assert job.step() == 0                      # young staleness: yield
    assert job.stats.yields == 1
    time.sleep(0.08)                            # SLO breached
    assert job.step() > 0                       # repairs despite backlog
    while not job.converged():
        job.step(force=True)
    assert_store_current(mgr, storage)
    job.stop()


def test_concurrent_ingest_upsert_supersedes_repair():
    mgr = make_manager()
    plan = q1_plan(mgr, refresh=RepairSpec(), upsert=True)
    storage = seed_storage(mgr, plan, 50, nparts=1, upsert=True)
    job = RepairJob(plan, storage, mgr.refstore, mgr.predeploy)
    mgr.refstore["safety_levels"].upsert(       # all countries dirty
        np.arange(100, dtype=np.int64),
        safety_level=np.full(100, 7, np.int32))
    # "concurrent" ingestion re-delivers the same pks, enriched under the
    # NEW versions, before the repair scheduler gets to the old unit
    runner = ComputingRunner(ComputingSpec(plan.udf, plan.batch_size),
                             mgr.refstore, mgr.predeploy)
    for frame in SyntheticTweets(seed=3).batches(50, plan.batch_size):
        out = runner.run(frame)
        storage.write(plan.restrict(out), lineage=runner.last_versions)
    while not job.converged():
        job.step(force=True)
    assert job.stats.superseded_rows > 0        # ingest won those rows
    assert storage.count == 50
    assert_store_current(mgr, storage)
    job.stop()


def test_coarse_repair_without_repair_keys_stateful_stage():
    """Q2 declares no repair_keys: staleness stays coarse (whole unit
    re-enriched) and the group-by STATE is rebuilt at the new version."""
    mgr = make_manager()
    p = (pipeline(SyntheticAdapter(total=0, frame_size=50), "q2rp")
         .parse(batch_size=50).options(num_partitions=2)
         .enrich(Q.Q2).store(refresh=RepairSpec()))
    plan = p.compile(mgr.refstore)
    storage = seed_storage(mgr, plan, 150)
    job = RepairJob(plan, storage, mgr.refstore, mgr.predeploy)
    t = mgr.refstore["religious_populations"]
    t.upsert(np.asarray([0, 1], np.int64),
             country=np.asarray([3, 3], np.int32),
             religion=np.asarray([1, 2], np.int32),
             population=np.asarray([10_000, 20_000], np.int32))
    while not job.converged():
        job.step(force=True)
    assert job.stats.refined_rows == 0          # coarse: nothing refined
    assert job.stats.repaired_rows > 0
    # bitwise: stored rows equal a from-scratch run under the new snapshot
    fresh = ComputingRunner(ComputingSpec(plan.udf, plan.batch_size),
                            mgr.refstore, mgr.predeploy)
    want = {}
    for frame in SyntheticTweets(seed=3).batches(150, plan.batch_size):
        out = fresh.run(frame)
        for i in range(int(out["valid"].sum())):
            want[int(out["id"][i])] = int(out["religious_population"][i])
    got = {pk: int(r["religious_population"])
           for pk, r in stored_rows(storage).items()}
    assert got == want
    job.stop()


# ---------------------------------------------------------------------------
# end to end: convergence under concurrent ingestion
# ---------------------------------------------------------------------------

def test_end_to_end_repair_converges_under_concurrent_ingestion():
    """The acceptance scenario: ingest N rows, upsert a subset of ref keys
    mid-feed, keep ingesting — join() must hand back a store that is
    bitwise equal to a from-scratch re-enrichment under the final
    snapshot, with no lost or duplicated rows and exactly-once upserts."""
    mgr = make_manager()
    total, batch = 3000, 100
    p = (pipeline(SyntheticAdapter(total=total, frame_size=batch, seed=3,
                                   rate=4000.0), "e2e-repair")
         .parse(batch_size=batch)
         .options(num_partitions=2)
         .enrich(Q.Q1)
         .store(refresh=RepairSpec(budget_rows_s=100_000)))
    h = mgr.submit(p)
    time.sleep(0.25)                            # some rows stored & stale-able
    t = mgr.refstore["safety_levels"]
    t.upsert(np.arange(30, dtype=np.int64),     # existing keys: no resize
             safety_level=np.full(30, 9, np.int32))
    time.sleep(0.25)
    t.upsert(np.arange(30, 60, dtype=np.int64),
             safety_level=np.full(30, 8, np.int32))
    stats = h.join(timeout=120)
    assert stats.records_in == total
    assert stats.stored == total                # nothing lost
    assert h.storage.count == total             # nothing duplicated
    assert h.repair is not None and h.repair.converged()
    assert_store_current(mgr, h.storage)        # bitwise vs from-scratch
    assert stats.repaired_rows > 0
    assert stats.repair is not None
    assert stats.stale_rows == stats.repair.stale_rows
    assert stats.repair_lag_p95_s >= stats.repair_lag_p50_s > 0.0


def test_feed_without_refresh_has_no_repair_job():
    mgr = make_manager()
    h = mgr.submit(q1_plan(mgr, total=200))
    stats = h.join(timeout=120)
    assert h.repair is None
    assert stats.repair is None and stats.repaired_rows == 0


# ---------------------------------------------------------------------------
# filter-deletes (satellite: closes the PR 4 known limit — a stored row
# the re-evaluated filter rejects is deleted, not just counted)
# ---------------------------------------------------------------------------

def filter_plan(mgr, threshold=1, name="fdel", refresh=None):
    p = (pipeline(SyntheticAdapter(total=0, frame_size=50, seed=3), name)
         .parse(batch_size=50)
         .options(num_partitions=2)
         .enrich(Q.Q1)
         .filter(lambda b: b["safety_level"] >= threshold, name="lvl")
         .store(refresh=refresh))
    return p.compile(mgr.refstore)


def test_repair_deletes_rows_the_reevaluated_filter_rejects():
    mgr = make_manager()
    plan = filter_plan(mgr, refresh=RepairSpec(budget_rows_s=1e9))
    storage = seed_storage(mgr, plan, 600)
    stored0 = storage.count
    assert stored0 > 0
    job = RepairJob(plan, storage, mgr.refstore, mgr.predeploy)
    try:
        # flip a slab of countries below the filter threshold: every
        # stored row joining them must DISAPPEAR from the store
        table = mgr.refstore["safety_levels"]
        flipped = np.arange(40, dtype=np.int64)
        table.upsert(flipped, safety_level=np.zeros(40, np.int32))
        doomed = [pk for pk, row in stored_rows(storage).items()
                  if int(row["country"]) < 40]
        assert doomed, "seed produced no rows in the flipped countries"
        assert job.drain(timeout=60)
        assert job.stats.deleted_rows == len(doomed)
        assert job.stats.invalidated_rows == len(doomed)
        assert storage.count == stored0 - len(doomed)
        for pk in doomed:
            assert storage.get(pk) is None
        # the deleted versions are dead storage until compaction
        assert storage.dead_rows >= len(doomed)
        assert storage.compact() >= len(doomed)
        # survivors are current AND still satisfy the filter
        assert_store_current(mgr, storage)
        for row in stored_rows(storage).values():
            assert int(row["safety_level"]) >= 1
        # idempotent: a re-scan neither resurrects nor double-deletes
        before = job.stats.deleted_rows
        job.step(force=True)
        assert job.stats.deleted_rows == before
        assert storage.count == stored0 - len(doomed)
    finally:
        job.stop()


def test_repair_delete_loses_to_racing_ingest_upsert():
    """Exactly-once composition: if an ingest upsert re-wrote the pk after
    the repair scan, the conditional delete must spare the newer row."""
    mgr = make_manager()
    plan = filter_plan(mgr, refresh=RepairSpec(budget_rows_s=1e9))
    storage = seed_storage(mgr, plan, 200, upsert=True)
    rows = stored_rows(storage)
    victim_pk, victim = next(
        (pk, r) for pk, r in rows.items() if int(r["country"]) < 40)
    part = storage.partitions[victim_pk % len(storage.partitions)]
    table = mgr.refstore["safety_levels"]
    table.upsert(np.arange(40, dtype=np.int64),
                 safety_level=np.zeros(40, np.int32))
    # simulate the racing ingest upsert landing between scan and delete:
    # re-write the victim AFTER repair captured its unit list by patching
    # delete_rows to upsert first, once
    orig_delete = part.delete_rows
    state = {"fired": False}

    def racing_delete(ids, global_rows, expect_epoch=None):
        if not state["fired"] and np.isin(victim_pk, ids):
            state["fired"] = True
            fresh = {k: np.asarray([victim[k]]) for k in victim}
            fresh["valid"] = np.ones(1, bool)
            part.insert(fresh, upsert=True, lineage={"safety_levels": 0})
        return orig_delete(ids, global_rows, expect_epoch)

    part.delete_rows = racing_delete
    job = RepairJob(plan, storage, mgr.refstore, mgr.predeploy)
    try:
        assert job.drain(timeout=60)
    finally:
        job.stop()
        part.delete_rows = orig_delete
    assert state["fired"]
    # the racing upsert won round 1; its stale-lineage row was then
    # re-scanned and deleted on a LATER pass (it still fails the filter) —
    # but never misattributed: the store converges with no victim row
    assert storage.get(victim_pk) is None
    # compact first: the scan-order helper would resurrect deleted
    # versions (the pk index — and so compaction — owns delete semantics)
    storage.compact()
    assert_store_current(mgr, storage)


def test_repair_unit_survives_compaction_shrinking_its_span():
    """Regression: a compaction between the staleness scan and the unit
    read shrinks the position space — the stale (start, rows) span may
    now be short or out of range entirely.  The unit must be skipped (and
    re-listed next pass), never crash or misapply."""
    mgr = make_manager()
    plan = q1_plan(mgr, refresh=RepairSpec(budget_rows_s=1e9))
    storage = seed_storage(mgr, plan, 400, upsert=True)
    # churn so compaction has something to drop
    runner = ComputingRunner(ComputingSpec(plan.udf, plan.batch_size),
                             mgr.refstore, mgr.predeploy)
    for frame in SyntheticTweets(seed=3).batches(200, plan.batch_size):
        storage.write(runner.run(frame), lineage=runner.last_versions)
    job = RepairJob(plan, storage, mgr.refstore, mgr.predeploy)
    try:
        mgr.refstore["safety_levels"].upsert(
            np.arange(20, dtype=np.int64),
            safety_level=np.full(20, 7, np.int32))
        now = time.monotonic()
        versions = {t: mgr.refstore[t].version for t in plan.udf.ref_tables}
        stale = job._stale_units(versions, now)
        assert stale
        assert storage.compact() == 200      # spans shrink under the units
        repaired = 0
        for _, since, part, start, n, lin in stale:
            repaired += job._repair_unit(part, start, n, lin, versions,
                                         since)
        # whatever was applied, it was applied consistently: drain to
        # convergence and check bitwise against from-scratch enrichment
        assert job.drain(timeout=60)
        storage.compact()
        assert_store_current(mgr, storage)
    finally:
        job.stop()


# ---------------------------------------------------------------------------
# shim removal (satellite: the deprecated lowering path is gone)
# ---------------------------------------------------------------------------

def test_start_rejects_shim_but_plans_and_baselines_run_clean():
    mgr = make_manager()
    cfg = FeedConfig(name="dep", udf=Q.Q1, batch_size=50, num_partitions=1)
    with pytest.raises(ValueError, match="pipeline"):
        mgr.start(cfg, SyntheticAdapter(total=100, frame_size=50))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        h2 = mgr.submit(q1_plan(mgr, total=100, name="dep2"))
        assert h2.join(timeout=120).stored == 100


def test_baseline_frameworks_keep_their_measurement_path():
    mgr = make_manager()
    cfg = FeedConfig(name="base", udf=Q.Q1, batch_size=50,
                     num_partitions=1, framework="balanced")
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        h = mgr.start(cfg, SyntheticAdapter(total=100, frame_size=50))
        assert h.join(timeout=120).stored == 100
