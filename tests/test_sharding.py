"""Sharding-rule + elastic-remesh tests (multi-device via subprocess so the
session's single-device jax stays untouched)."""

import os
import subprocess
import sys

import jax
import numpy as np

from repro.models.sharding import DEFAULT_RULES, spec_for


def test_spec_for_no_mesh_is_unconstrained():
    assert spec_for((8, 16), ("batch", "embed"), mesh=None) == \
        jax.sharding.PartitionSpec()


def _run(code: str) -> str:
    out = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                         env={**os.environ, "PYTHONPATH": "src"},
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_spec_for_divisibility_fallback_and_axis_reuse():
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import repro
from jax.sharding import PartitionSpec as P
from repro.models.sharding import sharding_ctx, spec_for, \
    recorded_fallbacks
from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh((2, 4), ("data", "model"))
with sharding_ctx(mesh):
    # divisible: sharded
    assert spec_for((16, 64), ("batch", "ffn")) == P("data", "model"), \
        spec_for((16, 64), ("batch", "ffn"))
    # 42 heads not divisible by 4 -> fallback to replication, recorded
    s = spec_for((8, 42), ("batch", "heads"))
    assert s == P("data",), s
    assert recorded_fallbacks(), "fallback not recorded"
    # same mesh axis cannot appear twice: second use dropped
    s = spec_for((64, 64), ("ffn", "vocab"))
    assert s == P("model",), s
print("OK")
"""
    assert "OK" in _run(code)


def test_elastic_remesh_reshard_roundtrip(tmp_path):
    """Checkpoint on a 2x4 mesh, restore resharded onto 8x1 and 4x2 —
    values identical, shardings actually applied."""
    code = rf"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
import repro
from repro.ckpt import save, restore
from repro.runtime.elastic import build_mesh, remesh_shardings

state = {{"w": jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32),
          "b": jnp.ones((32,), jnp.float32)}}
axes = {{"w": ("embed", "ffn"), "b": ("ffn",)}}
save(r"{tmp_path}", 7, state)

for mp in (1, 2, 4):
    mesh = build_mesh(model_parallel=mp)
    shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    sh = remesh_shardings(shapes, axes, mesh)
    back = restore(r"{tmp_path}", state, shardings=sh)
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(state["w"]))
    assert back["w"].sharding.is_equivalent_to(
        jax.tree.leaves(sh)[1] if False else sh["w"], 2)
print("OK")
"""
    assert "OK" in _run(code)
