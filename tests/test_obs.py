"""Observability tests: registry semantics, exposition golden, tracer
ring behavior, span lifecycle on a real feed, FeedStats backward-compat
pins, currency accounting, and the static-feed backlog_p95 regression.

Deliberately hypothesis-free: CI runs this module in the minimal
plan-api container, so the observability surface is pinned even where
the property-test extras are not installed.
"""

import json
import math

import pytest

from repro.core import (FeedManager, MetricsRegistry, RefStore,
                        SyntheticAdapter, TraceSpec, pipeline)
from repro.core.enrich import queries as Q
from repro.core.feed import FeedStats
from repro.core.obs import Tracer, mangle, percentile_of


def make_manager(scale=0.002):
    store = RefStore()
    Q.make_reference_tables(store, scale=scale, seed=7)
    return FeedManager(store)


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------

def test_counter_gauge_get_or_create_and_update():
    reg = MetricsRegistry()
    c = reg.counter("hits")
    c.inc()
    c.inc(4)
    assert reg.counter("hits") is c          # get-or-create
    assert reg.snapshot()["hits"] == 5
    c.set(2)
    assert reg.snapshot()["hits"] == 2

    g = reg.gauge("depth")
    g.set(1.5)
    g.add(0.5)
    assert reg.snapshot()["depth"] == 2.0


def test_histogram_buckets_sum_count_percentile():
    reg = MetricsRegistry()
    h = reg.histogram("lat", bounds=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(v)
    snap = reg.snapshot()["lat"]
    assert snap.count == 5
    assert snap.sum == pytest.approx(56.05)
    assert snap.bucket_counts == (1, 2, 1)
    assert snap.overflow == 1
    assert snap.percentile(0.5) == 0.5
    assert h.percentile(0.5) == 0.5          # live view agrees
    assert snap.cumulative_buckets() == [(0.1, 1), (1.0, 3), (10.0, 4)]


def test_cross_kind_name_collision_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x")
    with pytest.raises(ValueError, match="already registered"):
        reg.histogram("x")


def test_snapshot_is_isolated_from_later_updates():
    reg = MetricsRegistry()
    reg.counter("n").inc(1)
    reg.histogram("h").observe(1.0)
    snap = reg.snapshot()
    reg.counter("n").inc(10)
    reg.histogram("h").observe(2.0)
    assert snap["n"] == 1
    assert snap["h"].count == 1
    assert reg.snapshot()["n"] == 11


def test_merge_counters_add_gauges_overwrite_histograms_combine():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("n").inc(2)
    b.counter("n").inc(3)
    a.gauge("g").set(1.0)
    b.gauge("g").set(9.0)
    a.histogram("h", bounds=(1.0, 2.0)).observe(0.5)
    b.histogram("h", bounds=(1.0, 2.0)).observe(1.5)
    a.merge(b)
    snap = a.snapshot()
    assert snap["n"] == 5
    assert snap["g"] == 9.0
    assert snap["h"].count == 2
    assert snap["h"].bucket_counts == (1, 1)


def test_mangle_and_percentile_helpers():
    assert mangle("dispatch_path_('seg', 'kern')") == \
        "dispatch_path___seg____kern__"
    # empty input is "never observed", not "instant": nan by design
    assert math.isnan(percentile_of([], 0.5))
    assert percentile_of([3.0, 1.0, 2.0], 0.5) == 2.0


def test_exposition_golden():
    reg = MetricsRegistry()
    reg.counter("feed_stored").set(42)
    reg.gauge("wall_s").set(1.5)
    h = reg.histogram("lat_s", bounds=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    assert reg.exposition() == (
        "# TYPE feed_stored counter\n"
        "feed_stored 42\n"
        "# TYPE lat_s histogram\n"
        'lat_s_bucket{le="0.1"} 1\n'
        'lat_s_bucket{le="1"} 2\n'
        'lat_s_bucket{le="+Inf"} 3\n'
        "lat_s_sum 5.55\n"
        "lat_s_count 3\n"
        "# TYPE wall_s gauge\n"
        "wall_s 1.5\n")


# ---------------------------------------------------------------------------
# tracer ring
# ---------------------------------------------------------------------------

def test_tracer_ring_overflow_drops_oldest():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.emit("hop", (tr.new_id(),), t0=float(i))
    spans = tr.drain()
    assert len(spans) == 4
    assert [s["t0"] for s in spans] == [6.0, 7.0, 8.0, 9.0]
    assert tr.drain() == []                  # drain empties


def test_tracer_span_ids_are_unique_and_start_at_one():
    tr = Tracer()
    ids = [tr.new_id() for _ in range(5)]
    assert ids == [1, 2, 3, 4, 5]            # 0 is the tracing-off id


def test_trace_spec_validation():
    with pytest.raises(ValueError, match="capacity"):
        TraceSpec(capacity=0)


# ---------------------------------------------------------------------------
# FeedStats backward compatibility (registry-backed views)
# ---------------------------------------------------------------------------

def test_unbound_feedstats_is_a_plain_dataclass():
    s = FeedStats()
    assert s.stored == 0
    s.stored += 7
    s.records_in = 100
    s.wall_s = 2.0
    assert s.stored == 7
    assert s.records_per_s == 50.0


def test_bound_feedstats_reads_and_writes_through_the_registry():
    reg = MetricsRegistry()
    s = FeedStats()
    s.stored = 3
    s.bind(reg)
    assert reg.snapshot()["feed_stored"] == 3     # carried over
    s.stored += 4
    assert s.stored == 7
    assert reg.snapshot()["feed_stored"] == 7     # same storage
    reg.counter("feed_stored").set(11)
    assert s.stored == 11                         # view, not copy
    s.wall_s = 0.5
    assert reg.snapshot()["feed_wall_s"] == 0.5


# ---------------------------------------------------------------------------
# live feed: metrics, currency, spans, backlog p95
# ---------------------------------------------------------------------------

def _run_traced_feed(mgr, name, **opts):
    plan = (pipeline(SyntheticAdapter(total=600, frame_size=50, seed=3),
                     name)
            .parse(batch_size=50)
            .options(num_partitions=1, **opts)
            .enrich(Q.Q2)          # Q2's state build dispatches a
            .store())              # segment op -> dispatch_path metrics
    h = mgr.submit(plan)
    stats = h.join(timeout=120)
    return h, stats


def test_feed_metrics_surface_and_currency_accounting():
    mgr = make_manager()
    h, stats = _run_traced_feed(mgr, "obs-metrics")
    m = h.metrics()
    assert m["feed_stored"] == stats.stored == 600
    assert m["feed_records_in"] == 600
    # currency: every stored batch was stamped at intake and observed at
    # store-append, so the native histogram carries real samples
    lat = m["ingest_visible_latency_s"]
    assert lat.count > 0
    assert lat.percentile(0.95) > 0.0
    # computing attribution flows into the registry on collection
    assert m["computing_invocations"] > 0
    assert any(k.startswith("stage_") and k.endswith("_apply_s")
               for k in m)
    assert any(k.startswith("dispatch_path_") for k in m)
    assert m["store_rows"] == 600
    text = h.metrics_text()
    assert "# TYPE feed_stored counter" in text
    assert "ingest_visible_latency_s_bucket" in text


def test_trace_spans_cover_the_batch_journey():
    mgr = make_manager()
    h, stats = _run_traced_feed(mgr, "obs-trace", trace=True)
    spans = h.drain_trace()
    names = {s["name"] for s in spans}
    assert "intake.draw" in names
    assert "store.append" in names
    assert any(n.startswith("apply.") for n in names)
    # one batch's journey: an intake span id shows up again at apply and
    # at the store sink (ids ride TrackedFrame like wal_seqs)
    draw_ids = {i for s in spans if s["name"] == "intake.draw"
                for i in s["spans"]}
    apply_ids = {i for s in spans if s["name"].startswith("apply.")
                 for i in s["spans"]}
    store_ids = {i for s in spans if s["name"] == "store.append"
                 for i in s["spans"]}
    assert draw_ids & apply_ids & store_ids
    assert h.drain_trace() == []             # drained


def test_trace_path_dumps_jsonl_at_join(tmp_path):
    mgr = make_manager()
    out = tmp_path / "trace.jsonl"
    h, stats = _run_traced_feed(
        mgr, "obs-dump", trace={"path": str(out)})
    lines = out.read_text().strip().splitlines()
    assert lines
    spans = [json.loads(ln) for ln in lines]
    assert all("name" in s and "t0" in s for s in spans)


def test_untraced_feed_has_no_span_overhead_surface():
    mgr = make_manager()
    h, stats = _run_traced_feed(mgr, "obs-off")
    assert h.drain_trace() == []
    assert h.obs.tracing is False
    assert h.obs.new_span() == 0


def test_static_feed_reports_nonzero_backlog_p95_under_backlog():
    """Regression: backlog_p95_rows used to report only while an
    elasticity controller was sampling; a static (non-elastic) feed
    always showed 0.  Every worker pull now samples queue depth, so an
    induced backlog (fast intake, uncoalesced frames, one worker that
    stalls on the first JIT compile) must surface in the p95."""
    mgr = make_manager()
    plan = (pipeline(SyntheticAdapter(total=1500, frame_size=50, seed=11),
                     "obs-backlog")
            .parse(batch_size=50)
            .options(num_partitions=1, coalesce_rows=0)
            .enrich(Q.Q1)
            .store())
    h = mgr.submit(plan)
    stats = h.join(timeout=120)
    assert stats.stored == 1500
    assert h.controller is None              # genuinely static
    assert stats.backlog_p95_rows > 0.0
    assert h.metrics()["backlog_rows"].count > 0
