"""Bench JSON emission + the threshold regression gate.

Synthetic BENCH_*.json documents (no driver runs: the drivers exercise
themselves in the bench-smoke CI job) through benchmarks/regression_gate
in-process, plus the write_json shape contract the gate consumes.

Deliberately hypothesis-free: runs in the minimal-install CI job.
"""

import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO))   # benchmarks/ is not an installed package

from benchmarks import common                         # noqa: E402
from benchmarks.regression_gate import (THRESHOLDS,   # noqa: E402
                                        check_file, main)


def bench_doc(tmp_path, fig, metrics, name=None):
    doc = {"fig": fig,
           "metrics": {k: {"value": v, "unit": "x", "notes": ""}
                       for k, v in metrics.items()}}
    f = tmp_path / (name or f"BENCH_{fig}.json")
    f.write_text(json.dumps(doc))
    return str(f)


GOOD = {
    "fig_repair": {"currency_converged_mismatches": 0,
                   "currency_stale_rows": 0,
                   "interference_ratio": 0.97},
    "fig_query": {"prune_speedup": 3.2, "live_query_p95_ms": 40.0,
                  "batched_agg_speedup": 2.0, "merged_scan_speedup": 3.0},
    "fig25": {"bursty_elastic_vs_best_static": 1.1,
              "obs_overhead_ratio": 1.0,
              "profile_overhead_ratio": 1.0},
}


def test_gate_passes_healthy_metrics_on_both_profiles(tmp_path):
    files = [bench_doc(tmp_path, fig, m) for fig, m in GOOD.items()]
    for profile in ("smoke", "full"):
        for f in files:
            assert check_file(f, profile) == [], (f, profile)
    assert main(["--profile", "smoke", *files]) == 0


def test_gate_fails_on_convergence_regression(tmp_path):
    bad = dict(GOOD["fig_repair"], currency_converged_mismatches=3)
    f = bench_doc(tmp_path, "fig_repair", bad)
    fails = check_file(f, "smoke")
    assert len(fails) == 1 and "currency_converged_mismatches" in fails[0]
    assert main(["--profile", "smoke", f]) == 1


def test_gate_fails_on_ratio_floor_and_latency_ceiling(tmp_path):
    f = bench_doc(tmp_path, "fig_query",
                  dict(GOOD["fig_query"], prune_speedup=0.2,
                       live_query_p95_ms=99_999.0))
    fails = check_file(f, "smoke")
    assert len(fails) == 2


def test_full_profile_is_strictly_tighter(tmp_path):
    # passes smoke, fails full: the drift band the two profiles bracket
    f = bench_doc(tmp_path, "fig_repair",
                  dict(GOOD["fig_repair"], interference_ratio=0.5))
    assert check_file(f, "smoke") == []
    assert len(check_file(f, "full")) == 1


def test_missing_required_metric_is_a_failure(tmp_path):
    m = dict(GOOD["fig_repair"])
    del m["interference_ratio"]
    f = bench_doc(tmp_path, "fig_repair", m)
    fails = check_file(f, "smoke")
    assert len(fails) == 1 and "missing" in fails[0]


def test_unknown_fig_and_unreadable_file_fail(tmp_path):
    f = bench_doc(tmp_path, "fig_nonexistent", {"x": 1})
    assert any("unknown fig" in s for s in check_file(f, "smoke"))
    g = tmp_path / "not_json.json"
    g.write_text("{")
    assert any("unreadable" in s for s in check_file(str(g), "smoke"))


def test_every_threshold_metric_is_emitted_by_its_driver():
    """Presence contract: each gated metric name appears literally in its
    driver source (an emit(...) rename must update the gate too)."""
    src = {
        "fig_repair": (REPO / "benchmarks" / "fig_repair.py").read_text(),
        "fig_query": (REPO / "benchmarks" / "fig_query.py").read_text(),
        "fig25": (REPO / "benchmarks" /
                  "fig25_udf_enrichment.py").read_text(),
        "fig_recovery": (REPO / "benchmarks" /
                         "fig_recovery.py").read_text(),
    }
    for profile in THRESHOLDS:
        for fig, rows in THRESHOLDS[profile].items():
            for name, _, _ in rows:
                assert f'"{name}"' in src[fig], (profile, fig, name)


def test_write_json_shape_matches_gate_contract(tmp_path, monkeypatch):
    monkeypatch.setattr(common, "ROWS", [])
    common.emit("figX", "alpha", 1.234567891, "rec/s", "n1")
    common.emit("figX", "beta", 7, "rows", "")
    common.emit("figOther", "gamma", 1.0, "x", "")   # filtered out
    out = tmp_path / "BENCH_figX.json"
    common.write_json("figX", str(out))
    doc = json.loads(out.read_text())
    assert doc["fig"] == "figX"
    assert set(doc["metrics"]) == {"alpha", "beta"}
    assert doc["metrics"]["alpha"] == {"value": 1.234568,
                                       "unit": "rec/s", "notes": "n1"}
    assert doc["metrics"]["beta"]["value"] == 7
