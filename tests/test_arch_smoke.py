"""Per-architecture smoke tests (deliverable f).

Every assigned arch instantiates a REDUCED config of the same family (small
width/depth, few experts, tiny vocab) and runs:
  * one jitted training loss + grad step on CPU — asserts finite scalars,
  * prefill + two decode steps — asserts logits shapes, finiteness, and
    cache-length bookkeeping.

Full-size configs are exercised only by the dry-run (ShapeDtypeStruct, no
allocation) — see launch/dryrun.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, smoke_config
from repro.models import api

SEQ = 32
BATCH = 2


def _smoke_batch(cfg, rng):
    t = api.token_len(cfg, SEQ)
    tokens = jax.random.randint(rng, (BATCH, t), 0, cfg.vocab_size,
                                dtype=jnp.int32)
    batch = {"tokens": tokens,
             "targets": jnp.roll(tokens, -1, axis=1)}
    if cfg.family in ("vlm", "encdec"):
        batch["frontend"] = jax.random.normal(
            rng, (BATCH, cfg.num_frontend_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch):
    cfg = smoke_config(arch)
    params = api.init_params(cfg, jax.random.key(0))
    batch = _smoke_batch(cfg, jax.random.key(1))

    def loss_fn(p, b):
        l, metrics = api.loss(cfg, p, b)
        return l, metrics

    (l, metrics), grads = jax.jit(
        jax.value_and_grad(loss_fn, has_aux=True))(params, batch)
    assert np.isfinite(float(l)), (arch, l)
    assert float(l) > 0
    # a correct smoke init predicts ~uniform: loss ~= log(vocab)
    assert float(l) < np.log(cfg.vocab_size) + 2.0
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = smoke_config(arch)
    params = api.init_params(cfg, jax.random.key(0))
    t = api.token_len(cfg, SEQ // 2)
    tokens = jax.random.randint(jax.random.key(2), (BATCH, t), 0,
                                cfg.vocab_size, dtype=jnp.int32)
    frontend = None
    if cfg.family in ("vlm", "encdec"):
        frontend = jnp.zeros((BATCH, cfg.num_frontend_tokens, cfg.d_model),
                             jnp.dtype(cfg.dtype))

    cache, logits = jax.jit(
        lambda p, tk, fe: api.prefill(cfg, p, tk, fe))(params, tokens,
                                                       frontend)
    assert logits.shape == (BATCH, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    cache = api.pad_cache(cfg, cache, SEQ)
    step = jax.jit(lambda p, c, tk: api.decode_step(cfg, p, c, tk))
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    for i in range(2):
        logits, cache = step(params, cache, nxt)
        assert logits.shape == (BATCH, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    # vlm counts its prepended patch embeddings as cache positions
    nf = cfg.num_frontend_tokens if cfg.family == "vlm" else 0
    np.testing.assert_array_equal(np.asarray(cache["len"]),
                                  np.full((BATCH,), t + nf + 2))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_registered(arch):
    cfg = get_config(arch)
    assert cfg.name == arch
    assert cfg.param_count() > 0
    # every full config exposes dry-run input specs for all applicable shapes
    from repro.configs import SHAPES, shape_applicable
    for shape in SHAPES.values():
        ok, why = shape_applicable(cfg, shape)
        if not ok:
            assert "full-attention" in why
            continue
        specs, axes = api.input_specs(cfg, shape)
        assert jax.tree.structure(specs) == jax.tree.structure(
            axes, is_leaf=lambda x: isinstance(x, tuple))


def test_decode_matches_prefill_logits():
    """Prefill of n+1 tokens must equal prefill(n) + decode(token n).
    This is the KV-cache correctness invariant, checked on the dense family
    (shared attention path for dense/moe/vlm)."""
    cfg = smoke_config("deepseek-coder-33b")
    params = api.init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(3), (1, 9), 0,
                                cfg.vocab_size, dtype=jnp.int32)
    _, logits_full = api.prefill(cfg, params, tokens)
    cache, _ = api.prefill(cfg, params, tokens[:, :-1])
    cache = api.pad_cache(cfg, cache, 16)
    logits_dec, _ = api.decode_step(cfg, params, cache, tokens[:, -1:])
    np.testing.assert_allclose(np.asarray(logits_full),
                               np.asarray(logits_dec), rtol=2e-4, atol=2e-4)


def test_ssm_decode_matches_prefill():
    """Recurrent decode must continue the chunked-SSD prefill exactly."""
    cfg = smoke_config("mamba2-130m")
    params = api.init_params(cfg, jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(4), (1, 17), 0,
                                cfg.vocab_size, dtype=jnp.int32)
    # chunked path needs multiples of the chunk (8): prefill 16, decode 1
    _, logits_full = api.prefill(cfg, params, tokens[:, :16])
    cache, _ = api.prefill(cfg, params, tokens[:, :8])
    for i in range(8, 16):
        logits_dec, cache = api.decode_step(cfg, params, cache,
                                            tokens[:, i:i + 1])
    np.testing.assert_allclose(np.asarray(logits_full),
                               np.asarray(logits_dec), rtol=2e-3, atol=2e-3)
