"""Durable feeds (core/durability.py + core/recovery.py): WAL framing
and torn-tail truncation, checkpoint atomicity and truncation, the
ledger watermark, compile-time durable-plan validation, and in-process
crash-image resume with exactly-once verification.

Crash images are taken by copying the live durable directory (in the
causal order a crash would preserve: checkpoints before WAL data,
manifests before segments) while the feed is running — a mid-write copy
IS a crash image, and the CRC/atomic-rename machinery must absorb it.

Deliberately hypothesis-free: runs in the minimal-install CI job.
"""

import json
import os
import random
import shutil
import time

import numpy as np
import pytest

from repro.core import (DurableSpec, FeedManager, FileAdapter,
                        NotResumableError, PlanError, RefStore,
                        RepairSpec, SocketAdapter, StorageJob,
                        SyntheticAdapter, pipeline)
from repro.core.durability import (CheckpointStore, DurabilityRuntime,
                                   FrameLedger, IntakeLog)
from repro.core.enrich import queries as Q
from repro.core.repair import RepairJob

pytestmark = pytest.mark.timeout(300)


def make_manager(scale=0.002):
    store = RefStore()
    Q.make_reference_tables(store, scale=scale, seed=7)
    return FeedManager(store)


def durable_plan(mgr, dur_dir, total=0, batch=50, name="dp", seed=3,
                 rate=None, refresh=None, **dur_kw):
    p = (pipeline(SyntheticAdapter(total=total, frame_size=batch,
                                   seed=seed, rate=rate), name)
         .parse(batch_size=batch)
         .options(num_partitions=2, holder_capacity=16)
         .enrich(Q.Q1)
         .store(durable=DurableSpec(dir=str(dur_dir), **dur_kw),
                refresh=refresh))
    return p.compile(mgr.refstore)


def stored_ids(storage):
    """Every live pk across all partitions, duplicates included."""
    out = []
    for part in storage.partitions:
        snap = part.snapshot_view()
        try:
            for u in snap.units:
                ids = np.asarray(u.read(("id",))["id"])
                out.append(ids[snap.live_mask(ids, u.base)])
        finally:
            snap.release()
    return (np.concatenate(out) if out
            else np.array([], dtype=np.int64))


def stored_rows(storage):
    """{pk: row} with latest-occurrence-wins (global row order)."""
    rows = {}
    for c in storage.scan():
        for i in range(c["id"].shape[0]):
            rows[int(c["id"][i])] = {k: c[k][i] for k in c}
    return rows


def assert_store_current(mgr, storage):
    """Every stored row's safety_level equals a from-scratch enrichment
    under the CURRENT reference snapshot."""
    snap = mgr.refstore["safety_levels"].snapshot()
    a = snap.arrays
    table = {int(k): int(v) for k, v in
             zip(a["key"][:snap.size], a["safety_level"][:snap.size])}
    rows = stored_rows(storage)
    assert rows, "empty store"
    for pk, row in rows.items():
        assert int(row["safety_level"]) == table.get(int(row["country"]),
                                                     -1), pk


def assert_exactly_once(storage, total):
    got = stored_ids(storage)
    assert len(got) == len(set(got.tolist())), "duplicate rows stored"
    assert set(got.tolist()) == set(range(total)), (
        f"rows lost: {len(set(range(total)) - set(got.tolist()))}")


# ---------------------------------------------------------------------------
# IntakeLog framing
# ---------------------------------------------------------------------------

def frames_of(n, k=5, tag=b"r"):
    return [[b"%s-%d-%d" % (tag, i, j) for j in range(k)]
            for i in range(n)]


def test_wal_round_trip_and_reopen(tmp_path):
    wal = IntakeLog(str(tmp_path), fsync="never")
    for i, fr in enumerate(frames_of(7)):
        assert wal.append_frame((i + 1) * 10, fr) == i + 1
    assert wal.tail() == (7, 70)
    wal.close()
    re = IntakeLog(str(tmp_path), fsync="never")
    assert re.tail() == (7, 70)
    recs = list(re.replay(0))
    assert [r.seq for r in recs] == list(range(1, 8))
    assert [r.offset for r in recs] == [10 * i for i in range(1, 8)]
    assert recs[3].lines == frames_of(7)[3]
    # replay from a mid watermark
    assert [r.seq for r in re.replay(5)] == [6, 7]
    # appends continue the sequence
    assert re.append_frame(80, [b"x"]) == 8
    re.close()


def test_wal_truncates_torn_tail_and_continues(tmp_path):
    wal = IntakeLog(str(tmp_path), fsync="never")
    for i, fr in enumerate(frames_of(4)):
        wal.append_frame(i + 1, fr)
    wal.close()
    (seg,) = [n for n in os.listdir(str(tmp_path)) if n.endswith(".log")]
    path = os.path.join(str(tmp_path), seg)
    with open(path, "r+b") as f:          # tear the last record mid-write
        f.truncate(os.path.getsize(path) - 3)
    re = IntakeLog(str(tmp_path), fsync="never")
    assert re.tail() == (3, 3)            # torn record 4 dropped
    assert [r.seq for r in re.replay(0)] == [1, 2, 3]
    assert re.append_frame(99, [b"new"]) == 4   # prefix continues
    assert [r.seq for r in re.replay(0)] == [1, 2, 3, 4]
    re.close()


def test_wal_replay_stops_at_corrupt_middle_record(tmp_path):
    """Prefix contract: a flipped byte mid-log ends the readable prefix
    — later records are NOT resurrected past the corruption."""
    wal = IntakeLog(str(tmp_path), fsync="never")
    sizes = []
    for i, fr in enumerate(frames_of(5)):
        wal.append_frame(i + 1, fr)
        sizes.append(os.path.getsize(
            os.path.join(str(tmp_path), os.listdir(str(tmp_path))[0])))
    wal.close()
    (seg,) = os.listdir(str(tmp_path))
    path = os.path.join(str(tmp_path), seg)
    with open(path, "r+b") as f:          # corrupt record 3's payload
        f.seek(sizes[1] + 20)
        f.write(b"\xff")
    re = IntakeLog(str(tmp_path), fsync="never")
    assert [r.seq for r in re.replay(0)] == [1, 2]
    re.close()


def test_wal_rotation_and_checkpoint_truncation(tmp_path):
    wal = IntakeLog(str(tmp_path), fsync="never", segment_bytes=1 << 12)
    big = [b"x" * 200 for _ in range(8)]
    for i in range(40):
        wal.append_frame(i + 1, big)
    segs = sorted(n for n in os.listdir(str(tmp_path))
                  if n.endswith(".log"))
    assert len(segs) > 3                  # rotated
    # truncate to a watermark inside the log: only sealed segments whose
    # every record <= W are unlinked, never the active one
    assert wal.truncate(20) >= 1
    left = sorted(n for n in os.listdir(str(tmp_path))
                  if n.endswith(".log"))
    assert left and left[-1] == segs[-1]
    recs = [r.seq for r in wal.replay(20)]
    assert recs[-1] == 40 and recs == list(range(recs[0], 41))
    assert min(recs) <= 21                # nothing past W is lost
    assert wal.tail()[0] == 40
    wal.close()


def test_checkpoint_store_atomic_with_bak_fallback(tmp_path):
    ck = CheckpointStore(str(tmp_path))
    assert ck.load() is None
    ck.save({"watermark": 3, "last_seq": 3, "last_offset": 30})
    ck.save({"watermark": 7, "last_seq": 9, "last_offset": 90})
    assert ck.load()["watermark"] == 7
    with open(ck.path, "w") as f:         # torn current checkpoint
        f.write('{"waterm')
    assert ck.load()["watermark"] == 3    # falls back one checkpoint
    os.unlink(ck.path)
    assert ck.load()["watermark"] == 3    # .bak alone still recovers


def test_frame_ledger_out_of_order_watermark():
    led = FrameLedger()
    for s in range(1, 6):
        led.note_logged(s, s * 10)
    assert led.watermark() == 0 and led.backlog() == 5
    led.mark_done([2, 3])
    assert led.watermark() == 0
    led.mark_done([1])
    assert led.watermark() == 3
    led.mark_done([5])
    assert led.watermark() == 3
    led.mark_done([4])
    assert led.watermark() == 5 and led.backlog() == 0
    assert led.tail() == (5, 50)


def test_ledger_resume_initialization():
    """On resume the ledger starts at the checkpoint watermark with the
    WAL tail pending — a checkpoint can never claim unreplayed
    progress."""
    led = FrameLedger(watermark=10, tail_seq=14, tail_offset=700)
    assert led.watermark() == 10 and led.backlog() == 4
    led.mark_done([11, 12, 13, 14])
    assert led.watermark() == 14


# ---------------------------------------------------------------------------
# spec + compile-time validation
# ---------------------------------------------------------------------------

def test_durable_spec_validation(tmp_path):
    with pytest.raises(ValueError, match="fsync"):
        DurableSpec(dir=str(tmp_path), fsync="sometimes")
    with pytest.raises(ValueError, match="dir"):
        DurableSpec(dir="")
    with pytest.raises(ValueError, match="checkpoint_interval_s"):
        DurableSpec(dir=str(tmp_path), checkpoint_interval_s=0)
    s = DurableSpec(dir=str(tmp_path))
    assert s.wal_dir.endswith("intake") and s.store_dir.endswith("store")


def test_plan_rejects_durable_on_socket_adapter(tmp_path):
    ad = SocketAdapter("127.0.0.1", 0, frame_size=10)
    try:
        p = (pipeline(ad, "sock").parse(batch_size=10)
             .store(durable=DurableSpec(dir=str(tmp_path))))
        with pytest.raises(PlanError, match="resumable"):
            p.compile(RefStore())
        with pytest.raises(NotResumableError):
            ad.resume(0)
    finally:
        ad.stop()
        ad._srv.close()


def test_plan_rejects_durable_on_multi_group_and_per_record(tmp_path):
    mgr = make_manager()
    p = (pipeline(SyntheticAdapter(total=0, frame_size=50), "mg")
         .parse(batch_size=50)
         .enrich(Q.Q1)
         .enrich(Q.Q2, partitions=2)          # opens a second stage group
         .store(durable=DurableSpec(dir=str(tmp_path))))
    with pytest.raises(PlanError, match="stage group"):
        p.compile(mgr.refstore)
    p2 = (pipeline(SyntheticAdapter(total=0, frame_size=50), "pr")
          .parse(batch_size=50, model="per_record")
          .enrich(Q.Q1)
          .store(durable=DurableSpec(dir=str(tmp_path))))
    with pytest.raises(PlanError, match="per_record"):
        p2.compile(mgr.refstore)


def test_store_durable_coercion_and_spill_default(tmp_path):
    mgr = make_manager()
    p = (pipeline(SyntheticAdapter(total=0, frame_size=50), "dc")
         .parse(batch_size=50).enrich(Q.Q1)
         .store(durable={"dir": str(tmp_path)}))      # dict coerces
    plan = p.compile(mgr.refstore)
    spec = plan.store_spec
    assert spec.durable.dir == str(tmp_path)
    # a durable feed without a durable store would be pointless: the
    # replay dedup needs the recovered pk index
    assert spec.spill_dir == spec.durable.store_dir
    with pytest.raises(PlanError, match="durable"):
        (pipeline(SyntheticAdapter(total=0, frame_size=50), "dx")
         .parse(batch_size=50).store(durable=42))


def test_create_refuses_dirty_durable_dir(tmp_path):
    spec = DurableSpec(dir=str(tmp_path))
    rt = DurabilityRuntime.create(spec)
    rt.wal.append_frame(1, [b"x"])
    rt.wal.close()
    with pytest.raises(RuntimeError, match="resume"):
        DurabilityRuntime.create(spec)


def test_file_adapter_resumes_mid_file(tmp_path):
    path = str(tmp_path / "in.jsonl")
    lines = [b'{"n": %d}' % i for i in range(10)]
    with open(path, "wb") as f:
        f.write(b"\n".join(lines) + b"\n")
    ad = FileAdapter(path, frame_size=3)
    it = ad.frames()
    assert next(it) == lines[:3]
    off = ad.offset
    ad.stop()
    re = FileAdapter(path, frame_size=3)
    re.resume(off)
    got = [ln for fr in re.frames() for ln in fr]
    assert got == lines[3:]


def test_synthetic_adapter_resume_is_deterministic():
    full = [ln for fr in SyntheticAdapter(total=100, frame_size=10,
                                          seed=5).frames() for ln in fr]
    re = SyntheticAdapter(total=100, frame_size=10, seed=5)
    re.resume(37)
    tail = [ln for fr in re.frames() for ln in fr]
    assert tail == full[37:]
    assert re.offset == 100
    with pytest.raises(ValueError):
        re.resume(101)


# ---------------------------------------------------------------------------
# durable feed: clean run, no-op resume, crash-image resume
# ---------------------------------------------------------------------------

def test_durable_feed_clean_run_then_noop_resume(tmp_path):
    d = tmp_path / "dur"
    mgr = make_manager()
    h = mgr.submit(durable_plan(mgr, d, total=600, batch=50))
    stats = h.join()
    assert stats.records_in == 600
    assert_exactly_once(h.storage, 600)
    ck = CheckpointStore(str(d)).load()
    assert ck is not None
    assert ck["watermark"] == ck["last_seq"] > 0
    assert ck["last_offset"] == 600
    assert ck["partitions"] == {h.stage_groups[0].name: 2}
    # resume after a clean shutdown: nothing to replay, nothing to
    # re-obtain, and the recovered store is byte-identical
    mgr2 = make_manager()
    h2 = mgr2.resume(durable_plan(mgr2, d, total=600, batch=50))
    assert h2.durability.recovered
    assert h2.durability.replayed_records == 0
    assert h2.join().records_in == 0
    assert_exactly_once(h2.storage, 600)


def copy_crash_image(src, dst):
    """Copy a live durable dir in crash-causal order: checkpoints first,
    then store manifests, then data files (WAL segments, npz segments) —
    so a reference in a copied metadata file always points at data that
    was copied *later* (hence at least as new), exactly the invariant
    the fsync ordering gives a real crash.  Tolerates files vanishing
    mid-walk."""
    paths = []
    for root, _, names in os.walk(src):
        for n in names:
            p = os.path.join(root, n)
            if n.endswith(".tmp"):
                continue
            if n.startswith("CHECKPOINT"):
                rank = 0
            elif n.startswith("MANIFEST"):
                rank = 1
            else:
                rank = 2
            paths.append((rank, p))
    for _, p in sorted(paths):
        rel = os.path.relpath(p, src)
        out = os.path.join(dst, rel)
        os.makedirs(os.path.dirname(out), exist_ok=True)
        try:
            shutil.copyfile(p, out)
        except FileNotFoundError:
            continue


def test_crash_image_resume_is_exactly_once(tmp_path):
    """The tentpole invariant, in-process: copy the durable dir at
    random moments while a rate-limited durable feed runs (mid-write
    copies are crash images), then resume every image in a fresh
    process-image (fresh manager/refstore/adapter) and verify zero rows
    lost, zero duplicated."""
    total, batch = 600, 25
    d = tmp_path / "dur"
    mgr = make_manager()
    plan = durable_plan(mgr, d, total=total, batch=batch, rate=1500.0,
                        checkpoint_interval_s=0.1, fsync_interval_s=0.02)
    rng = random.Random(11)
    images = [str(tmp_path / f"img{i}") for i in range(3)]
    h = mgr.submit(plan)
    t_run = total / 1500.0
    for img in images:
        time.sleep(rng.uniform(0.05, t_run / 2))
        copy_crash_image(str(d), img)
    h.join()
    assert_exactly_once(h.storage, total)
    for img in images:
        mgr2 = make_manager()
        # plan points at the ORIGINAL dir; durable_dir re-points it (the
        # _override_dir path, spill_dir re-derived)
        p2 = durable_plan(mgr2, d, total=total, batch=batch)
        h2 = mgr2.resume(p2, durable_dir=img)
        assert h2.durability.recovered
        stats = h2.join()
        assert_exactly_once(h2.storage, total)
        # the resumed run re-obtained the unlogged suffix from the
        # adapter and/or replayed the WAL tail; both are bounded by the
        # original total
        assert stats.records_in <= total
        ck = CheckpointStore(img).load()
        assert ck["watermark"] == ck["last_seq"]


def test_crash_image_resume_exactly_once_with_live_merging(tmp_path):
    """Leveled merging must not weaken the exactly-once contract: crash
    images are taken while segment merges rewrite the store (manifest
    commits BEFORE replaced files are GC'd), and every image resumes to
    zero rows lost, zero duplicated."""
    from repro.core import CompactionSpec

    total, batch = 600, 25
    d = tmp_path / "dur"
    mgr = make_manager()

    def merging_plan(m):
        p = (pipeline(SyntheticAdapter(total=total, frame_size=batch,
                                       seed=3, rate=1000.0), "mp")
             .parse(batch_size=batch)
             .options(num_partitions=2, holder_capacity=16)
             .enrich(Q.Q1)
             .store(segment_rows=50, sort_key="country",
                    compact=CompactionSpec(interval_s=0.05,
                                           budget_rows_s=500_000.0,
                                           # never yield: the point is
                                           # merging DURING ingestion
                                           yield_backlog_batches=1e9,
                                           merge_fanin=3,
                                           level_target_rows=100_000),
                    durable=DurableSpec(dir=str(d),
                                        checkpoint_interval_s=0.1,
                                        fsync_interval_s=0.02)))
        return p.compile(m.refstore)

    rng = random.Random(13)
    images = [str(tmp_path / f"mimg{i}") for i in range(3)]
    h = mgr.submit(merging_plan(mgr))
    for img in images:
        time.sleep(rng.uniform(0.1, 0.25))
        # force a synchronous merge right before the copy so every image
        # holds a just-merged (or mid-GC) layout, independent of the
        # background scheduler's timing; the background job keeps
        # merging concurrently as well
        h.compaction.merge_now(min_run=2)
        copy_crash_image(str(d), img)
    h.join()
    assert_exactly_once(h.storage, total)
    # merges really ran while the images were taken
    assert h.stats.compaction is not None
    assert h.stats.compaction.merges > 0
    assert any(lv > 0 for lv in h.storage.level_histogram())
    for img in images:
        mgr2 = make_manager()
        h2 = mgr2.resume(merging_plan(mgr2), durable_dir=img)
        assert h2.durability.recovered
        h2.join()
        assert_exactly_once(h2.storage, total)
        # the resumed store merges too (levels recover through format 3)
        assert h2.storage.segment_count >= 1


def test_stop_mid_feed_then_resume_completes_stream(tmp_path):
    """A feed stopped mid-stream leaves a partial durable dir; a fresh
    process resumes it and completes the stream exactly-once."""
    total, batch = 800, 25
    d = tmp_path / "dur"
    mgr = make_manager()
    plan = durable_plan(mgr, d, total=total, batch=batch, rate=2000.0,
                        checkpoint_interval_s=0.1, fsync_interval_s=0.01)
    h = mgr.submit(plan)
    time.sleep(0.15)
    h.stop()                  # adapter dies mid-stream: a partial feed
    h.join()
    assert 0 < h.stats.records_in <= total
    # the durable dir now looks like a crash at the stop point; a fresh
    # "process" resumes and completes the stream
    mgr2 = make_manager()
    h2 = mgr2.resume(durable_plan(mgr2, d, total=total, batch=batch))
    h2.join()
    assert_exactly_once(h2.storage, total)


# ---------------------------------------------------------------------------
# repair event-log checkpoint/restore + lineage trust
# ---------------------------------------------------------------------------

def test_repair_event_snapshot_restore_round_trip(tmp_path):
    mgr = make_manager()
    plan = durable_plan(mgr, tmp_path / "d0", refresh=RepairSpec())
    job = RepairJob(plan, StorageJob(1), mgr.refstore, mgr.predeploy)
    t = mgr.refstore["safety_levels"]
    t.upsert(np.arange(4, dtype=np.int64),
             safety_level=np.full(4, 2, np.int32))
    t.upsert(np.arange(90000, 90002, dtype=np.int64),
             safety_level=np.full(2, 1, np.int32))
    img = job.snapshot_events()
    job.stop()
    assert len(img["safety_levels"]) == 2
    json.dumps(img)                       # checkpoint-serializable
    job2 = RepairJob(plan, StorageJob(1), mgr.refstore, mgr.predeploy)
    job2.restore_events(img)
    with job2._events_lock:
        evs = list(job2._events["safety_levels"])
    assert [e.version for e in evs] == \
        [e[0] for e in img["safety_levels"]]
    assert evs[0].keys.tolist() == [0, 1, 2, 3]
    assert job2._oldest_pending is not None
    job2.stop()


def test_resume_restores_repair_events_when_fingerprints_match(tmp_path):
    """Same rebuilt reference state -> the checkpointed event journal is
    trusted and lineage survives: resuming a converged feed repairs
    nothing."""
    d = tmp_path / "dur"
    mgr = make_manager()
    h = mgr.submit(durable_plan(mgr, d, total=400, batch=50,
                                refresh=RepairSpec()))
    h.join()
    ck = CheckpointStore(str(d)).load()
    assert "ref_fingerprints" in ck and "repair_events" in ck
    mgr2 = make_manager()                 # same seed -> same tables
    h2 = mgr2.resume(durable_plan(mgr2, d, total=400, batch=50,
                                  refresh=RepairSpec()))
    stats = h2.join()
    assert_exactly_once(h2.storage, 400)
    assert stats.repaired_rows == 0       # lineage trusted: nothing stale


def test_resume_resets_lineage_on_fingerprint_mismatch(tmp_path):
    """Changed reference state across the restart -> recovered lineage
    is meaningless: it must degrade to a full re-scan that re-enriches
    against the CURRENT tables (never silently-current)."""
    d = tmp_path / "dur"
    mgr = make_manager()
    h = mgr.submit(durable_plan(mgr, d, total=400, batch=50,
                                refresh=RepairSpec()))
    h.join()
    mgr2 = make_manager()
    t = mgr2.refstore["safety_levels"]
    snap = t.snapshot()
    keys = np.asarray(snap.arrays["key"][:snap.size][:50], np.int64)
    t.upsert(keys, safety_level=np.full(keys.size, 4, np.int32))
    h2 = mgr2.resume(durable_plan(mgr2, d, total=400, batch=50,
                                  refresh=RepairSpec()))
    stats = h2.join()
    assert_exactly_once(h2.storage, 400)
    # full re-scan happened and the store converged to the NEW table
    assert_store_current(mgr2, h2.storage)
    assert stats.repair is not None and stats.repair.units_scanned > 0


def test_resume_at_learned_scale(tmp_path):
    d = tmp_path / "dur"
    mgr = make_manager()
    h = mgr.submit(durable_plan(mgr, d, total=200, batch=50))
    h.join()
    gname = h.stage_groups[0].name
    ck = CheckpointStore(str(d))
    state = ck.load()
    assert state["partitions"] == {gname: 2}
    state["partitions"][gname] = 3        # pretend elasticity learned 3
    ck.save(state)
    mgr2 = make_manager()
    h2 = mgr2.resume(durable_plan(mgr2, d, total=200, batch=50))
    assert len(h2.stage_groups[0].holders) == 3
    h2.join()
    assert_exactly_once(h2.storage, 200)
