"""Q1-Q7 + UDF1/UDF2 against brute-force numpy oracles on small-scale
reference tables, plus the Model-1/2/3 freshness semantics of §5.3 — the
paper's central correctness claim."""

import numpy as np
import pytest

from repro.core import ComputingRunner, ComputingSpec, RefStore
from repro.core import records
from repro.core.enrich import queries as Q
from repro.core.records import SyntheticTweets, parse_json_lines
from repro.core.refdata import KEY_SENTINEL

SCALE = 0.002   # 50k-row tables -> 100 rows; persons/suspicious -> 2000


@pytest.fixture(scope="module")
def store():
    s = RefStore()
    Q.make_reference_tables(s, scale=SCALE, seed=7)
    return s


@pytest.fixture(scope="module")
def tweets():
    src = SyntheticTweets(seed=3)
    return parse_json_lines(src.raw_lines(64))


def run_udf(store, udf, batch, model="per_batch", refresh="always"):
    runner = ComputingRunner(
        ComputingSpec(udf, batch["id"].shape[0], model, refresh), store)
    return runner, runner.run(batch)


def snap(store, name):
    s = store[name].snapshot()
    valid = s.arrays["key"] != KEY_SENTINEL
    return s.arrays, valid


# ---------------------------------------------------------------------------
# individual UDFs vs oracles
# ---------------------------------------------------------------------------

def test_q1_safety_level(store, tweets):
    _, out = run_udf(store, Q.Q1, tweets)
    arrays, valid = snap(store, "safety_levels")
    table = {int(k): int(v) for k, v, ok in
             zip(arrays["key"], arrays["safety_level"], valid) if ok}
    for i in range(len(tweets["id"])):
        want = table.get(int(tweets["country"][i]), -1)
        assert out["safety_level"][i] == want


def test_q2_religious_population(store, tweets):
    _, out = run_udf(store, Q.Q2, tweets)
    arrays, valid = snap(store, "religious_populations")
    for i in range(len(tweets["id"])):
        c = int(tweets["country"][i])
        want = int(arrays["population"][(arrays["country"] == c)
                                        & valid].sum())
        assert out["religious_population"][i] == want


def test_q3_largest_religions(store, tweets):
    _, out = run_udf(store, Q.Q3, tweets)
    arrays, valid = snap(store, "religious_populations")
    for i in range(len(tweets["id"])):
        c = int(tweets["country"][i])
        rows = np.where((arrays["country"] == c) & valid)[0]
        want_vals = sorted(arrays["population"][rows], reverse=True)[:3]
        got = out["largest_religions"][i]
        got_rows = [r for r in got if r >= 0]
        assert len(got_rows) == len(want_vals)
        # religions claimed must be real rows of this country with the
        # right (multiset of) populations
        got_vals = []
        for rel in got_rows:
            match = rows[arrays["religion"][rows] == rel]
            assert match.size > 0
            got_vals.append(int(arrays["population"][match].max()))
        assert sorted(got_vals, reverse=True)[:len(want_vals)] == \
            sorted(want_vals, reverse=True) or \
            sorted(got_vals, reverse=True) == want_vals


def test_q4_nearby_monuments(store, tweets):
    _, out = run_udf(store, Q.Q4, tweets)
    arrays, valid = snap(store, "monuments")
    pts = np.stack([tweets["lat"], tweets["lon"]], 1)
    refs = np.stack([arrays["lat"], arrays["lon"]], 1)
    d2 = ((pts[:, None] - refs[None]) ** 2).sum(-1)
    d2 = np.where(valid[None, :], d2, np.inf)
    for i in range(len(tweets["id"])):
        hits = np.where(d2[i] <= Q.Q4_RADIUS ** 2)[0]
        assert out["nearby_monument_count"][i] == len(hits)
        want_ids = set(arrays["key"][hits[np.argsort(d2[i][hits])][
            :Q.Q4_K]].tolist())
        got_ids = set(int(g) for g in out["nearby_monuments"][i] if g >= 0)
        assert got_ids == want_ids


def test_q5_suspicious_names(store, tweets):
    _, out = run_udf(store, Q.Q5, tweets)
    sn, sn_valid = snap(store, "suspicious_names")
    threat = {int(k): int(t) for k, t, ok in
              zip(sn["key"], sn["threat_level"], sn_valid) if ok}
    fac, fac_valid = snap(store, "facilities")
    pts = np.stack([tweets["lat"], tweets["lon"]], 1)
    frefs = np.stack([fac["lat"], fac["lon"]], 1)
    fd2 = ((pts[:, None] - frefs[None]) ** 2).sum(-1)
    for i in range(len(tweets["id"])):
        assert out["suspect_threat_level"][i] == threat.get(
            int(tweets["user_name_hash"][i]), -1)
        hits = (fd2[i] <= Q.Q5_RADIUS ** 2) & fac_valid
        for ft in range(Q.NUM_FACILITY_TYPES):
            assert out["nearby_facility_counts"][i][ft] == \
                int((hits & (fac["ftype"] == ft)).sum())


def test_q6_tweet_context(store, tweets):
    _, out = run_udf(store, Q.Q6, tweets)
    dst, dvalid = snap(store, "district_areas")
    inc, ivalid = snap(store, "average_incomes")
    per, pvalid = snap(store, "persons")
    income = {int(k): float(v) for k, v, ok in
              zip(inc["key"], inc["income"], ivalid) if ok}

    def district_of(lat, lon):
        inside = ((lat >= dst["xmin"]) & (lon >= dst["ymin"])
                  & (lat <= dst["xmax"]) & (lon <= dst["ymax"]) & dvalid)
        hits = np.where(inside)[0]
        return int(hits[0]) if hits.size else -1

    for i in range(0, len(tweets["id"]), 7):
        d = district_of(tweets["lat"][i], tweets["lon"][i])
        assert out["district"][i] == d
        if d < 0:
            assert out["area_avg_income"][i] == 0.0
            continue
        assert abs(out["area_avg_income"][i]
                   - income.get(int(dst["key"][d]), 0.0)) < 1e-3
        # ethnicity distribution oracle for this district
        pin = ((per["lat"] >= dst["xmin"][d]) & (per["lon"] >= dst["ymin"][d])
               & (per["lat"] <= dst["xmax"][d])
               & (per["lon"] <= dst["ymax"][d]) & pvalid)
        # person counts only in their FIRST matching district
        for j in np.where(pin)[0]:
            if district_of(per["lat"][j], per["lon"][j]) != d:
                pin[j] = False
        for e in range(Q.NUM_ETHNICITIES):
            assert out["area_ethnicity_dist"][i][e] == \
                int((pin & (per["ethnicity"] == e)).sum())


def test_q7_worrisome(store, tweets):
    _, out = run_udf(store, Q.Q7, tweets)
    ev, evalid = snap(store, "attack_events")
    for i in range(len(tweets["id"])):
        t = int(tweets["created_at"][i])
        for k in range(Q.Q7_K):
            rel = int(out["nearby_religions"][i][k])
            if rel < 0:
                assert out["religion_attack_counts"][i][k] == 0
                continue
            want = int(((ev["religion"] == rel) & evalid
                        & (ev["time"] < t)
                        & (ev["time"] > t - Q.TWO_MONTHS)).sum())
            assert out["religion_attack_counts"][i][k] == want


def test_udf1_stateless(store, tweets):
    _, out = run_udf(store, Q.UDF1, tweets)
    for i in range(len(tweets["id"])):
        want = (int(tweets["country"][i]) == Q.US_CODE
                and Q.BOMB_HASH in tweets["text_tokens"][i])
        assert bool(out["safety_check_flag"][i]) == want


def test_udf2_matches_oracle(store, tweets):
    _, out = run_udf(store, Q.UDF2, tweets)
    sw, valid = snap(store, "sensitive_words")
    for i in range(len(tweets["id"])):
        c = int(tweets["country"][i])
        toks = set(int(t) for t in tweets["text_tokens"][i] if t != 0)
        want = any(ok and sw["country"][j] == c and int(sw["word"][j]) in toks
                   for j, ok in enumerate(valid))
        assert bool(out["safety_check_flag"][i]) == want


# ---------------------------------------------------------------------------
# §5.3 freshness semantics: the reason the paper exists
# ---------------------------------------------------------------------------

def _fresh_store():
    s = RefStore()
    t = s.create("religious_populations", 64,
                 {"country": np.int32, "religion": np.int32,
                  "population": np.int32})
    t.upsert(np.array([0, 1], np.int64),
             country=np.array([5, 5], np.int32),
             religion=np.array([1, 2], np.int32),
             population=np.array([100, 200], np.int32))
    return s


def _one_tweet_batch(country=5):
    b = records.empty_batch(4)
    b["id"][:] = np.arange(4)
    b["country"][:] = country
    b["valid"][:] = True
    return b


@pytest.mark.parametrize("model,refresh,sees_update", [
    ("per_record", "always", True),    # Model 1: always fresh
    ("per_batch", "always", True),     # Model 2: fresh at batch boundary
    ("per_batch", "version", True),    # version-gated Model 2: still fresh
    ("stream", "always", False),       # Model 3: stale (Fig 15 failure mode)
])
def test_freshness_semantics(model, refresh, sees_update):
    store = _fresh_store()
    runner = ComputingRunner(ComputingSpec(Q.Q2, 4, model, refresh), store)
    out1 = runner.run(_one_tweet_batch())
    assert out1["religious_population"][0] == 300
    # mid-ingestion UPSERT (the paper's new-keyword scenario)
    store["religious_populations"].upsert(
        np.array([2], np.int64), country=np.array([5], np.int32),
        religion=np.array([3], np.int32),
        population=np.array([1000], np.int32))
    out2 = runner.run(_one_tweet_batch())
    want = 1300 if sees_update else 300
    assert out2["religious_population"][0] == want


def test_version_gated_rebuild_skips_quiet_batches():
    """Beyond-paper: version-gated Model 2 builds state once per *version*,
    not once per batch — but never serves stale state."""
    store = _fresh_store()
    runner = ComputingRunner(
        ComputingSpec(Q.Q2, 4, "per_batch", "version"), store)
    for _ in range(5):
        runner.run(_one_tweet_batch())
    assert runner.stats.state_builds == 1
    assert runner.stats.state_reuses == 4
    store["religious_populations"].upsert(
        np.array([9], np.int64), country=np.array([5], np.int32),
        religion=np.array([9], np.int32), population=np.array([7], np.int32))
    out = runner.run(_one_tweet_batch())
    assert runner.stats.state_builds == 2
    assert out["religious_population"][0] == 307


def test_paper_faithful_model2_rebuilds_every_batch():
    store = _fresh_store()
    runner = ComputingRunner(
        ComputingSpec(Q.Q2, 4, "per_batch", "always"), store)
    for _ in range(3):
        runner.run(_one_tweet_batch())
    assert runner.stats.state_builds == 3
    assert runner.stats.state_reuses == 0
