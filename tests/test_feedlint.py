"""feedlint self-tests: one seeded violation per rule (R1-R6) that must
fire, a clean counterpart per rule that must NOT (false-positive guard),
the ``Annotated[..., guarded_by(...)]`` declaration form, the CLI exit
codes the CI gate relies on, and the integration pin that the real
``src/repro`` tree is finding-free.

Deliberately hypothesis-free and stdlib-only beyond the repo itself: the
analyzer never imports the code it scans, so these fixtures are plain
source strings written to tmp_path.
"""

import subprocess
import sys
from pathlib import Path

from repro.analysis.feedlint import run_paths

REPO = Path(__file__).resolve().parents[1]


def lint_src(tmp_path, source, name="fixture.py", extra_order=()):
    f = tmp_path / name
    f.write_text(source)
    return run_paths([str(f)], extra_order=extra_order)


def rules_of(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# R1 guarded-field
# ---------------------------------------------------------------------------

R1_VIOLATION = '''
import threading

class Counter:
    def __init__(self):
        self._lock = threading.Lock()   # lock-name: counter
        self._n = 0                     # guarded-by: _lock

    def bump(self):
        self._n += 1                    # BAD: write outside the lock
'''

R1_CLEAN = '''
import threading

class Counter:
    def __init__(self):
        self._lock = threading.Lock()   # lock-name: counter
        self._n = 0                     # guarded-by: _lock

    def bump(self):
        with self._lock:
            self._n += 1

    def _bump_locked(self):             # requires-lock: _lock
        self._n += 1
'''


def test_r1_guarded_field_fires(tmp_path):
    findings = lint_src(tmp_path, R1_VIOLATION)
    assert rules_of(findings) == ["guarded-field"]
    assert "_n" in findings[0].msg


def test_r1_clean_counterpart(tmp_path):
    assert lint_src(tmp_path, R1_CLEAN) == []


def test_r1_write_guarded_allows_lock_free_reads(tmp_path):
    src = R1_VIOLATION.replace("# guarded-by:", "# write-guarded-by:")
    findings = lint_src(tmp_path, src)
    assert rules_of(findings) == ["guarded-field"]   # the write still fires
    read_only = src.replace("self._n += 1", "return self._n")
    assert lint_src(tmp_path, read_only) == []


def test_r1_annotated_helper_form(tmp_path):
    src = '''
import threading
from typing import Annotated
from repro.analysis.annotations import guarded_by

class Counter:
    _n: Annotated[int, guarded_by("_lock")]

    def __init__(self):
        self._lock = threading.Lock()   # lock-name: counter
        self._n = 0

    def peek(self):
        return self._n                  # BAD: read outside the lock
'''
    findings = lint_src(tmp_path, src)
    assert rules_of(findings) == ["guarded-field"]


def test_r1_module_level_global(tmp_path):
    src = '''
import threading

_lock = threading.Lock()    # lock-name: stats
_hits = {}                  # guarded-by: _lock

def bump(k):
    _hits[k] = _hits.get(k, 0) + 1      # BAD

def bump_locked(k):
    with _lock:
        _hits[k] = _hits.get(k, 0) + 1
'''
    findings = lint_src(tmp_path, src)
    assert rules_of(findings) == ["guarded-field"]
    assert all(f.line < src[:src.index("bump_locked")].count("\n") + 2
               for f in findings)


# ---------------------------------------------------------------------------
# R2 lock-order
# ---------------------------------------------------------------------------

R2_NESTED = '''
import threading

class Pair:
    def __init__(self):
        self._a = threading.Lock()      # lock-name: alpha
        self._b = threading.Lock()      # lock-name: beta

    def both(self):
        with self._a:
            with self._b:
                pass
'''


def test_r2_undeclared_nesting_fires(tmp_path):
    findings = lint_src(tmp_path, R2_NESTED)
    assert rules_of(findings) == ["lock-order"]
    assert "alpha" in findings[0].msg and "beta" in findings[0].msg


def test_r2_declared_nesting_is_clean(tmp_path):
    src = "# feedlint: order alpha -> beta\n" + R2_NESTED
    assert lint_src(tmp_path, src) == []


def test_r2_extra_order_parameter(tmp_path):
    assert lint_src(tmp_path, R2_NESTED,
                    extra_order=[("alpha", "beta")]) == []


def test_r2_cycle_fires_even_when_both_edges_declared(tmp_path):
    src = ("# feedlint: order alpha -> beta\n"
           "# feedlint: order beta -> alpha\n" + R2_NESTED)
    findings = lint_src(tmp_path, src)
    assert "lock-order" in rules_of(findings)
    assert any("cycle" in f.msg for f in findings)


def test_r2_nesting_through_a_callee_is_seen(tmp_path):
    src = '''
import threading

class Pair:
    def __init__(self):
        self._a = threading.Lock()      # lock-name: alpha
        self._b = threading.Lock()      # lock-name: beta

    def inner(self):
        with self._b:
            pass

    def outer(self):
        with self._a:
            self.inner()                # BAD: alpha -> beta via call
'''
    findings = lint_src(tmp_path, src)
    assert rules_of(findings) == ["lock-order"]


# ---------------------------------------------------------------------------
# R3 no-blocking-under-lock
# ---------------------------------------------------------------------------

R3_VIOLATION = '''
import threading
import time

class Slow:
    def __init__(self):
        self._lock = threading.Lock()   # lock-name: slow

    def nap(self):
        with self._lock:
            time.sleep(0.1)             # BAD
'''


def test_r3_sleep_under_lock_fires(tmp_path):
    findings = lint_src(tmp_path, R3_VIOLATION)
    assert rules_of(findings) == ["blocking-under-lock"]
    assert "time.sleep" in findings[0].msg


def test_r3_clean_counterpart(tmp_path):
    src = R3_VIOLATION.replace("            time.sleep(0.1)             # BAD",
                               "            pass\n        time.sleep(0.1)")
    assert lint_src(tmp_path, src) == []


def test_r3_file_io_under_lock_fires(tmp_path):
    src = R3_VIOLATION.replace('time.sleep(0.1)             # BAD',
                               'open("/tmp/x")                # BAD')
    findings = lint_src(tmp_path, src)
    assert rules_of(findings) == ["blocking-under-lock"]


def test_r3_allow_comment_suppresses_with_reason(tmp_path):
    src = R3_VIOLATION.replace(
        "time.sleep(0.1)             # BAD",
        "time.sleep(0.1)  # feedlint: allow[blocking-under-lock] test rig")
    assert lint_src(tmp_path, src) == []


def test_r3_blocking_ok_lock_is_exempt(tmp_path):
    src = R3_VIOLATION.replace("# lock-name: slow",
                               "# lock-name: slow blocking-ok")
    assert lint_src(tmp_path, src) == []


# ---------------------------------------------------------------------------
# R4 epoch-fence
# ---------------------------------------------------------------------------

R4_VIOLATION = '''
def fix_rows(part, rows, idx, lineage):
    return part.repair_rows(rows, idx, lineage)     # BAD: unfenced
'''

R4_CLEAN = '''
def fix_rows(part, rows, idx, lineage, epoch):
    return part.repair_rows(rows, idx, lineage, expect_epoch=epoch)
'''


def test_r4_unfenced_repair_fires(tmp_path):
    findings = lint_src(tmp_path, R4_VIOLATION)
    assert rules_of(findings) == ["epoch-fence"]
    assert "expect_epoch" in findings[0].msg


def test_r4_fenced_call_is_clean(tmp_path):
    assert lint_src(tmp_path, R4_CLEAN) == []


def test_r4_exempt_inside_storage_py(tmp_path):
    # storage.py itself implements the primitives: no fence required
    assert lint_src(tmp_path, R4_VIOLATION, name="storage.py") == []


def test_r4_covers_delete_and_lineage_too(tmp_path):
    for fn in ("delete_rows", "update_lineage"):
        src = R4_VIOLATION.replace("repair_rows", fn)
        findings = lint_src(tmp_path, src)
        assert rules_of(findings) == ["epoch-fence"], fn


# ---------------------------------------------------------------------------
# R5 listener-outside-lock
# ---------------------------------------------------------------------------

R5_VIOLATION = '''
import threading

class Table:
    def __init__(self):
        self._lock = threading.Lock()   # lock-name: table
        self._version = 0               # guarded-by: _lock
        self._listeners = []            # guarded-by: _lock — listener-registry

    def _notify(self, listeners):       # fires-listeners
        for cb in listeners:
            cb()

    def publish(self):
        with self._lock:
            self._version += 1
            self._notify(list(self._listeners))     # BAD: under the lock
'''


def test_r5_fires_listeners_under_lock(tmp_path):
    findings = lint_src(tmp_path, R5_VIOLATION)
    assert rules_of(findings) == ["listener-under-lock"]


def test_r5_clean_counterpart(tmp_path):
    src = R5_VIOLATION.replace(
        "            self._version += 1\n"
        "            self._notify(list(self._listeners))     # BAD: under the lock",
        "            self._version += 1\n"
        "            listeners = list(self._listeners)\n"
        "        self._notify(listeners)")
    assert lint_src(tmp_path, src) == []


def test_r5_direct_registry_invocation_under_lock(tmp_path):
    src = '''
import threading

class Table:
    def __init__(self):
        self._lock = threading.Lock()   # lock-name: table
        self._listeners = []            # guarded-by: _lock — listener-registry

    def publish(self):
        with self._lock:
            for cb in self._listeners:
                cb()                    # BAD
'''
    findings = lint_src(tmp_path, src)
    assert "listener-under-lock" in rules_of(findings)


# ---------------------------------------------------------------------------
# R6 obs-under-lock
# ---------------------------------------------------------------------------

R6_VIOLATION = '''
import threading

class Stage:
    def __init__(self, hist, obs):
        self._lock = threading.Lock()   # lock-name: stage
        self._hist = hist
        self._obs = obs
        self._rows = 0                  # guarded-by: _lock

    def push(self, n, dt):
        with self._lock:
            self._rows += n
            self._hist.observe(dt)      # BAD: telemetry under the lock
'''


def test_r6_observe_under_lock_fires(tmp_path):
    findings = lint_src(tmp_path, R6_VIOLATION)
    assert rules_of(findings) == ["obs-under-lock"]
    assert ".observe()" in findings[0].msg


def test_r6_emit_under_lock_fires(tmp_path):
    src = R6_VIOLATION.replace("self._hist.observe(dt)",
                               "self._obs.emit('x', (), dt)")
    findings = lint_src(tmp_path, src)
    assert rules_of(findings) == ["obs-under-lock"]
    assert ".emit()" in findings[0].msg


def test_r6_clean_after_release(tmp_path):
    src = R6_VIOLATION.replace(
        "            self._rows += n\n"
        "            self._hist.observe(dt)      # BAD: telemetry under the lock",
        "            self._rows += n\n"
        "        self._hist.observe(dt)")
    assert lint_src(tmp_path, src) == []


def test_r6_counters_and_gauges_stay_legal_under_lock(tmp_path):
    src = R6_VIOLATION.replace("self._hist.observe(dt)",
                               "self._hist.inc(n) or self._hist.set(n)")
    assert lint_src(tmp_path, src) == []


def test_r6_blocking_ok_lock_is_exempt(tmp_path):
    src = R6_VIOLATION.replace("# lock-name: stage",
                               "# lock-name: stage blocking-ok")
    assert lint_src(tmp_path, src) == []


def test_r6_allow_comment_suppresses_with_reason(tmp_path):
    src = R6_VIOLATION.replace(
        "self._hist.observe(dt)      # BAD: telemetry under the lock",
        "self._hist.observe(dt)  # feedlint: allow[obs-under-lock] test rig")
    assert lint_src(tmp_path, src) == []


# the feedscope ops-server discipline (core/obs/server.py): HTTP handlers
# must render from snapshot()/drained copies, never observe/emit inside a
# strict lock window.  Fixture pair pins the rule on a server-ish shape.
R6_SERVER_VIOLATION = '''
import threading

class OpsRenderer:
    def __init__(self, obs):
        self._lock = threading.Lock()   # lock-name: renderer
        self._obs = obs
        self._hits = 0                  # guarded-by: _lock

    def render(self, t0, dt):
        with self._lock:
            self._hits += 1
            self._obs.emit("scrape", (), t0, dt)   # BAD: span under lock
            return self._obs.registry.exposition()
'''

R6_SERVER_CLEAN = '''
import threading

class OpsRenderer:
    def __init__(self, obs):
        self._lock = threading.Lock()   # lock-name: renderer
        self._obs = obs
        self._hits = 0                  # guarded-by: _lock

    def render(self, t0, dt):
        with self._lock:
            self._hits += 1
            snap = self._obs.registry.snapshot()
        self._obs.emit("scrape", (), t0, dt)       # outside: legal
        return snap
'''


def test_r6_server_render_emitting_under_lock_fires(tmp_path):
    findings = lint_src(tmp_path, R6_SERVER_VIOLATION)
    assert rules_of(findings) == ["obs-under-lock"]


def test_r6_server_snapshot_under_lock_emit_outside_is_clean(tmp_path):
    assert lint_src(tmp_path, R6_SERVER_CLEAN) == []


# ---------------------------------------------------------------------------
# CLI contract (what the CI job runs) + integration
# ---------------------------------------------------------------------------

def _cli(*paths):
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.feedlint", *paths],
        capture_output=True, text=True, env=env, cwd=str(REPO))


def test_cli_nonzero_on_violation_zero_on_clean(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(R1_VIOLATION)
    good = tmp_path / "good.py"
    good.write_text(R1_CLEAN)
    r = _cli(str(bad))
    assert r.returncode != 0
    assert "guarded-field" in r.stdout
    r = _cli(str(good))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 findings" in r.stdout


def test_real_tree_is_finding_free():
    """The annotated src/repro tree has zero findings — any true-positive
    the initial sweep surfaced was fixed, not suppressed silently (the
    suppressions that remain are audited in docs/CONCURRENCY.md)."""
    findings = run_paths([str(REPO / "src" / "repro")])
    assert findings == [], "\n".join(str(f) for f in findings)
