"""Dry-run machinery integration test: one cheap cell end-to-end in a
subprocess (512 placeholder devices never touch this process)."""

import json
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_dryrun_cell_produces_roofline_artifact(tmp_path):
    env = {**os.environ, "PYTHONPATH": "src"}
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "mamba2-130m", "--shape", "decode_32k",
         "--mesh", "single", "--tag", "testrun"],
        cwd="/root/repo", env=env, capture_output=True, text=True,
        timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    art = ("launch_artifacts/dryrun/"
           "mamba2-130m__decode_32k__single@testrun.json")
    r = json.load(open(art))
    assert r["status"] == "ok"
    assert r["chips"] == 256
    rf = r["roofline"]
    assert rf["flops_per_dev"] > 0
    assert rf["memory_s"] > 0
    assert rf["dominant"] in ("compute", "memory", "collective")
    assert not r["f64_leaks"]
    # decode is memory-bound on any sane reading of the hardware
    assert rf["dominant"] != "compute"
    os.remove(art)
