"""feedscope tests: journey reconstruction + critical-path attribution
(core/obs/profile.py), the SLO health model (core/obs/health.py), the
live ops endpoint (core/obs/server.py), the per-stage calibration split,
and the empty-histogram nan pins.

Deliberately hypothesis-free: CI runs this module in the minimal
plan-api container, so the feedscope surface is pinned even where the
property-test extras are not installed.
"""

import json
import math
import threading
import time

import pytest

from repro.core import (ComputingRunner, ComputingSpec, FeedManager,
                        MetricsRegistry, RefStore, SyntheticAdapter,
                        pipeline)
from repro.core.enrich import queries as Q
from repro.core.obs import (FeedHealthModel, HealthSpec, JourneyProfiler,
                            ProfileSpec, http_get)
from repro.core.records import SyntheticTweets


def make_manager(scale=0.002):
    store = RefStore()
    Q.make_reference_tables(store, scale=scale, seed=7)
    return FeedManager(store)


def span(name, ids, t0, dur=0.0):
    return {"name": name, "spans": list(ids), "t0": t0, "dur": dur}


# ---------------------------------------------------------------------------
# journey profiler: golden fractions, queue vs service, id unification
# ---------------------------------------------------------------------------

def test_profiler_golden_fractions_and_bottleneck_verdict():
    prof = JourneyProfiler()
    prof.ingest([
        span("intake.draw", [1], 0.0, 1.0),
        span("apply.q1", [1], 2.0, 3.0),      # 1s queue gap before it
        span("store.append", [1], 5.0, 1.0),
        span("store.flush", [1], 6.0, 2.0),
    ])
    rep = prof.report()
    assert rep.journeys == 1
    assert rep.complete == 1
    # service: draw 1, apply 3, append 1, flush 2; queue: 1s waiting for
    # apply -> total attributed 8s
    assert rep.hops["intake.draw"].frac == pytest.approx(1 / 8)
    assert rep.hops["apply.q1"].service_s == pytest.approx(3.0)
    assert rep.hops["apply.q1"].queue_s == pytest.approx(1.0)
    assert rep.hops["apply.q1"].frac == pytest.approx(4 / 8)
    assert rep.hops["store.flush"].frac == pytest.approx(2 / 8)
    assert rep.bottleneck == "apply.q1"
    assert rep.ranked[0] == ("apply.q1", pytest.approx(0.5))
    # visible latency: intake start 0.0 -> last hop end 8.0
    assert rep.visible_p95_s == pytest.approx(8.0)


def test_profiler_decomposes_queue_vs_service_time():
    prof = JourneyProfiler()
    # back-to-back hops: no queue time anywhere
    prof.ingest([span("intake.draw", [1], 0.0, 1.0),
                 span("store.append", [1], 1.0, 1.0)])
    # gapped hops on a second journey: 5s spent waiting for the store
    prof.ingest([span("intake.draw", [2], 10.0, 1.0),
                 span("store.append", [2], 16.0, 1.0)])
    rep = prof.report()
    sa = rep.hops["store.append"]
    assert sa.service_s == pytest.approx(2.0)
    assert sa.queue_s == pytest.approx(5.0)
    assert sa.queue_p95 == pytest.approx(5.0)
    assert sa.service_p50 == pytest.approx(1.0)
    # the wait dominates: the verdict blames the hop that was waited FOR
    assert rep.bottleneck == "store.append"


def test_profiler_unions_ids_across_coalesce_and_flush():
    prof = JourneyProfiler()
    prof.ingest([
        span("intake.draw", [1], 0.0, 0.1),
        span("intake.draw", [2], 0.2, 0.1),
        span("coalesce", [1, 2], 0.4),            # merges both draws
        span("apply.g", [1, 2], 0.5, 0.5),
        span("store.flush", [1, 2], 1.1, 0.2),
    ])
    rep = prof.report()
    assert rep.journeys == 1                      # one connected component
    assert rep.complete == 1
    assert rep.hops["intake.draw"].count == 2


def test_profiler_window_evicts_oldest_journeys():
    prof = JourneyProfiler(ProfileSpec(window=2))
    for i in range(1, 6):
        prof.ingest([span("intake.draw", [i], float(i), 0.1)])
    rep = prof.report()
    assert rep.journeys == 2
    # a late span for an evicted journey resurfaces as a fresh journey
    # (never corrupting a live one) and the window re-trims to bound
    prof.ingest([span("store.append", [1], 99.0, 0.1)])
    assert prof.report().journeys == 2


def test_profile_spec_validation():
    with pytest.raises(ValueError, match="window"):
        ProfileSpec(window=0)
    with pytest.raises(ValueError, match="trace_keep"):
        ProfileSpec(trace_keep=-1)


# ---------------------------------------------------------------------------
# end-to-end: a two-stage-group traced plan reconstructs a full journey
# ---------------------------------------------------------------------------

def test_two_stage_group_plan_reconstructs_complete_journeys(tmp_path):
    mgr = make_manager()
    plan = (pipeline(SyntheticAdapter(total=400, frame_size=50, seed=5),
                     "prof-groups")
            .parse(batch_size=50)
            .options(num_partitions=1, profile=True)
            .enrich(Q.Q1, partitions=1)
            .enrich(Q.Q2, partitions=1)      # second stage group
            .store(spill_dir=str(tmp_path), segment_rows=100))
    h = mgr.submit(plan)
    stats = h.join(timeout=120)
    assert stats.stored == 400
    rep = h.profile()
    assert rep is not None and rep.journeys > 0
    names = set(rep.hops)
    assert "intake.draw" in names
    # BOTH groups' apply hops joined the same journeys (the stamps now
    # survive _push_downstream — the old multi-group known limit)
    assert sum(1 for n in names if n.startswith("apply.")) == 2
    assert "store.append" in names
    # segment flushes carry the span ids buffered per storage partition,
    # closing journeys intake.draw -> ... -> store.flush
    assert "store.flush" in names
    assert rep.complete > 0
    assert rep.visible_p95_s > 0.0
    assert rep.bottleneck is not None
    # the verdict also lands as gauges for /metrics scrapes
    m = h.metrics()
    assert any(k.startswith("bottleneck_") and k.endswith("_frac")
               for k in m)


# ---------------------------------------------------------------------------
# health model: SLO rules and state transitions under an injected clock
# ---------------------------------------------------------------------------

def _snap(visible=None, wal=None, repair=None, **scalars):
    reg = MetricsRegistry()
    for name, vals in (("ingest_visible_latency_s", visible),
                       ("wal_fsync_s", wal), ("repair_currency_s", repair)):
        h = reg.histogram(name)
        for v in vals or ():
            h.observe(v)
    for k, v in scalars.items():
        reg.gauge(k).set(float(v))
    return reg.snapshot()


def test_health_ok_with_empty_signals():
    model = FeedHealthModel()
    rep = model.evaluate(_snap())
    assert rep.state == "ok" and rep.code == 0
    assert rep.reasons == []
    assert set(rep.rules) == {"visible_latency", "wal_fsync",
                              "repair_currency", "worker_errors",
                              "backlog_growth", "stalled"}


def test_health_degrades_on_latency_errors_and_repair_lag():
    spec = HealthSpec(visible_p95_s=0.5, wal_fsync_p95_s=0.1)
    model = FeedHealthModel(spec, max_lag_s=1.0)   # budget 2.0s w/ slack
    rep = model.evaluate(_snap(visible=[2.0] * 10, wal=[0.5] * 10,
                               repair=[5.0] * 10, worker_errors=2))
    assert rep.state == "degraded" and rep.code == 1
    assert rep.rules["visible_latency"] == "degraded"
    assert rep.rules["wal_fsync"] == "degraded"
    assert rep.rules["repair_currency"] == "degraded"
    assert rep.rules["worker_errors"] == "degraded"
    assert len(rep.reasons) == 4


def test_health_backlog_growth_needs_monotone_run():
    t = [0.0]
    model = FeedHealthModel(HealthSpec(backlog_growth_evals=3),
                            clock=lambda: t[0])
    for rows in (10, 20, 15):                 # not monotone
        assert model.evaluate(_snap(backlog_rows_now=rows,
                                    feed_stored=rows)
                              ).rules["backlog_growth"] == "ok"
    for i, rows in enumerate((30, 40, 50)):   # monotone x3 -> trips
        rep = model.evaluate(_snap(backlog_rows_now=rows,
                                   feed_stored=100 + i))
    assert rep.rules["backlog_growth"] == "degraded"
    assert rep.state == "degraded"


def test_health_stall_transition_and_recovery_with_injected_clock():
    t = [0.0]
    model = FeedHealthModel(HealthSpec(stall_after_s=5.0),
                            clock=lambda: t[0])
    base = dict(backlog_rows_now=100, feed_stored=7, sink_lm_batches=3)
    assert model.evaluate(_snap(**base)).state == "ok"     # anchors
    t[0] = 4.0
    assert model.evaluate(_snap(**base)).state == "ok"     # within budget
    t[0] = 6.0
    rep = model.evaluate(_snap(**base))                    # frozen > 5s
    assert rep.state == "stalled" and rep.code == 2
    assert rep.rules["stalled"] == "stalled"
    # ANY progress counter moving re-anchors (tee pulls count too)
    t[0] = 12.0
    moved = dict(base, sink_lm_batches=4)
    assert model.evaluate(_snap(**moved)).state == "ok"
    # so does an empty backlog, stalled-for however long
    t[0] = 50.0
    assert model.evaluate(_snap(backlog_rows_now=0,
                                feed_stored=8)).state == "ok"


def test_health_spec_validation():
    with pytest.raises(ValueError, match="backlog_growth_evals"):
        HealthSpec(backlog_growth_evals=1)
    with pytest.raises(ValueError, match="stall_after_s"):
        HealthSpec(stall_after_s=0.0)


# ---------------------------------------------------------------------------
# live ops endpoint: /metrics, /health, /profile, /trace over a real feed
# ---------------------------------------------------------------------------

def test_obs_server_smoke_and_health_flip_on_induced_stall():
    mgr = make_manager()
    gate = threading.Event()
    seen = []

    def blocked_sink(batch):
        gate.wait(timeout=60)
        seen.append(batch)

    plan = (pipeline(SyntheticAdapter(total=400, frame_size=50, seed=9),
                     "ops-feed")
            .parse(batch_size=50)
            .options(num_partitions=1, coalesce_rows=0, profile=True,
                     health={"stall_after_s": 0.3})
            .enrich(Q.Q1)
            .tee(blocked_sink, name="lm"))
    h = mgr.submit(plan)
    srv = mgr.serve_obs(port=0)
    assert mgr.serve_obs() is srv            # idempotent
    try:
        url = srv.url
        code, idx = http_get(url + "/")
        assert code == 200
        assert "/metrics" in json.loads(idx)["endpoints"]

        # the tee consumer is gated shut: backlog accumulates with zero
        # progress, so /health must flip to stalled (503) within the SLO
        status = None
        deadline = time.time() + 30
        while time.time() < deadline:
            status, body = http_get(url + "/health")
            if status == 503:
                break
            time.sleep(0.1)
        assert status == 503
        payload = json.loads(body)
        assert payload["stalled"] is True
        assert payload["feeds"]["ops-feed"]["state"] == "stalled"

        code, text = http_get(url + "/metrics")
        assert code == 200
        assert "# TYPE feed_stored counter" in text
        assert "feed_health" in text
        assert "backlog_rows_now" in text

        code, prof = http_get(url + "/profile")
        assert code == 200
        assert "ops-feed" in json.loads(prof)["feeds"]

        code, tr = http_get(url + "/trace")
        assert code == 200
        spans = json.loads(tr)["feeds"]["ops-feed"]
        assert any(s["name"] == "intake.draw" for s in spans)

        code, _ = http_get(url + "/nope")
        assert code == 404

        gate.set()                            # unblock: the feed drains
        stats = h.join(timeout=120)
        assert stats.sink_batches["lm"] == len(seen) > 0
        code, body = http_get(url + "/health")
        assert code == 200                    # feed gone or recovered
    finally:
        gate.set()
        mgr.stop_obs()
        mgr.stop_obs()                        # no-op when already stopped


# ---------------------------------------------------------------------------
# per-stage calibration: measured fractions replace the even split
# ---------------------------------------------------------------------------

def test_calibration_weights_attribution_for_fused_chains():
    store = RefStore()
    Q.make_reference_tables(store, scale=0.002, seed=7)
    udf = Q.chain("q1_then_q2", Q.Q1, Q.Q2)
    runner = ComputingRunner(ComputingSpec(udf, batch_size=50),
                             store, None)
    runner.CALIBRATE_EVERY = 1               # instance override: every batch
    frames = list(SyntheticTweets(seed=4).batches(150, 50))
    for f in frames:
        runner.run(f)
    st = runner.stats
    assert st.calibrations >= 1
    weights = runner._stage_weights
    assert weights is not None
    assert set(weights) == {u.name for u in udf.stages}
    assert sum(weights.values()) == pytest.approx(1.0)
    assert all(w > 0.0 for w in weights.values())
    # the measured split still conserves the batch walls: per-stage
    # apply_s sums to the chain's total apply_s
    per_stage_total = sum(ss.apply_s for ss in st.per_stage.values())
    assert per_stage_total == pytest.approx(st.apply_s, rel=1e-6)
    # calibration walls price the attribution, not the feed: apply_s
    # stays the fused dispatch wall only (invocations unchanged)
    assert st.invocations == len(frames)


def test_even_split_until_first_calibration():
    store = RefStore()
    Q.make_reference_tables(store, scale=0.002, seed=7)
    udf = Q.chain("q1q2_even", Q.Q1, Q.Q2)
    runner = ComputingRunner(ComputingSpec(udf, batch_size=50),
                             store, None)
    assert runner.CALIBRATE_EVERY > 3        # default: no calibration yet
    for f in SyntheticTweets(seed=2).batches(150, 50):
        runner.run(f)
    st = runner.stats
    assert st.calibrations == 0
    a, b = (st.per_stage[u.name].apply_s for u in udf.stages)
    assert a == pytest.approx(b)             # even split
    assert a + b == pytest.approx(st.apply_s, rel=1e-6)


# ---------------------------------------------------------------------------
# empty-histogram pins: percentiles are nan, exposition stays valid
# ---------------------------------------------------------------------------

def test_empty_histogram_percentile_is_nan_everywhere():
    reg = MetricsRegistry()
    h = reg.histogram("quiet_s")
    assert math.isnan(h.percentile(0.5))
    snap = reg.snapshot()["quiet_s"]
    assert snap.count == 0
    assert math.isnan(snap.percentile(0.95))
    assert snap.mean == 0.0                  # mean keeps its 0.0 default


def test_empty_histogram_renders_valid_exposition():
    reg = MetricsRegistry()
    reg.histogram("quiet_s", bounds=(0.1, 1.0))
    assert reg.exposition() == (
        "# TYPE quiet_s histogram\n"
        'quiet_s_bucket{le="0.1"} 0\n'
        'quiet_s_bucket{le="1"} 0\n'
        'quiet_s_bucket{le="+Inf"} 0\n'
        "quiet_s_sum 0\n"
        "quiet_s_count 0\n")
