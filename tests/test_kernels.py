"""Pallas kernel validation: interpret=True on CPU, swept over shapes and
dtypes, assert_allclose against the pure-jnp oracles (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.refdata import KEY_SENTINEL
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.hash_probe import ref as hp_ref
from repro.kernels.hash_probe.kernel import sorted_probe_pallas
from repro.kernels.segment_reduce import ref as sr_ref
from repro.kernels.segment_reduce.kernel import segment_sum_pallas
from repro.kernels.spatial_join import ref as sj_ref
from repro.kernels.spatial_join.kernel import radius_join_pallas


# ---------------------------------------------------------------------------
# segment_reduce
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
@pytest.mark.parametrize("r,s", [(100, 7), (2048, 128), (5000, 300),
                                 (1, 1), (4097, 129)])
def test_segment_sum_kernel(dtype, r, s):
    rng = np.random.default_rng(r + s)
    vals = jnp.asarray(rng.integers(0, 100, r).astype(dtype))
    seg = jnp.asarray(rng.integers(0, s, r).astype(np.int32))
    got = segment_sum_pallas(vals, seg, s, block_r=512, interpret=True)
    want = sr_ref.segment_sum(vals, seg, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6)


def test_segment_sum_kernel_drops_out_of_range():
    vals = jnp.asarray(np.array([1, 2, 3], np.int32))
    seg = jnp.asarray(np.array([0, 5, 0], np.int32))   # 5 >= num_segments
    got = segment_sum_pallas(vals, seg, 2, block_r=512, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), [4, 0])


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3000), st.integers(1, 200), st.integers(0, 2**31))
def test_segment_sum_kernel_property(r, s, seed):
    rng = np.random.default_rng(seed)
    vals = jnp.asarray(rng.normal(size=r).astype(np.float32))
    seg = jnp.asarray(rng.integers(0, s, r).astype(np.int32))
    got = segment_sum_pallas(vals, seg, s, block_r=256, interpret=True)
    want = sr_ref.segment_sum(vals, seg, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# hash_probe
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,r,cap", [(10, 8, 16), (600, 3000, 4096),
                                     (1, 1, 4), (513, 2049, 2100)])
def test_sorted_probe_kernel(b, r, cap):
    rng = np.random.default_rng(b * r)
    ref_real = rng.choice(10 * r, r, replace=False).astype(np.int64)
    keys = np.full((cap,), KEY_SENTINEL, np.int64)
    keys[:r] = np.sort(ref_real)
    probe = rng.integers(0, 12 * r, b).astype(np.int64)
    probe[0] = ref_real[0]                       # at least one hit
    kj, rj = jnp.asarray(probe), jnp.asarray(keys)
    gi, gf = sorted_probe_pallas(kj, rj, block_b=128, block_r=512,
                                 interpret=True)
    wi, wf = hp_ref.sorted_probe(kj, rj)
    np.testing.assert_array_equal(np.asarray(gf), np.asarray(wf))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


def test_sorted_probe_kernel_64bit_keys():
    """Hash keys above 2^32 exercise the (hi, lo) int32 split."""
    keys = np.sort(np.array([2**40 + 7, 2**55 + 1, 5], np.int64))
    cap = np.concatenate([keys, [KEY_SENTINEL]])
    probe = jnp.asarray(np.array([2**55 + 1, 2**40 + 7, 2**40 + 8, 5,
                                  KEY_SENTINEL], np.int64))
    gi, gf = sorted_probe_pallas(probe, jnp.asarray(cap), interpret=True)
    np.testing.assert_array_equal(np.asarray(gf),
                                  [True, True, False, True, False])
    np.testing.assert_array_equal(np.asarray(gi), [2, 1, -1, 0, -1])


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 500), st.integers(1, 1000), st.integers(0, 2**31))
def test_sorted_probe_kernel_property(b, r, seed):
    rng = np.random.default_rng(seed)
    ref_real = rng.choice(5 * r, r, replace=False).astype(np.int64)
    keys = jnp.asarray(np.sort(ref_real))
    probe = jnp.asarray(rng.integers(0, 6 * r, b).astype(np.int64))
    gi, gf = sorted_probe_pallas(probe, keys, block_b=128, block_r=256,
                                 interpret=True)
    wi, wf = hp_ref.sorted_probe(probe, keys)
    np.testing.assert_array_equal(np.asarray(gf), np.asarray(wf))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


# ---------------------------------------------------------------------------
# spatial_join
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,r,k", [(40, 60, 3), (300, 2000, 8), (1, 1, 2),
                                   (257, 1025, 1)])
def test_radius_join_kernel(b, r, k):
    rng = np.random.default_rng(b + r + k)
    px = jnp.asarray(rng.uniform(-10, 10, b).astype(np.float32))
    py = jnp.asarray(rng.uniform(-10, 10, b).astype(np.float32))
    rx = jnp.asarray(rng.uniform(-10, 10, r).astype(np.float32))
    ry = jnp.asarray(rng.uniform(-10, 10, r).astype(np.float32))
    valid = jnp.asarray((rng.random(r) < 0.9))
    gi, gd, gc = radius_join_pallas(px, py, rx, ry, 4.0, k, valid,
                                    block_b=128, block_r=256,
                                    interpret=True)
    wi, wd, wc = sj_ref.radius_join(px, py, rx, ry, 4.0, k, valid)
    np.testing.assert_array_equal(np.asarray(gc), np.asarray(wc))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_allclose(np.asarray(gd), np.asarray(wd),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 200), st.integers(1, 600), st.integers(1, 6),
       st.integers(0, 2**31))
def test_radius_join_kernel_property(b, r, k, seed):
    rng = np.random.default_rng(seed)
    px = jnp.asarray(rng.uniform(-8, 8, b).astype(np.float32))
    py = jnp.asarray(rng.uniform(-8, 8, b).astype(np.float32))
    rx = jnp.asarray(rng.uniform(-8, 8, r).astype(np.float32))
    ry = jnp.asarray(rng.uniform(-8, 8, r).astype(np.float32))
    gi, gd, gc = radius_join_pallas(px, py, rx, ry, 3.0, k,
                                    block_b=64, block_r=128, interpret=True)
    wi, wd, wc = sj_ref.radius_join(px, py, rx, ry, 3.0, k)
    np.testing.assert_array_equal(np.asarray(gc), np.asarray(wc))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,kv,d", [
    (1, 256, 4, 4, 64),      # MHA
    (2, 256, 8, 2, 64),      # GQA 4:1
    (1, 384, 4, 1, 128),     # MQA, odd seq blocks
    (1, 128, 4, 4, 112),     # kimi-k2 head_dim (padded to 128)
])
def test_flash_attention_kernel(dtype, b, s, h, kv, d):
    rng = np.random.default_rng(s + h + d)
    q = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32),
                    dtype=dtype)
    k = jnp.asarray(rng.normal(size=(b, s, kv, d)).astype(np.float32),
                    dtype=dtype)
    v = jnp.asarray(rng.normal(size=(b, s, kv, d)).astype(np.float32),
                    dtype=dtype)
    got = flash_attention_pallas(q, k, v, causal=True, block_q=128,
                                 block_k=128, interpret=True)
    want = fa_ref.flash_attention(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


def test_flash_attention_noncausal():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 128, 2, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 64)).astype(np.float32))
    got = flash_attention_pallas(q, k, v, causal=False, interpret=True)
    want = fa_ref.flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_matches_model_sdpa():
    """The kernel and the model's XLA chunked path agree — they are two
    lowerings of the same attention (layers._sdpa is the dry-run path)."""
    from repro.configs import smoke_config
    from repro.models import layers as L
    cfg = smoke_config("deepseek-coder-33b").replace(
        num_heads=4, num_kv_heads=2, head_dim=64)
    rng = np.random.default_rng(1)
    b, s, d = 1, 256, 64
    q = jnp.asarray(rng.normal(size=(b, s, 4, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, 2, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, 2, d)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    want = L._sdpa(cfg, q, k, v, pos, pos, None, None, causal=True)
    got = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
