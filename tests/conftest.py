"""Shared test configuration.

``REQUIRE_HYPOTHESIS=1`` (set by the full CI job, which installs the
``.[test]`` extras) turns the four property-test modules' polite
``pytest.importorskip("hypothesis")`` into a hard failure when the
library is absent — so a broken extras install surfaces as a red build
instead of 4 silently-skipped modules that *look* like coverage.
Minimal installs (the plan-api CI job, bare containers) leave the
variable unset and keep the graceful skip.
"""

import os

import pytest


def pytest_configure(config):
    if os.environ.get("REQUIRE_HYPOTHESIS"):
        try:
            import hypothesis  # noqa: F401
        except ImportError as e:
            raise pytest.UsageError(
                "REQUIRE_HYPOTHESIS is set but hypothesis is not "
                "importable — the property-test modules would silently "
                f"skip; install the .[test] extras ({e})")
