"""Per-stage elasticity tests: the controller's control law (synchronous,
against fakes), controller-driven scale up/down end-to-end, the
plan-derived scale_up spec (regression for the shim-UDF bug), locked
holder-list mutation under sustained ingestion, exactly-once scale_down
drain, and retired-runner stats accounting.

Deliberately hypothesis-free: CI runs this module in the minimal container
job alongside test_pipeline_api.py.  Thread-heavy tests carry explicit
join timeouts AND a module-level pytest-timeout so a wedged drain fails
fast instead of hanging CI.
"""

import threading
import time
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import (ComputingStats, ElasticityController, ElasticSpec,
                        FeedConfig, FeedManager, PlanError, RefStore,
                        SyntheticAdapter, pipeline)
from repro.core.elasticity import Decision
from repro.core.enrich import queries as Q
from repro.core.intake import Adapter
from repro.core.records import SyntheticTweets

pytestmark = pytest.mark.timeout(180)


def make_manager(scale=0.002):
    store = RefStore()
    Q.make_reference_tables(store, scale=scale, seed=7)
    return FeedManager(store)


def scan_by_id(storage):
    rows = {}
    for chunk in storage.scan():
        for i in range(chunk["id"].shape[0]):
            rows[int(chunk["id"][i])] = {k: chunk[k][i] for k in chunk}
    return rows


class ReplayAdapter(Adapter):
    """Pre-generated frames replayed at memory speed (sustained backlog)."""

    def __init__(self, frames):
        super().__init__()
        self._frames = frames

    def frames(self):
        for f in self._frames:
            if self._stop.is_set():
                return
            yield f


class BurstThenQuietAdapter(Adapter):
    """A burst of frames at memory speed, a quiet gap (the feed stays open
    but idle), then a second burst — the square wave the controller must
    ride up AND down within one feed."""

    def __init__(self, frames, quiet_s):
        super().__init__()
        half = len(frames) // 2
        self._phases = [frames[:half], frames[half:]]
        self.quiet_s = quiet_s

    def frames(self):
        for i, phase in enumerate(self._phases):
            if i:
                time.sleep(self.quiet_s)
            for f in phase:
                if self._stop.is_set():
                    return
                yield f


# ---------------------------------------------------------------------------
# control law, synchronously against fakes (no threads, injectable clock)
# ---------------------------------------------------------------------------

class FakeHolder:
    def __init__(self):
        self.rows = 0

    def backlog(self):
        return self.rows, self.rows * 100


def _fake_slot():
    return SimpleNamespace(runner=SimpleNamespace(stats=ComputingStats()),
                           thread=SimpleNamespace(is_alive=lambda: True))


class FakeHandle:
    def __init__(self, spec, partitions):
        g = SimpleNamespace(gid=0, name="g", elastic=spec,
                            holders=[FakeHolder() for _ in range(partitions)],
                            slots=[_fake_slot() for _ in range(partitions)])
        self.stage_groups = [g]

    def set_backlog(self, rows):
        g = self.stage_groups[0]
        for h in g.holders:
            h.rows = rows // len(g.holders)
        g.holders[0].rows += rows - sum(h.rows for h in g.holders)

    def scale_up(self, n, stage=0):
        g = self.stage_groups[stage]
        for _ in range(n):
            g.holders.append(FakeHolder())
            g.slots.append(_fake_slot())
        return n

    def scale_down(self, n, stage=0):
        g = self.stage_groups[stage]
        dropped = 0
        for _ in range(n):
            if len(g.holders) <= 1:
                break
            g.holders.pop()
            g.slots.pop()
            dropped += 1
        return dropped


def test_control_law_scales_up_with_hysteresis_and_cooldown():
    spec = ElasticSpec(min_partitions=1, max_partitions=3, up_after=2,
                       down_after=3, cooldown_s=1.0, high_watermark=1.5,
                       low_watermark=0.25)
    h = FakeHandle(spec, partitions=1)
    c = ElasticityController(h, batch_size=100)
    parts = lambda: len(h.stage_groups[0].holders)

    h.set_backlog(200)                      # > 1.5 * 100 * 1
    c.step(now=0.0)
    assert parts() == 1                     # one high sample: not yet
    c.step(now=0.1)
    assert parts() == 2                     # up_after=2 reached
    h.set_backlog(400)                      # > 1.5 * 100 * 2
    c.step(now=0.2)
    c.step(now=0.3)
    assert parts() == 2                     # inside cooldown: held
    c.step(now=1.2)
    c.step(now=1.3)
    assert parts() == 3                     # cooldown over
    h.set_backlog(10_000)
    for i in range(5):
        c.step(now=2.5 + i)
    assert parts() == 3                     # max_partitions is a hard bound


def test_control_law_scales_down_to_min_when_idle():
    spec = ElasticSpec(min_partitions=1, max_partitions=4, up_after=1,
                       down_after=2, cooldown_s=0.0, low_watermark=0.25)
    h = FakeHandle(spec, partitions=3)
    c = ElasticityController(h, batch_size=100)

    h.set_backlog(0)
    for i in range(10):
        c.step(now=float(i))
    assert len(h.stage_groups[0].holders) == 1   # down to min, never below
    downs = [d for d in c.decisions if d.action == "down"]
    assert [d.partitions for d in downs] == [2, 1]
    assert all(1 <= d.partitions <= 4 for d in c.decisions)


def test_elastic_spec_and_plan_validation():
    with pytest.raises(ValueError, match="min <= max"):
        ElasticSpec(min_partitions=3, max_partitions=2)
    with pytest.raises(ValueError, match="interval_s"):
        ElasticSpec(interval_s=0)
    mgr = make_manager()
    adapter = SyntheticAdapter(total=10, frame_size=10)
    with pytest.raises(PlanError, match="invalid elastic spec"):
        pipeline(adapter, "bad").options(elastic=dict(min_partitions=9,
                                                      max_partitions=1))
    with pytest.raises(PlanError, match="elastic must be"):
        pipeline(adapter, "bad2").options(elastic=42)
    with pytest.raises(PlanError, match="partitions=..."):
        pipeline(adapter, "bad3").enrich(Q.Q1, partitions=0)
    with pytest.raises(PlanError, match="outside elastic bounds"):
        (pipeline(adapter, "bad4")
         .enrich(Q.Q1, partitions=8,
                 elastic=ElasticSpec(min_partitions=1, max_partitions=2))
         .store().compile(mgr.refstore))


# ---------------------------------------------------------------------------
# controller end-to-end: rides a burst up, rides the quiet back down
# ---------------------------------------------------------------------------

def test_controller_scales_up_under_backlog_and_down_when_idle():
    mgr = make_manager()
    total, frame = 4000, 50
    # warm the Q4 executable first (shared predeploy cache): a cold jit
    # compile inside the measured feed could eat the quiet window and
    # leave the backlog high until the second burst — flaky scale_downs=0
    warm = (pipeline(SyntheticAdapter(total=4 * frame, frame_size=frame,
                                      seed=30), "ride-warm")
            .parse(batch_size=frame)
            .options(num_partitions=1, coalesce_rows=0)
            .enrich(Q.Q4).store())
    mgr.submit(warm).join(timeout=120)

    frames = list(SyntheticTweets(seed=31).batches(total, frame))
    plan = (pipeline(BurstThenQuietAdapter(frames, quiet_s=2.5), "ride")
            .parse(batch_size=frame)
            .options(num_partitions=1, coalesce_rows=0, holder_capacity=64,
                     elastic=dict(min_partitions=1, max_partitions=3,
                                  interval_s=0.01, up_after=1,
                                  down_after=5, cooldown_s=0.05))
            .enrich(Q.Q4)
            .store())
    h = mgr.submit(plan)
    stats = h.join(timeout=240)
    assert stats.stored == total                  # nothing lost or doubled
    assert stats.scale_ups >= 1                   # rode the burst up...
    assert stats.scale_downs >= 1                 # ...and the quiet down
    decisions = h.controller.decisions
    assert all(1 <= d.partitions <= 3 for d in decisions)
    assert stats.peak_partitions["q4_nearby_monuments"] <= 3
    # every sample also respected the bounds
    assert all(1 <= p <= 3 for p in h.controller.partition_timeline())


# ---------------------------------------------------------------------------
# scale_up regression: plan-derived spec, bitwise-identical enrichment
# ---------------------------------------------------------------------------

def _enriched_plan(mgr, name, total, frame, rate=None):
    return (pipeline(SyntheticAdapter(total=total, frame_size=frame,
                                      seed=13, rate=rate), name)
            .parse(batch_size=frame)
            .options(num_partitions=1, coalesce_rows=0)
            .enrich(Q.Q1).enrich(Q.Q2)
            .filter(lambda b: b["country"] >= 0, name="keep_all")
            .store())


def test_scale_up_plan_feed_bitwise_identical_to_unscaled():
    """The acceptance criterion: a plan-submitted feed that scales up
    mid-stream produces bitwise-identical enriched output to the same feed
    without scaling (the old code rebuilt the spec from the FeedConfig
    shim's ``cfg.udf`` — scaled-up workers would run the wrong pipeline)."""
    mgr = make_manager()
    total, frame = 2000, 50

    h_plain = mgr.submit(_enriched_plan(mgr, "plain", total, frame))
    s_plain = h_plain.join(timeout=120)
    assert s_plain.stored == total

    h_scaled = mgr.submit(_enriched_plan(mgr, "scaled", total, frame,
                                         rate=30_000.0))
    time.sleep(0.02)
    added = h_scaled.scale_up(2)
    s_scaled = h_scaled.join(timeout=120)
    assert s_scaled.stored == total

    # the scaled-up workers got the COMPILED PLAN's fused stages, not a
    # spec re-derived from the shim config
    plan_udf = h_scaled.plan.udf
    assert added >= 1
    assert all(r.spec.udf is plan_udf for r in h_scaled.runners)
    assert h_scaled.stage_groups[0].spec.udf is plan_udf

    plain, scaled = scan_by_id(h_plain.storage), scan_by_id(h_scaled.storage)
    assert set(plain) == set(scaled)
    for rid, row in plain.items():
        for col, v in row.items():
            np.testing.assert_array_equal(v, scaled[rid][col], err_msg=col)


def test_scale_up_after_drain_is_refused():
    mgr = make_manager()
    h = mgr.submit(_enriched_plan(mgr, "drained", 200, 50))
    h.join(timeout=120)
    assert h.scale_up(1) == 0        # late worker would miss its StopRecord


def test_scale_on_coupled_baseline_raises():
    mgr = make_manager()
    cfg = FeedConfig(name="coupled", udf=Q.Q1, batch_size=50,
                     num_partitions=2, framework="balanced")
    h = mgr.start(cfg, SyntheticAdapter(total=200, frame_size=50))
    with pytest.raises(RuntimeError, match="decoupled plan path"):
        h.scale_up(1)
    with pytest.raises(RuntimeError, match="decoupled plan path"):
        h.scale_down(1)
    assert h.join(timeout=120).stored == 200


# ---------------------------------------------------------------------------
# locked holder-list mutation: scaling during sustained ingestion
# ---------------------------------------------------------------------------

def test_scaling_during_sustained_ingestion_drops_nothing():
    """Stress the lock paths: scale up AND down repeatedly while a
    replayed stream keeps every holder backlogged; every record must reach
    the store and the tee exactly once."""
    mgr = make_manager()
    total, frame = 10_000, 25
    frames = list(SyntheticTweets(seed=41).batches(total, frame))
    seen = {}
    lock = threading.Lock()

    def counting_sink(batch):
        ids = batch["id"][batch["valid"]]
        with lock:
            for i in ids:
                seen[int(i)] = seen.get(int(i), 0) + 1

    plan = (pipeline(ReplayAdapter(frames), "stress")
            .parse(batch_size=frame)
            .options(num_partitions=1, coalesce_rows=0)
            .enrich(Q.Q1)
            .tee(counting_sink, name="count")
            .store())
    h = mgr.submit(plan)

    stop = threading.Event()

    def churn():
        step = 0
        while not stop.is_set():
            if step % 3 == 2:
                h.scale_down(1)
            else:
                h.scale_up(1)
            step += 1
            time.sleep(0.01)

    t = threading.Thread(target=churn, daemon=True)
    t.start()
    try:
        stats = h.join(timeout=240)
    finally:
        stop.set()
        t.join(timeout=10)
    assert stats.stored == total
    assert len(seen) == total
    assert set(seen.values()) == {1}          # exactly once, never twice
    assert stats.scale_ups >= 2 and stats.scale_downs >= 1


def test_holder_close_is_atomic_with_stop_enqueue():
    """Regression (review finding): ``close()`` must mark the holder
    closed the moment the StopRecord is ENQUEUED, not when a consumer
    pulls it — otherwise a push racing into that window lands behind the
    STOP on a retired holder and is silently lost (the round-robin
    re-target only fires when push raises)."""
    from repro.core import PartitionHolder, StopRecord
    h = PartitionHolder(("t", 0), capacity=4)
    h.push([b"a"])
    h.close()
    assert h.closed                        # atomic with the STOP enqueue
    with pytest.raises(RuntimeError, match="closed holder"):
        h.push([b"b"])                     # racing push bounces: re-target
    assert h.pull(timeout=0) == [b"a"]     # pre-STOP frames still drain
    assert isinstance(h.pull(timeout=0), StopRecord)


def test_scale_down_drains_exactly_once_into_store():
    mgr = make_manager()
    total, frame = 5000, 25
    frames = list(SyntheticTweets(seed=43).batches(total, frame))
    plan = (pipeline(ReplayAdapter(frames), "drain")
            .parse(batch_size=frame)
            .options(num_partitions=3, coalesce_rows=0, holder_capacity=16)
            .enrich(Q.Q1)
            .store())
    h = mgr.submit(plan)
    time.sleep(0.05)                  # let the holders fill
    dropped = h.scale_down(2)
    stats = h.join(timeout=240)
    assert dropped >= 1               # retired mid-stream, queues nonempty
    assert stats.stored == total      # drained exactly-once, nothing lost
    assert h.storage.count == total
    assert len(h.stage_groups[0].holders) == 3 - dropped


def test_retired_runner_stats_are_merged_into_feed_totals():
    """Satellite bugfix: workers retired by scale_down must contribute
    their ComputingStats to the feed totals — records must not vanish."""
    mgr = make_manager()
    total, frame = 4000, 25
    frames = list(SyntheticTweets(seed=47).batches(total, frame))
    plan = (pipeline(ReplayAdapter(frames), "retire-stats")
            .parse(batch_size=frame)
            .options(num_partitions=3, coalesce_rows=0, holder_capacity=16)
            .enrich(Q.Q1)
            .store())
    h = mgr.submit(plan)
    time.sleep(0.05)
    dropped = h.scale_down(2)
    stats = h.join(timeout=240)
    assert dropped >= 1
    assert stats.stored == total
    # the retired workers' invocation/record counts made it into the totals
    assert stats.computing.records == total
    assert stats.computing.per_stage["q1_safety_level"].records == total
    assert stats.computing.invocations == stats.sink_batches["store"]
    # the retired runners were dropped from the live list after merging
    assert len(h.runners) == len(h.stage_groups[0].slots)


# ---------------------------------------------------------------------------
# per-stage stage groups
# ---------------------------------------------------------------------------

def test_per_stage_groups_match_single_group_bitwise():
    """Splitting the chain at a stage boundary (own worker pool, linked by
    an intermediate holder) must not change a single output bit vs the
    fully fused single-group plan."""
    mgr = make_manager()
    total, frame = 1500, 50

    fused = (pipeline(SyntheticAdapter(total=total, frame_size=frame,
                                       seed=19), "fused")
             .parse(batch_size=frame)
             .options(num_partitions=1, coalesce_rows=0)
             .enrich(Q.Q1).enrich(Q.Q2)
             .store())
    h_fused = mgr.submit(fused)
    s_fused = h_fused.join(timeout=120)

    split = (pipeline(SyntheticAdapter(total=total, frame_size=frame,
                                       seed=19), "split")
             .parse(batch_size=frame)
             .options(num_partitions=1, coalesce_rows=0)
             .enrich(Q.Q1)
             .enrich(Q.Q2, partitions=2)         # stage-group boundary
             .store())
    plan = split.compile(mgr.refstore)
    assert [g.name for g in plan.stage_groups] == [
        "q1_safety_level", "q2_religious_population"]
    h_split = mgr.submit(plan)
    s_split = h_split.join(timeout=120)

    assert s_fused.stored == s_split.stored == total
    a, b = scan_by_id(h_fused.storage), scan_by_id(h_split.storage)
    assert set(a) == set(b)
    for rid, row in a.items():
        for col, v in row.items():
            np.testing.assert_array_equal(v, b[rid][col], err_msg=col)
    # both stages saw every record, each in its own group's workers
    per = s_split.computing.per_stage
    assert per["q1_safety_level"].records == total
    assert per["q2_religious_population"].records == total
    # the heavy group really ran 2 partitions
    assert s_split.peak_partitions["q2_religious_population"] == 2


def test_scale_targets_the_requested_stage_group():
    mgr = make_manager()
    total, frame = 3000, 50
    plan = (pipeline(SyntheticAdapter(total=total, frame_size=frame,
                                      seed=23, rate=40_000.0), "staged")
            .parse(batch_size=frame)
            .options(num_partitions=1, coalesce_rows=0)
            .enrich(Q.Q1)
            .enrich(Q.Q2, partitions=1)
            .store())
    h = mgr.submit(plan)
    time.sleep(0.02)
    added = h.scale_up(2, stage=1)
    stats = h.join(timeout=120)
    assert stats.stored == total
    if added:                          # scaling landed mid-stream
        assert h.stage_groups[0].peak_partitions == 1
        assert h.stage_groups[1].peak_partitions == 1 + added
    # group-1 runners got group 1's sub-chain, not the whole fused chain
    assert all(r.spec.udf.name == "q2_religious_population"
               for r in h.stage_groups[1].slots
               for r in [r.runner])


def test_per_stage_elastic_only_scales_declared_stage():
    """Elastic bounds declared on one stage leave the other static."""
    mgr = make_manager()
    total, frame = 4000, 50
    frames = list(SyntheticTweets(seed=29).batches(total, frame))
    plan = (pipeline(ReplayAdapter(frames), "stage-elastic")
            .parse(batch_size=frame)
            .options(num_partitions=1, coalesce_rows=0, holder_capacity=64)
            .enrich(Q.Q1)
            .enrich(Q.Q4, partitions=1,
                    elastic=ElasticSpec(min_partitions=1, max_partitions=3,
                                        interval_s=0.01, up_after=1,
                                        cooldown_s=0.05))
            .store())
    h = mgr.submit(plan)
    stats = h.join(timeout=240)
    assert stats.stored == total
    assert h.stage_groups[0].peak_partitions == 1      # static stage held
    assert stats.peak_partitions["q4_nearby_monuments"] <= 3
    # controller decisions only ever touched the declared stage (gid 1)
    assert all(d.gid == 1 for d in h.controller.decisions)
