"""Serving engine tests: continuous batching correctness — slot splicing,
bucketed prefill, and parity with naive one-at-a-time generation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import api
from repro.serve import Request, ServingEngine


def _greedy_reference(cfg, params, prompt, n):
    """Naive single-request generation (prefill exact + decode loop)."""
    tokens = jnp.asarray(np.asarray(prompt, np.int32)[None])
    frontend = None
    if cfg.family in ("vlm", "encdec"):
        frontend = jnp.zeros((1, cfg.num_frontend_tokens, cfg.d_model),
                             jnp.dtype(cfg.dtype))
    cache, logits = api.prefill(cfg, params, tokens, frontend)
    cache = api.pad_cache(cfg, cache, 128)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n - 1):
        logits, cache = api.decode_step(
            cfg, params, cache,
            jnp.asarray([[out[-1]]], jnp.int32))
        out.append(int(jnp.argmax(logits[0])))
    return out


@pytest.mark.parametrize("arch", ["deepseek-coder-33b", "olmoe-1b-7b",
                                  "mamba2-130m"])
def test_engine_matches_naive_generation(arch):
    cfg = smoke_config(arch)
    params = api.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(5)
    n_new = 6
    prompts = [rng.integers(16, cfg.vocab_size, 8).tolist()
               for _ in range(5)]
    engine = ServingEngine(cfg, params, slots=2, max_len=128)
    reqs = [engine.submit(Request(p, max_new_tokens=n_new,
                                  stop_at_eos=False)) for p in prompts]
    done = engine.run()
    assert len(done) == 5
    for req, prompt in zip(reqs, prompts):
        want = _greedy_reference(cfg, params, prompt, n_new)
        assert req.tokens == want, (req.rid, req.tokens, want)


def test_engine_continuous_refill():
    """More requests than slots: finished slots refill without draining."""
    cfg = smoke_config("deepseek-coder-33b")
    params = api.init_params(cfg, jax.random.key(0))
    engine = ServingEngine(cfg, params, slots=2, max_len=64)
    for i in range(6):
        engine.submit(Request([20 + i, 21, 22, 23], max_new_tokens=3,
                              stop_at_eos=False))
    done = engine.run()
    assert len(done) == 6
    assert all(len(r.tokens) == 3 for r in done)
    # 6 requests x 3 tokens on 2 slots needs >= 6 decode steps, but far
    # fewer than 18 (continuous batching actually batched)
    assert 6 <= engine.decode_steps <= 14


def test_engine_bucketed_prefill_correct():
    """Prompt lengths off the bucket boundary still decode correctly
    (the junk-overwrite invariant)."""
    cfg = smoke_config("deepseek-coder-33b")
    params = api.init_params(cfg, jax.random.key(0))
    prompt = [17, 18, 19]               # bucket pads to 16
    engine = ServingEngine(cfg, params, slots=1, max_len=64,
                           prompt_bucket=16)
    req = engine.submit(Request(prompt, max_new_tokens=5,
                                stop_at_eos=False))
    engine.run()
    want = _greedy_reference(cfg, params, prompt, 5)
    assert req.tokens == want
