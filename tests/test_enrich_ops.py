"""Operator-level tests for the enrichment algebra (core/enrich/ops.py)
against brute-force numpy oracles, including hypothesis property sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.enrich import ops
from repro.core.refdata import KEY_SENTINEL


def _pad_sorted_keys(keys, capacity):
    out = np.full((capacity,), KEY_SENTINEL, np.int64)
    out[:len(keys)] = np.sort(keys)
    return out


# ---------------------------------------------------------------------------
# sorted_join
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.data())
def test_sorted_join_matches_dict(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    nref = data.draw(st.integers(1, 64))
    nprobe = data.draw(st.integers(1, 64))
    cap = nref + data.draw(st.integers(0, 16))
    ref = rng.choice(200, nref, replace=False).astype(np.int64)
    keys = _pad_sorted_keys(ref, cap)
    probe = rng.integers(0, 220, nprobe).astype(np.int64)
    idx, found = jax.jit(ops.sorted_join)(jnp.asarray(probe),
                                          jnp.asarray(keys))
    table = {int(k): i for i, k in enumerate(keys[:nref])}
    for j in range(nprobe):
        if int(probe[j]) in table:
            assert bool(found[j])
            assert int(keys[int(idx[j])]) == int(probe[j])
        else:
            assert not bool(found[j])


def test_sorted_join_sentinel_probe_never_matches():
    keys = _pad_sorted_keys(np.array([5], np.int64), 4)
    probe = jnp.asarray(np.array([KEY_SENTINEL, 5], np.int64))
    _, found = ops.sorted_join(probe, jnp.asarray(keys))
    assert not bool(found[0]) and bool(found[1])


# ---------------------------------------------------------------------------
# segment ops
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.data())
def test_segment_sum_count(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    n = data.draw(st.integers(1, 200))
    s = data.draw(st.integers(1, 20))
    seg = rng.integers(0, s, n)
    vals = rng.integers(0, 100, n)
    valid = rng.random(n) < 0.8
    got = np.asarray(ops.segment_sum(jnp.asarray(vals), jnp.asarray(seg), s,
                                     jnp.asarray(valid)))
    want = np.zeros(s, np.int64)
    for i in range(n):
        if valid[i]:
            want[seg[i]] += vals[i]
    np.testing.assert_array_equal(got, want)


def test_segment_topk_exact():
    seg = jnp.asarray(np.array([0, 0, 0, 1, 1, 2], np.int32))
    vals = jnp.asarray(np.array([5, 9, 7, 3, 8, 1], np.int32))
    pay = jnp.asarray(np.array([10, 11, 12, 13, 14, 15], np.int32))
    top_pay, top_val = ops.segment_topk(vals, seg, pay, 4, 2)
    np.testing.assert_array_equal(np.asarray(top_val),
                                  [[9, 7], [8, 3], [1, 0], [0, 0]])
    np.testing.assert_array_equal(np.asarray(top_pay),
                                  [[11, 12], [14, 13], [15, -1], [-1, -1]])


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_segment_topk_property(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    n = data.draw(st.integers(1, 150))
    s = data.draw(st.integers(1, 10))
    k = data.draw(st.integers(1, 4))
    seg = rng.integers(0, s, n).astype(np.int32)
    vals = rng.integers(0, 1000, n).astype(np.int32)
    pay = np.arange(n, dtype=np.int32)
    top_pay, top_val = ops.segment_topk(
        jnp.asarray(vals), jnp.asarray(seg), jnp.asarray(pay), s, k)
    top_pay, top_val = np.asarray(top_pay), np.asarray(top_val)
    for g in range(s):
        want = sorted(vals[seg == g], reverse=True)[:k]
        got = [v for v, p in zip(top_val[g], top_pay[g]) if p >= 0]
        assert got == want, (g, got, want)
        # returned payloads actually hold the claimed values
        for v, p in zip(top_val[g], top_pay[g]):
            if p >= 0:
                assert vals[p] == v and seg[p] == g


# ---------------------------------------------------------------------------
# spatial ops
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.data())
def test_radius_ops_vs_bruteforce(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    b = data.draw(st.integers(1, 40))
    r = data.draw(st.integers(1, 60))
    pts = rng.uniform(-10, 10, (b, 2)).astype(np.float32)
    refs = rng.uniform(-10, 10, (r, 2)).astype(np.float32)
    valid = rng.random(r) < 0.8
    radius = 4.0
    d2 = ((pts[:, None, :] - refs[None, :, :]) ** 2).sum(-1)
    want_count = ((d2 <= radius ** 2) & valid[None, :]).sum(1)

    count = np.asarray(ops.radius_count(
        jnp.asarray(pts), jnp.asarray(refs), radius, jnp.asarray(valid),
        chunk=8))
    np.testing.assert_array_equal(count, want_count)

    k = 3
    idx, dd, cnt = ops.radius_topk(jnp.asarray(pts), jnp.asarray(refs),
                                   radius, k, jnp.asarray(valid), chunk=8)
    idx, dd = np.asarray(idx), np.asarray(dd)
    np.testing.assert_array_equal(np.asarray(cnt), want_count)
    for i in range(b):
        dmask = np.where(valid, d2[i], np.inf)
        order = np.argsort(dmask)
        want = [j for j in order[:k] if dmask[j] <= radius ** 2]
        got = [j for j in idx[i] if j >= 0]
        assert got == want, (i, got, want)


def test_point_in_rect_chunked_equals_unchunked():
    rng = np.random.default_rng(0)
    pts = jnp.asarray(rng.uniform(-5, 5, (100, 2)).astype(np.float32))
    rects = jnp.asarray(
        np.stack([rng.uniform(-5, 0, 20), rng.uniform(-5, 0, 20),
                  rng.uniform(0, 5, 20), rng.uniform(0, 5, 20)],
                 axis=1).astype(np.float32))
    a_idx, a_found = ops.point_in_rect(pts, rects, chunk=16)
    b_idx, b_found = ops.point_in_rect(pts, rects, chunk=1000)
    np.testing.assert_array_equal(np.asarray(a_idx), np.asarray(b_idx))
    np.testing.assert_array_equal(np.asarray(a_found), np.asarray(b_found))


def test_pairwise_dist2_identity():
    rng = np.random.default_rng(1)
    a = rng.uniform(-3, 3, (17, 2)).astype(np.float32)
    b = rng.uniform(-3, 3, (23, 2)).astype(np.float32)
    got = np.asarray(ops.pairwise_dist2(jnp.asarray(a), jnp.asarray(b)))
    want = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# text / time ops
# ---------------------------------------------------------------------------

def test_contains_any():
    toks = jnp.asarray(np.array([[1, 2, 3, 0], [4, 5, 6, 0],
                                 [0, 0, 0, 0]], np.int64))
    kws = jnp.asarray(np.array([3, 9], np.int64))
    got = np.asarray(ops.contains_any(toks, kws))
    np.testing.assert_array_equal(got, [True, False, False])


def test_time_window_count():
    t = jnp.asarray(np.array([100, 200], np.int64))
    ev_t = jnp.asarray(np.array([95, 99, 150, 210], np.int64))
    ev_g = jnp.asarray(np.array([1, 1, 2, 1], np.int32))
    groups = jnp.asarray(np.array([[1, 2], [1, 2]], np.int32))
    got = np.asarray(ops.time_window_count_by_group(t, ev_t, ev_g, groups,
                                                    window=50))
    # t=100: window (50,100): events 95(g1), 99(g1) -> g1:2, g2:0
    # t=200: window (150,200): none strictly inside -> 0,0
    np.testing.assert_array_equal(got, [[2, 0], [0, 0]])
